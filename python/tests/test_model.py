"""L2 correctness: the while_loop fixpoint vs the python-loop oracle and
the classic AC-3 closure.  Also pins the batched / incremental variants
and the padding-neutrality contract the Rust router relies on."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

BX = 8


def _inst(n, d, density, tightness, seed):
    cons, vars_ = ref.random_instance(n, d, density, tightness, seed)
    return jnp.array(cons), jnp.array(vars_)


class TestFixpoint:
    def test_agrees_with_python_loop_oracle(self):
        cons, vars_ = _inst(16, 8, 0.5, 0.4, 1)
        want_v, want_it, want_w = ref.fixpoint_ref(cons, vars_)
        got_v, got_it, got_st = model.rtac_fixpoint(cons, vars_, block_x=BX)
        assert_allclose(np.array(got_v), np.array(want_v))
        assert int(got_it) == want_it
        assert (int(got_st) == model.STATUS_WIPEOUT) == want_w

    def test_agrees_with_ac3_closure(self):
        cons, vars_ = _inst(16, 8, 0.6, 0.45, 2)
        got_v, _, got_st = model.rtac_fixpoint(cons, vars_, block_x=BX)
        ac3_v, _, ac3_w = ref.ac3_closure(np.array(cons), np.array(vars_))
        if ac3_w:
            assert int(got_st) == model.STATUS_WIPEOUT
        else:
            assert_allclose(np.array(got_v), ac3_v)
            assert int(got_st) == model.STATUS_CONSISTENT

    def test_already_consistent_takes_one_sweep(self):
        n, d = 8, 4
        cons = jnp.ones((n, n, d, d), dtype=jnp.float32)
        vars_ = jnp.ones((n, d), dtype=jnp.float32)
        v, it, st = model.rtac_fixpoint(cons, vars_, block_x=4)
        assert int(it) == 1  # the sweep that discovers the fixpoint
        assert int(st) == model.STATUS_CONSISTENT
        assert_allclose(np.array(v), np.ones((n, d), np.float32))

    def test_wipeout_detected_and_aborted(self):
        n, d = 8, 4
        cons = np.ones((n, n, d, d), dtype=np.float32)
        cons[0, 1] = 0.0  # empty relation: UNSAT
        cons[1, 0] = 0.0
        v, it, st = model.rtac_fixpoint(jnp.array(cons),
                                        jnp.ones((n, d), jnp.float32), block_x=4)
        assert int(st) == model.STATUS_WIPEOUT
        assert int(it) == 1  # wiped on the very first sweep -> abort

    def test_assignment_propagates(self):
        # x0 := value 0 under an equality chain forces everyone to 0.
        n, d = 8, 4
        eq = np.eye(d, dtype=np.float32)
        cons = np.ones((n, n, d, d), dtype=np.float32)
        for x in range(n - 1):
            cons[x, x + 1] = eq
            cons[x + 1, x] = eq
        vars_ = np.ones((n, d), dtype=np.float32)
        vars_[0] = [1, 0, 0, 0]
        v, it, st = model.rtac_fixpoint(jnp.array(cons), jnp.array(vars_), block_x=4)
        assert int(st) == model.STATUS_CONSISTENT
        want = np.zeros((n, d), np.float32)
        want[:, 0] = 1.0
        assert_allclose(np.array(v), want)
        # a chain of length n needs ~n sweeps: the worst case the paper's
        # Table 1 says random networks avoid.
        assert int(it) >= n - 2

    @settings(max_examples=15, deadline=None)
    @given(
        density=st.floats(0.1, 1.0),
        tightness=st.floats(0.1, 0.7),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_fixpoint_equals_ac3(self, density, tightness, seed):
        cons, vars_ = _inst(8, 4, density, tightness, seed)
        got_v, got_it, got_st = model.rtac_fixpoint(cons, vars_, block_x=4)
        ac3_v, _, ac3_w = ref.ac3_closure(np.array(cons), np.array(vars_))
        if ac3_w:
            assert int(got_st) == model.STATUS_WIPEOUT
        else:
            assert_allclose(np.array(got_v), ac3_v)
        # fixpoint property: one more sweep changes nothing (unless wiped)
        if int(got_st) == model.STATUS_CONSISTENT:
            again = model.rtac_step(cons, got_v, block_x=4)
            assert_allclose(np.array(again), np.array(got_v))


class TestBatched:
    def test_batched_equals_mapped_unbatched(self):
        cons, _ = _inst(16, 8, 0.5, 0.4, 3)
        planes = []
        for seed in range(4):
            _, v = _inst(16, 8, 0.0, 0.0, seed)
            v = np.array(v)
            rng = np.random.default_rng(seed)
            # random partial assignments (search-node snapshots)
            for x in rng.choice(16, size=3, replace=False):
                keep = rng.integers(0, 8)
                v[x] = 0.0
                v[x, keep] = 1.0
            planes.append(v)
        batch = jnp.array(np.stack(planes))
        vb, _, stb = model.rtac_fixpoint_batched(cons, batch, block_x=BX)
        for i, plane in enumerate(planes):
            vi, _, sti = model.rtac_fixpoint(cons, jnp.array(plane), block_x=BX)
            assert int(stb[i]) == int(sti)
            if int(sti) == model.STATUS_CONSISTENT:
                assert_allclose(np.array(vb[i]), np.array(vi))

    def test_wiped_plane_does_not_poison_batch(self):
        n, d = 8, 4
        cons = np.ones((n, n, d, d), dtype=np.float32)
        rel = np.zeros((d, d), np.float32)
        rel[0, 0] = 1.0
        cons[0, 1] = rel
        cons[1, 0] = rel.T
        ok_plane = np.ones((n, d), np.float32)
        bad_plane = ok_plane.copy()
        bad_plane[0] = [0, 1, 0, 0]  # (0,1) has no support -> wipeout of x0
        batch = jnp.array(np.stack([bad_plane, ok_plane]))
        vb, _, stb = model.rtac_fixpoint_batched(jnp.array(cons), batch, block_x=4)
        assert int(stb[0]) == model.STATUS_WIPEOUT
        assert int(stb[1]) == model.STATUS_CONSISTENT
        want, _, _ = model.rtac_fixpoint(jnp.array(cons), jnp.array(ok_plane), block_x=4)
        assert_allclose(np.array(vb[1]), np.array(want))


class TestIncremental:
    @settings(max_examples=10, deadline=None)
    @given(
        density=st.floats(0.2, 1.0),
        tightness=st.floats(0.2, 0.6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_incremental_identical_to_dense(self, density, tightness, seed):
        cons, vars_ = _inst(8, 4, density, tightness, seed)
        v1, it1, st1 = model.rtac_fixpoint(cons, vars_, block_x=4)
        v2, it2, st2 = model.rtac_fixpoint_incremental(cons, vars_, block_x=4)
        assert int(st1) == int(st2)
        assert int(it1) == int(it2)
        if int(st1) == model.STATUS_CONSISTENT:
            assert_allclose(np.array(v1), np.array(v2))


class TestPaddingNeutrality:
    """The Rust router pads (n, d) up to a bucket; padding must be
    AC-neutral: universal relations on padded rows, 1.0 on padded values
    of real variables... actually padded *values* must be 0 for real
    variables (absent from the domain) and padded *variables* get a full
    singleton-free all-ones row that nothing constrains."""

    def test_padding_preserves_closure(self):
        n, d, N, D = 6, 3, 8, 4
        cons, vars_ = ref.random_instance(n, d, 0.7, 0.5, 9)
        # embed into the (N, D) bucket
        big_cons = np.ones((N, N, D, D), dtype=np.float32)
        big_cons[:n, :n, :d, :d] = cons
        # real (x,y) pairs: padded b-columns must NOT provide fake support
        # for real values -> forbid (a<d, b>=d) and (a>=d, b<d) on real
        # constrained pairs.  Simplest sound scheme: for x,y < n copy the
        # relation and zero the padded region except the (pad,pad) corner.
        for x in range(n):
            for y in range(n):
                if x != y and not np.all(cons[x, y] == 1.0):
                    big_cons[x, y, :d, d:] = 0.0
                    big_cons[x, y, d:, :d] = 0.0
        big_vars = np.zeros((N, D), dtype=np.float32)
        big_vars[:n, :d] = vars_
        big_vars[n:, :] = 1.0  # padded variables: full dummy domains
        # padded values of real variables stay 0 (not in the domain)

        small_v, small_it, small_st = model.rtac_fixpoint(
            jnp.array(cons), jnp.array(vars_), block_x=2
        )
        big_v, big_it, big_st = model.rtac_fixpoint(
            jnp.array(big_cons), jnp.array(big_vars), block_x=4
        )
        assert int(small_st) == int(big_st)
        if int(small_st) == model.STATUS_CONSISTENT:
            assert_allclose(np.array(big_v)[:n, :d], np.array(small_v))
            # padding untouched
            assert np.all(np.array(big_v)[n:, :] == 1.0)
            assert np.all(np.array(big_v)[:n, d:] == 0.0)
            assert int(small_it) == int(big_it)
