"""AOT pipeline checks: manifest integrity, HLO text well-formedness, and
round-trip executability of the lowered modules on the *python* side
(jax.jit on CPU).  The Rust-side load/execute path is covered by
`cargo test` integration tests against the same artifacts."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))), "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
class TestManifest:
    def _manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_entry_file_exists_and_is_hlo(self):
        m = self._manifest()
        assert m["format"] == 1
        assert len(m["entries"]) >= len(aot.BUCKETS) * 2
        for e in m["entries"]:
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), e["file"]
            text = open(path).read()
            assert text.startswith("HloModule"), e["file"]
            assert e["hlo_bytes"] == len(text)

    def test_entry_shapes_declared_in_hlo(self):
        m = self._manifest()
        for e in m["entries"]:
            text = open(os.path.join(ART, e["file"])).read()
            first = text.splitlines()[0]
            n, d, b = e["n"], e["d"], e["batch"]
            assert f"f32[{n},{n},{d},{d}]" in first, e["name"]
            if e["kind"] == "fixpoint_batched":
                assert f"f32[{b},{n},{d}]" in first, e["name"]
            else:
                assert f"f32[{n},{d}]" in first, e["name"]

    def test_all_kinds_present_per_bucket(self):
        m = self._manifest()
        kinds = {}
        for e in m["entries"]:
            kinds.setdefault((e["n"], e["d"]), set()).add((e["kind"], e["batch"]))
        for (n, d) in aot.BUCKETS:
            have = kinds[(n, d)]
            assert ("step", 1) in have
            assert ("fixpoint", 1) in have
            for b in aot.BATCHES:
                assert ("fixpoint_batched", b) in have

    def test_block_x_recorded(self):
        assert self._manifest()["block_x"] == aot.BLOCK_X


class TestLoweredSemantics:
    """Lower-to-HLO must not change semantics: execute the same jitted
    callables the AOT pipeline lowers and compare with the oracle."""

    def test_fixpoint_lowered_matches_oracle(self):
        n, d = 8, 4
        cons, vars_ = ref.random_instance(n, d, 0.6, 0.45, 21)
        fn = jax.jit(lambda c, v: model.rtac_fixpoint(c, v, block_x=4))
        got_v, got_it, got_st = fn(jnp.array(cons), jnp.array(vars_))
        want_v, want_it, want_w = ref.fixpoint_ref(jnp.array(cons), jnp.array(vars_))
        if want_w:
            assert int(got_st) == model.STATUS_WIPEOUT
        else:
            assert_allclose(np.array(got_v), np.array(want_v))
            assert int(got_it) == want_it

    def test_hlo_text_roundtrip_stable(self):
        # Lowering the same function twice yields identical HLO text
        # (determinism `make artifacts` relies on for no-op rebuilds).
        n, d = 8, 4
        spec = jax.ShapeDtypeStruct((n, n, d, d), jnp.float32)
        vspec = jax.ShapeDtypeStruct((n, d), jnp.float32)
        f = lambda c, v: model.rtac_fixpoint(c, v, block_x=4)
        t1 = aot.to_hlo_text(jax.jit(f).lower(spec, vspec))
        t2 = aot.to_hlo_text(jax.jit(f).lower(spec, vspec))
        assert t1 == t2
