"""L1 correctness: the Pallas revise kernel vs the pure-jnp oracle.

The AC closure is a 0/1 grid, so equality is exact (no allclose slack
needed); we still route through assert_allclose for readable diffs.
Hypothesis sweeps shapes, densities, tightnesses and block sizes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref, revise


def _run_pair(n, d, density, tightness, seed, block_x):
    cons, vars_ = ref.random_instance(n, d, density, tightness, seed)
    got = revise.revise(jnp.array(cons), jnp.array(vars_), block_x=block_x)
    want = ref.revise_ref(jnp.array(cons), jnp.array(vars_))
    assert_allclose(np.array(got), np.array(want))
    return np.array(got)


class TestReviseBasics:
    def test_universal_relations_prune_nothing(self):
        n, d = 8, 4
        cons = np.ones((n, n, d, d), dtype=np.float32)
        vars_ = np.ones((n, d), dtype=np.float32)
        out = revise.revise(jnp.array(cons), jnp.array(vars_), block_x=4)
        assert_allclose(np.array(out), vars_)

    def test_empty_relation_wipes_both_sides(self):
        n, d = 8, 4
        cons = np.ones((n, n, d, d), dtype=np.float32)
        cons[0, 1] = 0.0
        cons[1, 0] = 0.0
        vars_ = np.ones((n, d), dtype=np.float32)
        out = np.array(revise.revise(jnp.array(cons), jnp.array(vars_), block_x=4))
        assert np.all(out[0] == 0.0)
        assert np.all(out[1] == 0.0)
        assert np.all(out[2:] == 1.0)

    def test_single_support_survives(self):
        n, d = 8, 4
        cons = np.ones((n, n, d, d), dtype=np.float32)
        rel = np.zeros((d, d), dtype=np.float32)
        rel[0, 3] = 1.0  # only (x=0,a=0) <-> (y=1,b=3) allowed
        cons[0, 1] = rel
        cons[1, 0] = rel.T
        vars_ = np.ones((n, d), dtype=np.float32)
        out = np.array(revise.revise(jnp.array(cons), jnp.array(vars_), block_x=4))
        assert out[0].tolist() == [1.0, 0.0, 0.0, 0.0]
        assert out[1].tolist() == [0.0, 0.0, 0.0, 1.0]

    def test_removed_value_gives_no_support(self):
        # (y, b) already removed must not count as a support.
        n, d = 8, 4
        cons = np.ones((n, n, d, d), dtype=np.float32)
        rel = np.zeros((d, d), dtype=np.float32)
        rel[1, 2] = 1.0
        cons[0, 1] = rel
        cons[1, 0] = rel.T
        vars_ = np.ones((n, d), dtype=np.float32)
        vars_[1, 2] = 0.0  # the lone support of (0,1) is gone
        out = np.array(revise.revise(jnp.array(cons), jnp.array(vars_), block_x=4))
        assert out[0, 1] == 0.0

    def test_matches_ref_on_dense_instance(self):
        _run_pair(16, 8, 1.0, 0.4, 3, block_x=8)

    def test_matches_ref_on_sparse_instance(self):
        _run_pair(16, 8, 0.1, 0.4, 4, block_x=8)

    def test_idempotent_on_fixpoint(self):
        cons, vars_ = ref.random_instance(8, 4, 0.5, 0.4, 11)
        v, _, _ = ref.fixpoint_ref(jnp.array(cons), jnp.array(vars_))
        again = revise.revise(jnp.array(cons), v, block_x=4)
        assert_allclose(np.array(again), np.array(v))


class TestReviseHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([4, 8, 16]),
        d=st.sampled_from([2, 4, 8]),
        density=st.floats(0.0, 1.0),
        tightness=st.floats(0.0, 0.8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_kernel_equals_ref(self, n, d, density, tightness, seed):
        _run_pair(n, d, density, tightness, seed, block_x=min(8, n))

    @settings(max_examples=10, deadline=None)
    @given(
        block_x=st.sampled_from([1, 2, 4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_block_shape_invariance(self, block_x, seed):
        # The perf pass sweeps block_x; results must not depend on it.
        n, d = 16, 4
        cons, vars_ = ref.random_instance(n, d, 0.7, 0.5, seed)
        got = revise.revise(jnp.array(cons), jnp.array(vars_), block_x=block_x)
        want = ref.revise_ref(jnp.array(cons), jnp.array(vars_))
        assert_allclose(np.array(got), np.array(want))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_monotone_removal(self, seed):
        # A sweep only removes values, never adds (D~ grows monotonically).
        n, d = 8, 4
        cons, vars_ = ref.random_instance(n, d, 0.8, 0.6, seed)
        out = np.array(revise.revise(jnp.array(cons), jnp.array(vars_), block_x=4))
        assert np.all(out <= vars_)
        assert set(np.unique(out)).issubset({0.0, 1.0})


class TestVmemModel:
    def test_vmem_within_budget_for_all_buckets(self):
        # DESIGN.md §8: every compiled bucket must fit TPU VMEM (16 MiB),
        # including at the perf-pass block policy (bx = whole bucket).
        from compile import aot

        for (n, d) in aot.BUCKETS:
            bx = revise.pick_block_x(n, d)
            assert revise.vmem_bytes(n, d, bx) < 16 * 2**20, (n, d, bx)

    def test_vmem_scales_linearly_in_block(self):
        a = revise.vmem_bytes(64, 16, block_x=4)
        b = revise.vmem_bytes(64, 16, block_x=8)
        assert a < b <= 2 * a

    def test_pick_block_x_takes_whole_bucket_when_it_fits(self):
        # §Perf L1: single grid program unless VMEM would overflow.
        for (n, d) in [(8, 4), (16, 8), (32, 8), (64, 16)]:
            assert revise.pick_block_x(n, d) == n

    def test_pick_block_x_halves_under_tight_budget(self):
        bx = revise.pick_block_x(64, 16, vmem_budget=2 * 2**20)
        assert bx < 64
        assert 64 % bx == 0
        assert revise.vmem_bytes(64, 16, bx) <= 2 * 2**20
        # pathological budget still returns a legal tile
        assert revise.pick_block_x(64, 16, vmem_budget=1) == 1

    def test_full_bucket_block_matches_ref(self):
        # correctness of the perf-pass configuration specifically
        n, d = 16, 8
        cons, vars_ = ref.random_instance(n, d, 0.9, 0.5, 123)
        got = revise.revise(jnp.array(cons), jnp.array(vars_),
                            block_x=revise.pick_block_x(n, d))
        assert_allclose(np.array(got), np.array(ref.revise_ref(
            jnp.array(cons), jnp.array(vars_))))
