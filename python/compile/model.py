"""Layer-2 JAX model: the full Recurrent Arc Consistency (RAC) fixpoint.

Wraps the Layer-1 Pallas revise kernel (``kernels/revise.py``) in a
``jax.lax.while_loop`` implementing Eq. 1 of the paper:

    D~(0) = ∅
    D~(k) = D~(k-1) ∪ { (x,a) | ∃y, c_xy|(x,a) ⊆ D~(k-1) }

iterated until the removed-set stops growing (fixpoint == the AC closure,
paper Prop. 1) or some domain is wiped out (inconsistency, early abort).

Entry points, all with static shapes so they can be AOT-lowered to single
HLO executables (no host round-trip inside the loop):

  rtac_step(cons, vars)        -> vars'                  one sweep
  rtac_fixpoint(cons, vars)    -> (vars*, iters, status) full enforcement,
                                  early-aborts on wipeout (paper's "throw
                                  inconsistency"); iters == #Recurrence
  rtac_fixpoint_batched(cons, vars[B])
                               -> (vars*[B], iters, status[B])
  rtac_fixpoint_incremental(cons, vars)
                               -> (vars*, iters, status)  Prop.-2 ablation

The batched variant runs B independent domain planes against ONE shared
constraint tensor — the coordinator uses it to fuse AC requests from
parallel search workers exploring different branches of the same CSP
(DESIGN.md §3).  It runs to the *joint* fixpoint (a wiped plane must not
abort its batch-mates), so its ``iters`` is a joint sweep count, not the
per-request #Recurrence.

Status codes (i32): 0 = CONSISTENT, 1 = WIPEOUT (some domain emptied).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import revise as revise_kernel

STATUS_CONSISTENT = 0
STATUS_WIPEOUT = 1

# Safety cap only: the loop exits on fixpoint (paper measures ~3.4-4.8
# sweeps); the theoretical max is n*d+1 sweeps (>=1 removal per sweep).
MAX_ITERS = 4096


def rtac_step(cons: jnp.ndarray, vars_: jnp.ndarray, *, block_x: int = 8):
    """One dense revise sweep (Layer-1 kernel pass-through)."""
    return revise_kernel.revise(cons, vars_, block_x=block_x)


def _wiped_plane(v):  # f32[n,d] -> bool
    return jnp.any(jnp.sum(v, axis=1) == 0.0)


@functools.partial(jax.jit, static_argnames=("block_x",))
def rtac_fixpoint(cons: jnp.ndarray, vars_: jnp.ndarray, *, block_x: int = 8):
    """Full RAC enforcement of a single domain plane.

    Returns (vars_out f32[n,d], iters i32, status i32).  ``iters`` counts
    executed sweeps per DESIGN.md §7 (the paper's ``while n_idx != 0``
    trip count); on WIPEOUT the loop aborts immediately, mirroring the
    paper's ``throw inconsistency``.
    """

    def body(carry):
        v, it, _changed = carry
        nv = revise_kernel.revise(cons, v, block_x=block_x)
        return nv, it + 1, jnp.any(nv != v)

    def cond(carry):
        v, it, changed = carry
        return changed & (~_wiped_plane(v)) & (it < MAX_ITERS)

    v0 = vars_.astype(jnp.float32)
    vout, iters, _ = jax.lax.while_loop(
        cond, body, (v0, jnp.int32(0), jnp.bool_(True))
    )
    status = jnp.where(_wiped_plane(vout), STATUS_WIPEOUT, STATUS_CONSISTENT)
    return vout, iters, status.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_x",))
def rtac_fixpoint_batched(cons: jnp.ndarray, vars_: jnp.ndarray, *, block_x: int = 8):
    """Joint RAC enforcement of B domain planes sharing one ``cons``.

    Args:
      cons:  f32[n, n, d, d]
      vars_: f32[B, n, d]

    Returns (vars_out f32[B,n,d], iters i32, status i32[B]).  Runs until
    no plane changes; a revise sweep is idempotent on already-stable
    planes, so stragglers converge independently.  Wiped planes are frozen
    (their fixpoint is already decided) purely to keep removal sets
    deterministic for the bit-exact cross-engine tests.
    """
    B, n, d = vars_.shape

    def wiped(v):  # f32[B,n,d] -> bool[B]
        return jnp.any(jnp.sum(v, axis=2) == 0.0, axis=1)

    def body(carry):
        v, it, _changed = carry
        nv = jax.vmap(lambda p: revise_kernel.revise(cons, p, block_x=block_x))(v)
        freeze = wiped(v)[:, None, None]
        nv = jnp.where(freeze, v, nv)
        return nv, it + 1, jnp.any(nv != v)

    def cond(carry):
        _v, it, changed = carry
        return changed & (it < MAX_ITERS)

    v0 = vars_.astype(jnp.float32)
    vout, iters, _ = jax.lax.while_loop(
        cond, body, (v0, jnp.int32(0), jnp.bool_(True))
    )
    status = jnp.where(wiped(vout), STATUS_WIPEOUT, STATUS_CONSISTENT)
    return vout, iters, status.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_x",))
def rtac_fixpoint_incremental(cons: jnp.ndarray, vars_: jnp.ndarray, *, block_x: int = 8):
    """Prop.-2 incremental formulation, static-shape edition (ablation).

    The paper's Listing 1.1 exploits Prop. 2 by *gathering* the changed
    columns (dynamic shapes).  The static-shape equivalent maintains the
    support-count tensor and updates it with the *delta* of removed
    values:

        supp[x,y,a] -= sum_b Cons[x,y,a,b] * removed[y,b]

    Each sweep costs one einsum either way on dense hardware, but replaces
    the full recount with a subtraction and avoids re-deriving ``ok`` from
    scratch; EXPERIMENTS.md quantifies whether XLA cares.  Semantics are
    identical to ``rtac_fixpoint`` (same iters, same closure) — asserted
    in the pytest suite.
    """
    n, d = vars_.shape
    v0 = vars_.astype(jnp.float32)
    supp0 = jnp.einsum("xyab,yb->xya", cons, v0)

    def prune(v, supp):
        ok = jnp.min(jnp.where(supp > 0.0, 1.0, 0.0), axis=1)
        return v * ok

    def body(carry):
        v, supp, it, _changed = carry
        nv = prune(v, supp)
        removed = v - nv
        nsupp = supp - jnp.einsum("xyab,yb->xya", cons, removed)
        return nv, nsupp, it + 1, jnp.any(nv != v)

    def cond(carry):
        v, _supp, it, changed = carry
        return changed & (~_wiped_plane(v)) & (it < MAX_ITERS)

    vout, _, iters, _ = jax.lax.while_loop(
        cond, body, (v0, supp0, jnp.int32(0), jnp.bool_(True))
    )
    status = jnp.where(_wiped_plane(vout), STATUS_WIPEOUT, STATUS_CONSISTENT)
    return vout, iters, status.astype(jnp.int32)
