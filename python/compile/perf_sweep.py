"""L1 perf tooling: block-shape sweep for the Pallas revise kernel.

Regenerates the EXPERIMENTS.md §Perf L1 table: wallclock per jitted call
(CPU, interpret-mode — optimise *structure*, per DESIGN.md §8) plus the
analytic VMEM footprint that gates TPU validity of each block shape.

Usage:  cd python && python -m compile.perf_sweep [--n 64 --d 16]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref, revise


def time_call(f, *args, iters: int = 15) -> float:
    """Mean wallclock per call in µs, after one warmup compile+run."""
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def sweep(n: int, d: int, density: float, tightness: float, seed: int) -> None:
    cons_np, vars_np = ref.random_instance(n, d, density, tightness, seed)
    cons, vars_ = jnp.array(cons_np), jnp.array(vars_np)

    print(f"# block_x sweep on ({n}, {d}) bucket  density={density} t={tightness}")
    print(f"{'bx':>4} {'step µs':>10} {'fixpoint µs':>12} {'VMEM MiB':>9} {'TPU-valid':>9}")
    bx = 1
    shapes = []
    while bx <= n:
        if n % bx == 0:
            shapes.append(bx)
        bx *= 2
    for bx in shapes:
        step = jax.jit(lambda c, v, bx=bx: revise.revise(c, v, block_x=bx))
        fix = jax.jit(lambda c, v, bx=bx: model.rtac_fixpoint(c, v, block_x=bx))
        vmem = revise.vmem_bytes(n, d, bx) / 2**20
        print(
            f"{bx:>4} {time_call(step, cons, vars_):>10.1f} "
            f"{time_call(fix, cons, vars_):>12.1f} {vmem:>9.2f} "
            f"{'yes' if vmem <= 12 else 'NO':>9}"
        )
    chosen = revise.pick_block_x(n, d)
    print(f"pick_block_x({n}, {d}) -> {chosen}")

    ref_us = time_call(jax.jit(ref.revise_ref), cons, vars_)
    print(f"pure-jnp einsum reference step: {ref_us:.1f} µs")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--density", type=float, default=0.8)
    ap.add_argument("--tightness", type=float, default=0.35)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    sweep(args.n, args.d, args.density, args.tightness, args.seed)


if __name__ == "__main__":
    main()
