"""AOT pipeline: lower the L2 model to HLO *text* artifacts for the Rust
runtime (Layer 3).

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Python runs ONCE here (``make artifacts``); the Rust binary is
self-contained afterwards.  Every artifact is compiled for a fixed shape
bucket (DESIGN.md §Hardware-Adaptation): the Rust router pads a request's
(n, d) up to the nearest bucket with universal relations / zero rows,
which is AC-neutral (tested on both sides of the boundary).

Emitted set (see BUCKETS / BATCHES below):
  artifacts/step_n{N}_d{D}.hlo.txt      one revise sweep
  artifacts/fix_n{N}_d{D}.hlo.txt       full fixpoint, B=1, wipeout abort
  artifacts/fixb{B}_n{N}_d{D}.hlo.txt   joint fixpoint over B planes
  artifacts/fixinc_n{N}_d{D}.hlo.txt    Prop.-2 incremental (ablation)
  artifacts/manifest.json               machine-readable index
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (n_vars, n_dom) shape buckets.  n is a multiple of the kernel x-tile.
BUCKETS = [(8, 4), (16, 8), (32, 8), (64, 16)]
# Batched-fixpoint sizes compiled per bucket (coordinator fuses up to
# max(BATCHES) requests per execution; it pads partial batches).
BATCHES = [4, 8]
# Incremental ablation bucket (one is enough for the ablation bench).
INC_BUCKETS = [(16, 8), (32, 8)]

# Kernel x-tile: one grid program per bucket unless VMEM would overflow
# (perf sweep: 12.6x over the old fixed bx=8 on the 64x16 bucket; see
# EXPERIMENTS.md §Perf).  `BLOCK_X` kept as the fallback/reporting value.
BLOCK_X = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side can always unwrap a tuple, regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries():
    """Yield (name, lowered, meta) for every artifact."""
    from compile.kernels.revise import pick_block_x

    for (n, d) in BUCKETS:
        bx = pick_block_x(n, d)
        cons = _spec((n, n, d, d))
        plane = _spec((n, d))

        name = f"step_n{n}_d{d}"
        low = jax.jit(lambda c, v: (model.rtac_step(c, v, block_x=bx),)).lower(cons, plane)
        yield name, low, dict(kind="step", n=n, d=d, batch=1, outputs=["vars"])

        name = f"fix_n{n}_d{d}"
        low = jax.jit(lambda c, v: model.rtac_fixpoint(c, v, block_x=bx)).lower(cons, plane)
        yield name, low, dict(kind="fixpoint", n=n, d=d, batch=1,
                              outputs=["vars", "iters", "status"])

        for b in BATCHES:
            name = f"fixb{b}_n{n}_d{d}"
            low = jax.jit(
                lambda c, v: model.rtac_fixpoint_batched(c, v, block_x=bx)
            ).lower(cons, _spec((b, n, d)))
            yield name, low, dict(kind="fixpoint_batched", n=n, d=d, batch=b,
                                  outputs=["vars", "iters", "status"])

    from compile.kernels.revise import pick_block_x

    for (n, d) in INC_BUCKETS:
        bx = pick_block_x(n, d)
        name = f"fixinc_n{n}_d{d}"
        low = jax.jit(
            lambda c, v: model.rtac_fixpoint_incremental(c, v, block_x=bx)
        ).lower(_spec((n, n, d, d)), _spec((n, d)))
        yield name, low, dict(kind="fixpoint_incremental", n=n, d=d, batch=1,
                              outputs=["vars", "iters", "status"])


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory (default: ../artifacts)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": 1, "block_x": BLOCK_X, "entries": []}
    for name, lowered, meta in build_entries():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(
            name=name,
            file=f"{name}.hlo.txt",
            hlo_bytes=len(text),
            inputs=[
                dict(name="cons", shape=[meta["n"], meta["n"], meta["d"], meta["d"]],
                     dtype="f32"),
                dict(name="vars",
                     shape=([meta["batch"], meta["n"], meta["d"]]
                            if meta["kind"] == "fixpoint_batched"
                            else [meta["n"], meta["d"]]),
                     dtype="f32"),
            ],
            **meta,
        )
        manifest["entries"].append(entry)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path} ({len(manifest['entries'])} entries)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
