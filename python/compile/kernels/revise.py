"""Layer-1 Pallas kernel: one dense RTAC revise sweep.

The hot spot of each recurrence iteration (paper Fig. 2 / Algorithm 1,
``tensorRevise``) is the support-count contraction

    supp[x, y, a] = sum_b Cons[x, y, a, b] * Vars[y, b]

followed by an all-reduce over the neighbour axis and a masked write-back.
On the paper's hardware (RTX3090 + PyTorch) this is a cuBLAS batched GEMM
over a *gathered* ``changed_idx`` slab.  Here we re-express it for the
TPU-style memory hierarchy (DESIGN.md §Hardware-Adaptation):

* the grid tiles the x axis; each program streams its ``(bx, n, d, d)``
  constraint slab HBM→VMEM via the BlockSpec index_map while the full
  ``(n, d)`` Vars plane stays VMEM-resident (it is tiny);
* the contraction is expressed as a ``dot_general`` on the last axis so
  XLA maps it to the MXU when d is large and to the VPU otherwise;
* the dynamic ``changed_idx`` gather of the paper's Listing 1.1 is
  replaced by a dense masked sweep — every shape is static, which is what
  makes ahead-of-time lowering (and TPU tiling) possible.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs anywhere.  Correctness is pinned to ``ref.revise_ref`` by
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _revise_kernel(cons_ref, vars_full_ref, vars_tile_ref, out_ref):
    """One x-tile of the revise sweep.

    Block shapes:
      cons_ref      : (bx, n, d, d)  — this tile's constraint slab
      vars_full_ref : (n, d)         — the whole Vars plane (the "y" side)
      vars_tile_ref : (bx, d)        — this tile's Vars rows (the "x" side)
      out_ref       : (bx, d)
    """
    cons = cons_ref[...]          # (bx, n, d, d)
    vy = vars_full_ref[...]       # (n, d)

    # supp[t, y, a] = sum_b cons[t, y, a, b] * vy[y, b]
    # dot_general: contract cons dim 3 with vy dim 1, batch cons dim 1 / vy dim 0.
    supp = jax.lax.dot_general(
        cons,
        vy,
        dimension_numbers=(((3,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )
    # dot_general output layout: (batch, lhs-free..., rhs-free...) = (n, bx, d)
    has = jnp.where(supp > 0.0, 1.0, 0.0)
    ok = jnp.min(has, axis=0)     # all over y -> (bx, d)
    out_ref[...] = vars_tile_ref[...] * ok


@functools.partial(jax.jit, static_argnames=("block_x",))
def revise(cons: jnp.ndarray, vars_: jnp.ndarray, *, block_x: int = 8) -> jnp.ndarray:
    """One dense revise sweep via the Pallas kernel.

    Args:
      cons: f32[n, n, d, d] constraint tensor (universal rows where no
        constraint exists — see ``ref.py`` for the encoding contract).
      vars_: f32[n, d] 0/1 domain plane.
      block_x: x-tile height; must divide n (shape buckets guarantee this).

    Returns f32[n, d]: the plane after one sweep (values that lost all
    supports on some constraint are zeroed).
    """
    n, d = vars_.shape
    assert cons.shape == (n, n, d, d), (cons.shape, vars_.shape)
    bx = min(block_x, n)
    assert n % bx == 0, f"block_x {bx} must divide n {n}"

    return pl.pallas_call(
        _revise_kernel,
        grid=(n // bx,),
        in_specs=[
            pl.BlockSpec((bx, n, d, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((bx, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bx, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cons, vars_, vars_)


def pick_block_x(n: int, d: int, vmem_budget: int = 12 * 2**20) -> int:
    """Largest x-tile whose VMEM footprint fits the budget (§Perf L1).

    Perf sweep on the (64,16) bucket (EXPERIMENTS.md §Perf): the
    interpret-mode grid loop dominates at small tiles — bx=8 ran 11.3ms,
    bx=64 (single program) 0.90ms, a 12.6x win — and analytically the
    whole constraint slab fits VMEM for every compiled bucket, so the
    policy is simply "one program unless the slab would blow VMEM", which
    also matches the TPU story: stream x-tiles only when you must.
    """
    bx = n
    while bx > 1 and vmem_bytes(n, d, bx) > vmem_budget:
        # halve until it fits; n is a power of two for all buckets
        bx //= 2
    return max(bx, 1)


def vmem_bytes(n: int, d: int, block_x: int = 8) -> int:
    """Analytic VMEM footprint of one kernel program (DESIGN.md §8 L1).

    cons tile + vars plane + vars tile + out tile + supp scratch, f32.
    """
    bx = min(block_x, n)
    cons_tile = bx * n * d * d
    vars_plane = n * d
    tiles = 2 * bx * d
    supp = n * bx * d
    return 4 * (cons_tile + vars_plane + tiles + supp)
