"""Pure-jnp / pure-python correctness oracles for the RTAC kernels.

Three independent references, from "closest to the kernel" to "closest to
the textbook definition":

1. ``revise_ref``          — one dense revise sweep, plain jnp (no Pallas).
2. ``fixpoint_ref``        — python-loop fixpoint over ``revise_ref``.
3. ``ac3_closure``         — a classic queue-based AC-3 on python sets.

The pytest suite asserts: Pallas kernel == (1), JAX while_loop model == (2),
and both == (3) on random instances.  The AC closure of a CSP is unique
(paper Prop. 1), so all engines must agree bit-for-bit on the 0/1 grid.

Encoding (shared with the Rust native engine and the AOT artifacts):
  Vars : f32[n, d]        Vars[x, a] = 1.0  iff value a is in dom(x)
  Cons : f32[n, n, d, d]  Cons[x, y, a, b] = 1.0 iff (a, b) allowed by
                          c_xy; pairs (x, y) with *no* constraint hold the
                          universal (all-ones) relation, which is
                          AC-neutral; the diagonal Cons[x, x] is universal
                          as well.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def revise_ref(cons: jnp.ndarray, vars_: jnp.ndarray) -> jnp.ndarray:
    """One dense revise sweep (paper Fig. 2 steps 1-3), plain jnp.

    supp[x, y, a] = sum_b Cons[x, y, a, b] * Vars[y, b]
    ok[x, a]      = all_y (supp[x, y, a] > 0)
    out[x, a]     = Vars[x, a] * ok[x, a]
    """
    supp = jnp.einsum("xyab,yb->xya", cons, vars_)
    ok = jnp.min(jnp.where(supp > 0.0, 1.0, 0.0), axis=1)
    return vars_ * ok


def fixpoint_ref(cons, vars_, max_iters: int = 10_000):
    """Run ``revise_ref`` to the fixpoint with a host-side python loop.

    Returns (vars_out, n_sweeps, wiped) where ``n_sweeps`` counts executed
    sweeps (the paper's ``while n_idx != 0`` trip count) and ``wiped`` is
    True iff some variable's domain was annihilated (inconsistent CSP).
    Matches the #Recurrence semantics in DESIGN.md §7.
    """
    v = vars_
    sweeps = 0
    for _ in range(max_iters):
        nv = revise_ref(cons, v)
        sweeps += 1
        wiped = bool(jnp.any(jnp.sum(nv, axis=1) == 0.0))
        if wiped:
            return nv, sweeps, True
        if bool(jnp.all(nv == v)):
            return nv, sweeps, False
        v = nv
    raise RuntimeError("fixpoint_ref did not converge")


# ---------------------------------------------------------------------------
# Classic AC-3 on python data structures (textbook comparator).
# ---------------------------------------------------------------------------


def ac3_closure(cons: np.ndarray, vars_: np.ndarray):
    """Queue-based AC-3 over the same tensor encoding.

    Returns (vars_out, n_revisions, wiped).  Only (x, y) pairs whose
    relation is non-universal are treated as constraints (universal
    relations can never prune and correspond to "no constraint").
    """
    cons = np.asarray(cons)
    vars_ = np.asarray(vars_).copy()
    n, d = vars_.shape

    def is_edge(x, y):
        return x != y and not np.all(cons[x, y] == 1.0)

    edges = [(x, y) for x in range(n) for y in range(n) if is_edge(x, y)]
    queue = list(edges)
    in_queue = set(queue)
    revisions = 0

    while queue:
        x, y = queue.pop(0)
        in_queue.discard((x, y))
        revisions += 1
        changed = False
        for a in range(d):
            if vars_[x, a] == 0.0:
                continue
            # does (x,a) keep a support on c_xy?
            if not np.any(cons[x, y, a] * vars_[y]):
                vars_[x, a] = 0.0
                changed = True
        if changed:
            if not np.any(vars_[x]):
                return vars_, revisions, True
            for (z, w) in edges:
                if w == x and z != y and (z, w) not in in_queue:
                    queue.append((z, w))
                    in_queue.add((z, w))
    return vars_, revisions, False


# ---------------------------------------------------------------------------
# Random instance builder shared by the pytest suite.
# ---------------------------------------------------------------------------


def random_instance(n: int, d: int, density: float, tightness: float, seed: int):
    """Random binary CSP in tensor encoding (paper §5.2 model).

    Each of the n(n-1)/2 variable pairs gets a constraint with probability
    ``density``; a constrained pair forbids each value pair independently
    with probability ``tightness``.  Unconstrained pairs (and the diagonal)
    hold the universal relation.
    """
    rng = np.random.default_rng(seed)
    cons = np.ones((n, n, d, d), dtype=np.float32)
    for x in range(n):
        for y in range(x + 1, n):
            if rng.random() < density:
                allowed = (rng.random((d, d)) >= tightness).astype(np.float32)
                cons[x, y] = allowed
                cons[y, x] = allowed.T
    vars_ = np.ones((n, d), dtype=np.float32)
    return cons, vars_
