//! n-queens across every AC engine: same search, same answer, very
//! different work profiles — a miniature of the paper's Table 1 on a
//! structured instance.
//!
//! Run: `cargo run --release --example nqueens -- [N]`   (default 10)

use rtac::ac::{make_engine, ALL_ENGINES};
use rtac::gen::queens;
use rtac::search::{Solver, SolverConfig};
use rtac::util::table::{fnum, Table};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(10);
    let p = queens(n);
    println!("queens({n}): {} constraints, density {:.2}", p.n_constraints(), p.density());

    let mut t = Table::new(&[
        "engine", "result", "assignments", "ac ms/call", "revisions/call", "recurrences/call",
    ]);
    let mut solution: Option<Vec<usize>> = None;
    for name in ALL_ENGINES {
        let mut engine = make_engine(name).unwrap();
        let cfg = SolverConfig { record_ac_times: true, ..Default::default() };
        let mut solver = Solver::new(engine.as_mut(), cfg);
        let (result, stats) = solver.solve(&p);
        let verdict = match &result {
            rtac::search::SolveResult::Sat(sol) => {
                assert!(p.satisfies(sol), "{name} returned a bad solution");
                if let Some(prev) = &solution {
                    // engines may find different solutions; both valid
                    let _ = prev;
                }
                solution = Some(sol.clone());
                "SAT"
            }
            rtac::search::SolveResult::Unsat => "UNSAT",
            rtac::search::SolveResult::Limit => "LIMIT",
        };
        t.row(vec![
            name.to_string(),
            verdict.into(),
            stats.assignments.to_string(),
            format!("{:.4}", stats.mean_ac_ms()),
            fnum(stats.revisions_per_call()),
            fnum(stats.recurrences_per_call()),
        ]);
    }
    println!("{}", t.render());

    if let Some(sol) = solution {
        println!("one solution:");
        for row in 0..n {
            let line: String =
                (0..n).map(|col| if sol[col] == row { " Q" } else { " ." }).collect();
            println!("{line}");
        }
    }
}
