//! Sudoku as a 810-constraint binary CSP, solved by MAC.  Demonstrates
//! the parser-free given-handling path (`solve_with_assignments`) on a
//! classic instance plus a hard one.
//!
//! Run: `cargo run --release --example sudoku -- [GRID]`
//! where GRID is 81 chars of 1-9 or '.'; defaults to a textbook puzzle.

use rtac::ac::make_engine;
use rtac::gen::sudoku_from_givens;
use rtac::search::{SolveResult, Solver, SolverConfig};

const DEFAULT: &str = "\
53..7....\
6..195...\
.98....6.\
8...6...3\
4..8.3..1\
7...2...6\
.6....28.\
...419..5\
....8..79";

fn render(sol: &[usize]) -> String {
    let mut out = String::new();
    for r in 0..9 {
        if r % 3 == 0 && r > 0 {
            out.push_str("------+-------+------\n");
        }
        for c in 0..9 {
            if c % 3 == 0 && c > 0 {
                out.push_str("| ");
            }
            out.push_str(&format!("{} ", sol[r * 9 + c] + 1));
        }
        out.push('\n');
    }
    out
}

fn main() {
    let grid = std::env::args().nth(1).unwrap_or_else(|| DEFAULT.to_string());
    let (p, givens) = sudoku_from_givens(&grid).expect("valid 81-cell grid");
    println!("sudoku: {} givens, {} binary constraints", givens.len(), p.n_constraints());

    for engine_name in ["ac3bit", "rtac-inc"] {
        let mut engine = make_engine(engine_name).unwrap();
        let cfg = SolverConfig { record_ac_times: true, ..Default::default() };
        let mut solver = Solver::new(engine.as_mut(), cfg);
        let t = std::time::Instant::now();
        let (result, stats) = solver.solve_with_assignments(&p, &givens);
        match result {
            SolveResult::Sat(sol) => {
                assert!(p.satisfies(&sol));
                println!(
                    "{engine_name}: solved in {:?} ({} assignments, {:.4} ms/AC-call)",
                    t.elapsed(),
                    stats.assignments,
                    stats.mean_ac_ms()
                );
                if engine_name == "ac3bit" {
                    print!("{}", render(&sol));
                }
            }
            other => println!("{engine_name}: {other:?}"),
        }
    }
}
