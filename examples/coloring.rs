//! Graph colouring: probe the chromatic number of random G(n, p) graphs
//! by solving k-colouring for increasing k — each probe is a CSP solve,
//! so denser graphs exercise exactly the regime the paper's dense random
//! networks target.
//!
//! Run: `cargo run --release --example coloring -- [N] [EDGE_PROB]`

use rtac::ac::make_engine;
use rtac::gen::coloring::random_graph_coloring;
use rtac::search::{SolveResult, Solver, SolverConfig};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(20);
    let prob: f64 = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(0.4);
    let seed = 42;

    println!("random graph: {n} vertices, edge probability {prob}");
    let mut chromatic = None;
    for k in 2..=n {
        let p = random_graph_coloring(n, k, prob, seed);
        let mut engine = make_engine("rtac-inc").unwrap();
        let cfg = SolverConfig {
            max_assignments: 200_000,
            ..Default::default()
        };
        let mut solver = Solver::new(engine.as_mut(), cfg);
        let t = std::time::Instant::now();
        let (result, stats) = solver.solve(&p);
        match result {
            SolveResult::Sat(sol) => {
                assert!(p.satisfies(&sol));
                println!(
                    "k={k}: SAT in {:?} ({} assignments, {:.2} recurrences/call)",
                    t.elapsed(),
                    stats.assignments,
                    stats.recurrences_per_call()
                );
                chromatic = Some(k);
                break;
            }
            SolveResult::Unsat => {
                println!("k={k}: UNSAT in {:?} ({} assignments)", t.elapsed(), stats.assignments)
            }
            SolveResult::Limit => {
                println!("k={k}: inconclusive (budget)");
                break;
            }
        }
    }
    match chromatic {
        Some(k) => println!("chromatic number <= {k} (first SAT k; all smaller k refuted)"),
        None => println!("no colouring found within budget"),
    }
}
