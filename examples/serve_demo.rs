//! END-TO-END DRIVER (DESIGN.md §5 "end-to-end" row): the full
//! three-layer system on real workloads.
//!
//!   parallel MAC search workers (L3)
//!     → TensorEngine encode/submit (L3)
//!       → coordinator dynamic batcher (L3)
//!         → fused `fixpoint_batched` XLA executions (L2/L1 artifacts)
//!
//! Reports SAT/UNSAT correctness, enforcement throughput, latency
//! decomposition (queue vs execute), and batch occupancy for three
//! workloads; results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example serve_demo`

use std::time::Duration;

use rtac::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use rtac::core::Problem;
use rtac::gen::random::{random_csp, RandomSpec};
use rtac::gen::{pigeonhole, queens};
use rtac::search::parallel::solve_parallel;
use rtac::search::{SolveResult, SolverConfig};
use rtac::util::table::Table;

struct RunRow {
    workload: String,
    workers: usize,
    result: String,
    enforcements: u64,
    throughput: f64,
    mean_total_us: f64,
    mean_exec_us: f64,
    occupancy: f64,
}

fn drive(name: &str, p: &Problem, workers: usize, max_wait: Duration) -> RunRow {
    let coord = Coordinator::start(
        p,
        CoordinatorConfig {
            artifact_dir: rtac::runtime::default_artifact_dir(),
            policy: BatchPolicy { max_batch: 8, max_wait, adaptive: false, ..Default::default() },
        },
    )
    .expect("coordinator start (did you run `make artifacts`?)");
    // per-worker assignment budget keeps each workload bounded; deep
    // searches report LIMIT rather than running unbounded.
    let cfg = SolverConfig { max_assignments: 1_500, ..Default::default() };
    let t = std::time::Instant::now();
    let out = solve_parallel(p, &coord, &cfg, 0, workers).expect("parallel solve");
    let wall = t.elapsed().as_secs_f64();
    let result = match &out.result {
        SolveResult::Sat(sol) => {
            assert!(p.satisfies(sol), "{name}: bad solution");
            format!("SAT(w{})", out.winner.unwrap_or(99))
        }
        SolveResult::Unsat => "UNSAT".into(),
        SolveResult::Limit => "LIMIT".into(),
    };
    let m = coord.metrics().snapshot();
    assert_eq!(m.requests, m.responses, "{name}: lost requests");
    RunRow {
        workload: name.to_string(),
        workers,
        result,
        enforcements: m.responses,
        throughput: m.responses as f64 / wall,
        mean_total_us: m.mean_total_us,
        mean_exec_us: m.mean_exec_us,
        occupancy: m.mean_batch_occupancy,
    }
}

fn main() {
    let wait = Duration::from_micros(400);
    let runs = vec![
        drive("queens(8) k=1", &queens(8), 1, wait),
        drive("queens(8) k=4", &queens(8), 4, wait),
        drive("queens(8) k=8", &queens(8), 8, wait),
        drive("pigeonhole(5,4) k=4", &pigeonhole(5, 4), 4, wait),
        drive(
            "random(14,8,d=0.7) k=4",
            &random_csp(&RandomSpec::new(14, 8, 0.7, 0.45, 3)),
            4,
            wait,
        ),
        drive(
            "random(28,10,d=0.6) k=8",
            &random_csp(&RandomSpec::new(28, 10, 0.6, 0.35, 7)),
            8,
            wait,
        ),
    ];

    let mut t = Table::new(&[
        "workload", "workers", "result", "enforcements", "enf/s", "lat µs", "exec µs", "batch occ",
    ]);
    for r in &runs {
        t.row(vec![
            r.workload.clone(),
            r.workers.to_string(),
            r.result.clone(),
            r.enforcements.to_string(),
            format!("{:.0}", r.throughput),
            format!("{:.0}", r.mean_total_us),
            format!("{:.0}", r.mean_exec_us),
            format!("{:.2}", r.occupancy),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: exec µs is per fused batch; occupancy > 1 means worker AC calls \
         were coalesced into shared tensor executions."
    );
}
