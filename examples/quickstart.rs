//! Quickstart: build a CSP with the public API, enforce arc consistency
//! with both a sequential engine and the paper's recurrent engine, then
//! solve it with MAC search.
//!
//! Run: `cargo run --release --example quickstart`

use rtac::ac::{make_engine, Counters};
use rtac::core::{Problem, Relation, State};
use rtac::search::{Solver, SolverConfig};

fn main() {
    // A tiny scheduling-flavoured CSP: four tasks, five slots.
    //   t0 < t1, t1 != t2, |t2 - t3| >= 2, t3 != t0
    let d = 5;
    let mut p = Problem::new("quickstart", 4, d);
    p.add_constraint(0, 1, Relation::from_fn(d, d, |a, b| a < b));
    p.add_constraint(1, 2, Relation::from_fn(d, d, |a, b| a != b));
    p.add_constraint(2, 3, Relation::from_fn(d, d, |a, b| (a as i64 - b as i64).abs() >= 2));
    p.add_constraint(3, 0, Relation::from_fn(d, d, |a, b| a != b));
    p.validate().expect("well-formed problem");
    println!("problem: {} vars, {} constraints", p.n_vars(), p.n_constraints());

    // 1. Arc consistency with two engines — identical closures (Prop. 1).
    for engine_name in ["ac3", "rtac"] {
        let mut engine = make_engine(engine_name).unwrap();
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce(&p, &mut s, &[], &mut c);
        println!(
            "{engine_name:>6}: {out:?}; domains now {:?}; revisions={} recurrences={}",
            (0..4).map(|v| s.dom_size(v)).collect::<Vec<_>>(),
            c.revisions,
            c.recurrences,
        );
    }

    // 2. Full MAC search with the recurrent engine.
    let mut engine = make_engine("rtac-inc").unwrap();
    let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
    let (result, stats) = solver.solve(&p);
    println!("solve -> {result:?}");
    println!(
        "  assignments={} ac_calls={} recurrences/call={:.2}",
        stats.assignments,
        stats.ac_calls,
        stats.recurrences_per_call()
    );
    if let rtac::search::SolveResult::Sat(sol) = &result {
        assert!(p.satisfies(sol));
        println!("  verified: t0..t3 = {sol:?}");
    }
}
