//! `cargo bench` target for Fig. 3: running time (ms) of one assignment
//! in backtrack search across the n × density grid, native engines.
//! Scaled grid by default (RTAC_BENCH_FULL=1 for the paper's full grid —
//! hours).  Output mirrors the paper's figure as table rows.

use rtac::bench::{fig3, GridSpec};

fn main() {
    let full = std::env::var("RTAC_BENCH_FULL").ok().as_deref() == Some("1");
    let mut spec = if full { GridSpec::paper_full() } else { GridSpec::scaled() };
    if !full {
        // keep the default cargo-bench wall time reasonable
        spec.assignments = std::env::var("RTAC_BENCH_ASSIGNMENTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(150);
    }
    let engines = ["ac3", "ac3bit", "rtac", "rtac-inc"];
    eprintln!(
        "fig3: sizes={:?} densities={:?} dom={} tightness={} assignments={}",
        spec.sizes, spec.densities, spec.dom_size, spec.tightness, spec.assignments
    );
    let mut results = fig3::run(&spec, &engines);
    println!("{}", fig3::render(&results, &engines));

    // XLA series on the bucket-sized grid (skipped without artifacts)
    let dir = rtac::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() && !full {
        let mut xspec = GridSpec::xla();
        xspec.assignments = std::env::var("RTAC_BENCH_XLA_ASSIGNMENTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(40);
        eprintln!("fig3 XLA series: sizes={:?} dom={}", xspec.sizes, xspec.dom_size);
        match fig3::run_xla(&xspec, &dir) {
            Ok(xla) => {
                println!("{}", fig3::render(&xla, &["rtac-xla"]));
                results.extend(xla);
            }
            Err(e) => eprintln!("XLA series failed: {e:#}"),
        }
    }
    for claim in fig3::shape_claims(&results) {
        println!("{claim}");
    }
}
