//! `cargo bench` target for the ablations (DESIGN.md §5): queue
//! ordering, the sequential algorithm ladder, dense-vs-incremental RTAC,
//! and the tightness sweep.

use rtac::bench::ablations;

fn main() {
    let episodes = std::env::var("RTAC_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let spec = ablations::default_spec();
    eprintln!("ablations: workload {spec:?}, {episodes} episodes each");
    let (_, a) = ablations::queue_ordering(&spec, episodes);
    println!("{a}");
    let (_, b) = ablations::algorithm_ladder(&spec, episodes);
    println!("{b}");
    let (_, c) = ablations::rtac_incremental(&spec, episodes);
    println!("{c}");
    let (_, d) = ablations::tightness_sweep(&spec, episodes);
    println!("{d}");
}
