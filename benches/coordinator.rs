//! `cargo bench` target for the coordinator: batching-policy sweep under
//! parallel search load — throughput, latency, and batch occupancy as a
//! function of the coalescing window.  The L3 half of §Perf.
//! Self-skips without artifacts.

use std::time::Duration;

use rtac::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use rtac::gen::queens;
use rtac::search::parallel::solve_parallel;
use rtac::search::SolverConfig;
use rtac::util::table::Table;

fn main() {
    let dir = rtac::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("coordinator bench skipped: run `make artifacts` first");
        return;
    }
    let p = queens(8);
    let mut t = Table::new(&[
        "max_wait µs", "workers", "enforcements", "enf/s", "p-lat µs", "exec µs/batch", "occupancy",
    ]);
    for &workers in &[1usize, 4, 8] {
        for &wait_us in &[0u64, 200, 1000, 5000] {
            let coord = Coordinator::start(
                &p,
                CoordinatorConfig {
                    artifact_dir: dir.clone(),
                    policy: BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_micros(wait_us),
                        adaptive: false,
                        ..Default::default()
                    },
                },
            )
            .expect("start coordinator");
            let t0 = std::time::Instant::now();
            let out = solve_parallel(&p, &coord, &SolverConfig::default(), 0, workers)
                .expect("parallel solve");
            let wall = t0.elapsed().as_secs_f64();
            assert!(out.result.is_sat());
            let m = coord.metrics().snapshot();
            t.row(vec![
                wait_us.to_string(),
                workers.to_string(),
                m.responses.to_string(),
                format!("{:.0}", m.responses as f64 / wall),
                format!("{:.0}", m.mean_total_us),
                format!("{:.0}", m.mean_exec_us),
                format!("{:.2}", m.mean_batch_occupancy),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "reading: occupancy grows with the window and worker count; the \
         throughput-optimal window balances fusion against queue wait."
    );
}
