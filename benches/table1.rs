//! `cargo bench` target for Table 1: `#Revision` (AC-3) vs `#Recurrence`
//! (RTAC) per assignment across the grid, in the paper's exact column
//! format.  Scaled grid by default; RTAC_BENCH_FULL=1 for the paper's.

use rtac::bench::{table1, GridSpec};

fn main() {
    let full = std::env::var("RTAC_BENCH_FULL").ok().as_deref() == Some("1");
    let mut spec = if full { GridSpec::paper_full() } else { GridSpec::scaled() };
    if !full {
        spec.assignments = std::env::var("RTAC_BENCH_ASSIGNMENTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
    }
    eprintln!(
        "table1: sizes={:?} densities={:?} dom={} tightness={} assignments={}",
        spec.sizes, spec.densities, spec.dom_size, spec.tightness, spec.assignments
    );
    let rows = table1::run(&spec);
    println!("{}", table1::render(&rows));
    println!("{}", table1::verdict(&rows));
}
