//! `cargo bench` target for the native RTAC family: sequential dense vs
//! Prop.-2 incremental vs pooled parallel plane sweeps (and the
//! scoped-spawn baseline), on the scaled grid, plus the batched-SAC
//! comparison cell.  Writes `BENCH_rtac.json` next to the working
//! directory (set `RTAC_BENCH_JSON` to move it, empty to disable).

use rtac::bench::rtac_bench;

fn main() {
    let spec = rtac_bench::default_spec();
    eprintln!(
        "rtac family: sizes={:?} densities={:?} dom={} tightness={} assignments={}",
        spec.sizes, spec.densities, spec.dom_size, spec.tightness, spec.assignments
    );
    let results = rtac_bench::run(&spec, rtac_bench::ENGINES);
    println!("{}", rtac_bench::render(&results, rtac_bench::ENGINES));
    // the SAC comparison cells: artifact-gated ones are explicitly
    // marked skipped instead of silently omitted
    let cells = rtac_bench::run_sac_cells(&spec, 4);
    println!("{}", rtac_bench::render_cells(&cells));

    let path = std::env::var("RTAC_BENCH_JSON").unwrap_or_else(|_| "BENCH_rtac.json".to_string());
    if !path.is_empty() {
        let json = rtac_bench::to_json(&spec, &results, &cells);
        match std::fs::write(&path, json.to_string()) {
            Ok(()) => eprintln!("wrote {path}"),
            Err(e) => eprintln!("writing {path}: {e}"),
        }
    }
}
