//! `cargo bench` target for the native RTAC family: sequential dense vs
//! Prop.-2 incremental vs pooled parallel plane sweeps (and the
//! scoped-spawn baseline), on the scaled grid, plus the batched-SAC
//! comparison cell.  Writes `BENCH_rtac.json` next to the working
//! directory (set `RTAC_BENCH_JSON` to move it, empty to disable).

use rtac::bench::rtac_bench;

fn main() {
    let spec = rtac_bench::default_spec();
    eprintln!(
        "rtac family: sizes={:?} densities={:?} dom={} tightness={} assignments={}",
        spec.sizes, spec.densities, spec.dom_size, spec.tightness, spec.assignments
    );
    let results = rtac_bench::run(&spec, rtac_bench::ENGINES);
    println!("{}", rtac_bench::render(&results, rtac_bench::ENGINES));
    let sac = rtac_bench::sac_probe_comparison(&spec, 4);
    if let Some(c) = &sac {
        println!("{}", rtac_bench::render_sac(c));
    }
    // tensor-routed cell: self-skips without compiled artifacts
    let sac_xla = rtac_bench::sac_xla_comparison(&spec, 4);
    if let Some(c) = &sac_xla {
        println!("{}", rtac_bench::render_sac_xla(c));
    }

    let path = std::env::var("RTAC_BENCH_JSON").unwrap_or_else(|_| "BENCH_rtac.json".to_string());
    if !path.is_empty() {
        let json = rtac_bench::to_json(&spec, &results, sac.as_ref(), sac_xla.as_ref());
        match std::fs::write(&path, json.to_string()) {
            Ok(()) => eprintln!("wrote {path}"),
            Err(e) => eprintln!("writing {path}: {e}"),
        }
    }
}
