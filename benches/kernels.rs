//! `cargo bench` target for the XLA executable latencies: per-bucket,
//! per-batch fixpoint timings plus the step kernel — the L1/L2 half of
//! the §Perf profile (the numbers that stand in for the paper's GPU
//! kernel timings on this CPU-PJRT testbed).  The XLA section
//! self-skips without artifacts; the native SIMD word-kernel section
//! always runs.

use std::hint::black_box;

use rtac::bench::{bench, bench_batch, BenchConfig};
use rtac::core::State;
use rtac::gen::random::{random_csp, RandomSpec};
use rtac::runtime::{encode_cons, encode_vars, Bucket, Kind, Runtime};
use rtac::util::bitset::{tail_mask, words_for};
use rtac::util::simd::{self, isa_name, Isa};

/// Microbench the three word kernels on the densest-grid-cell shapes
/// (`bench::rtac_bench::default_spec()`: n=200, density 1.0), scalar
/// oracle vs runtime dispatch.  No artifacts needed — this is the
/// native half of the kernel profile.
fn simd_kernel_benches(cfg: &BenchConfig) {
    let spec = rtac::bench::rtac_bench::default_spec();
    let n = spec.sizes.iter().copied().max().unwrap_or(200);
    let density = spec
        .densities
        .iter()
        .copied()
        .fold(f64::NEG_INFINITY, f64::max);
    let dom = spec.dom_size;
    let p = random_csp(&RandomSpec::new(n, dom, density, spec.tightness, spec.seed));
    let isa = simd::active_isa();
    eprintln!(
        "simd kernels on densest cell shapes (n={n}, density={density:.2}, dom={dom}); \
         dispatching to {}",
        isa_name(isa)
    );

    // supported_mask: one revise window's support intersection — the
    // packed rows of a real arc against a fully-alive domain run
    let arc = (0..p.n_vars())
        .find_map(|x| p.arcs_of(x).first().copied())
        .expect("dense cell has arcs");
    let (rows, rw) = p.arc_support_rows(arc);
    let n_rows = dom.min(64);
    let window = &rows[..n_rows * rw];
    let mut domv = vec![!0u64; rw];
    domv[rw - 1] &= tail_mask(dom);
    let mask = tail_mask(n_rows);
    const INNER: usize = 1024;
    for (leg, leg_isa) in [("scalar", Isa::Scalar), ("dispatched", isa)] {
        let m = bench_batch(&format!("simd supported_mask {leg}"), cfg, INNER, || {
            for _ in 0..INNER {
                black_box(simd::supported_mask(
                    leg_isa,
                    black_box(mask),
                    black_box(window),
                    rw,
                    black_box(&domv),
                ));
            }
        });
        println!("{}", m.line());
    }

    // row_delta + zero/or: whole-plane shapes (the barrier merge and
    // trail replay paths walk one word per variable window)
    let plane_words = n * words_for(dom);
    let cur: Vec<u64> = (0..plane_words as u64).map(|i| !0u64 >> (i % 17)).collect();
    let mut next = cur.clone();
    for w in next.iter_mut().skip(3).step_by(7) {
        *w &= 0x5555_5555_5555_5555;
    }
    let mut dst = vec![0u64; words_for(n)];
    let src: Vec<u64> = (0..words_for(n) as u64).map(|i| i.wrapping_mul(0x9E37)).collect();
    for (leg, leg_isa) in [("scalar", Isa::Scalar), ("dispatched", isa)] {
        let m = bench(&format!("simd row_delta {leg} ({plane_words} words)"), cfg, || {
            black_box(simd::row_delta(leg_isa, black_box(&cur), black_box(&next)));
        });
        println!("{}", m.line());
        let m = bench_batch(&format!("simd zero+or {leg}"), cfg, INNER, || {
            for _ in 0..INNER {
                simd::zero_words(leg_isa, black_box(&mut dst));
                simd::or_words(leg_isa, black_box(&mut dst), black_box(&src));
            }
        });
        println!("{}", m.line());
    }
}

fn main() {
    let cfg = BenchConfig { warmup: 3, samples: 30, max_time: std::time::Duration::from_secs(5) };
    simd_kernel_benches(&cfg);

    let dir = rtac::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("kernels bench skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir).expect("load artifacts");
    eprintln!("platform: {}; artifacts: {:?}", rt.platform(), rt.loaded_names());

    for (n, d) in rt.manifest().buckets(Kind::Fixpoint) {
        let bucket = Bucket { n, d };
        // a dense instance filling ~80% of the bucket
        let p = random_csp(&RandomSpec::new(
            (n * 4 / 5).max(2),
            d.min(((d * 4) / 5).max(2)),
            0.8,
            0.35,
            7,
        ));
        let cons = encode_cons(&p, bucket).unwrap();
        let mut s = State::new(&p);
        s.assign(0, 0);
        let vars = encode_vars(&p, &s, bucket).unwrap();

        let name = format!("step_n{n}_d{d}");
        let m = bench(&format!("xla {name}"), &cfg, || {
            rt.run_step(&name, &cons, &vars).unwrap();
        });
        println!("{}", m.line());

        let name = format!("fix_n{n}_d{d}");
        let m = bench(&format!("xla {name} (cons upload/call)"), &cfg, || {
            rt.run_fixpoint(&name, &cons, &vars).unwrap();
        });
        println!("{}", m.line());

        // §Perf L3: device-resident constraint tensor (upload once)
        let cons_dev = rt.upload(&cons, &[bucket.n, bucket.n, bucket.d, bucket.d]).unwrap();
        let m = bench(&format!("xla {name} (cons resident)"), &cfg, || {
            rt.run_fixpoint_dev(&name, &cons_dev, &vars).unwrap();
        });
        println!("{}", m.line());

        // §Perf L2 round-trip ablation: Rust-driven loop over the step
        // artifact vs the fused while_loop executable.
        let step_name = format!("step_n{n}_d{d}");
        let m = bench(&format!("xla fixpoint stepwise n{n} d{d}"), &cfg, || {
            rt.run_fixpoint_stepwise(&step_name, &cons, &vars).unwrap();
        });
        println!("{}", m.line());

        for b in rt.manifest().batch_sizes() {
            let name = format!("fixb{b}_n{n}_d{d}");
            let mut batch = Vec::new();
            for _ in 0..b {
                batch.extend_from_slice(&vars);
            }
            let m = bench(&format!("xla {name} (per-plane)"), &cfg, || {
                rt.run_fixpoint(&name, &cons, &batch).unwrap();
            });
            // report per-plane amortised time too
            println!(
                "{}   => {:.2}µs/plane",
                m.line(),
                m.summary.mean / b as f64
            );
        }
    }

    // native engine on identical instances, for the CPU-vs-XLA overhead
    // comparison quoted in EXPERIMENTS.md §Perf.
    for (n, d) in rt.manifest().buckets(Kind::Fixpoint) {
        let p = random_csp(&RandomSpec::new(
            (n * 4 / 5).max(2),
            d.min(((d * 4) / 5).max(2)),
            0.8,
            0.35,
            7,
        ));
        let m = bench(&format!("native rtac-inc n{n} d{d}"), &cfg, || {
            let mut engine = rtac::ac::rtac::RtacNative::incremental();
            let mut s = State::new(&p);
            s.assign(0, 0);
            let mut c = rtac::ac::Counters::default();
            use rtac::ac::Propagator;
            let _ = engine.enforce(&p, &mut s, &[], &mut c);
        });
        println!("{}", m.line());
    }
}
