//! `cargo bench` target for the XLA executable latencies: per-bucket,
//! per-batch fixpoint timings plus the step kernel — the L1/L2 half of
//! the §Perf profile (the numbers that stand in for the paper's GPU
//! kernel timings on this CPU-PJRT testbed).  Self-skips without
//! artifacts.

use rtac::bench::{bench, BenchConfig};
use rtac::core::State;
use rtac::gen::random::{random_csp, RandomSpec};
use rtac::runtime::{encode_cons, encode_vars, Bucket, Kind, Runtime};

fn main() {
    let dir = rtac::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("kernels bench skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir).expect("load artifacts");
    eprintln!("platform: {}; artifacts: {:?}", rt.platform(), rt.loaded_names());
    let cfg = BenchConfig { warmup: 3, samples: 30, max_time: std::time::Duration::from_secs(5) };

    for (n, d) in rt.manifest().buckets(Kind::Fixpoint) {
        let bucket = Bucket { n, d };
        // a dense instance filling ~80% of the bucket
        let p = random_csp(&RandomSpec::new(
            (n * 4 / 5).max(2),
            d.min(((d * 4) / 5).max(2)),
            0.8,
            0.35,
            7,
        ));
        let cons = encode_cons(&p, bucket).unwrap();
        let mut s = State::new(&p);
        s.assign(0, 0);
        let vars = encode_vars(&p, &s, bucket).unwrap();

        let name = format!("step_n{n}_d{d}");
        let m = bench(&format!("xla {name}"), &cfg, || {
            rt.run_step(&name, &cons, &vars).unwrap();
        });
        println!("{}", m.line());

        let name = format!("fix_n{n}_d{d}");
        let m = bench(&format!("xla {name} (cons upload/call)"), &cfg, || {
            rt.run_fixpoint(&name, &cons, &vars).unwrap();
        });
        println!("{}", m.line());

        // §Perf L3: device-resident constraint tensor (upload once)
        let cons_dev = rt.upload(&cons, &[bucket.n, bucket.n, bucket.d, bucket.d]).unwrap();
        let m = bench(&format!("xla {name} (cons resident)"), &cfg, || {
            rt.run_fixpoint_dev(&name, &cons_dev, &vars).unwrap();
        });
        println!("{}", m.line());

        // §Perf L2 round-trip ablation: Rust-driven loop over the step
        // artifact vs the fused while_loop executable.
        let step_name = format!("step_n{n}_d{d}");
        let m = bench(&format!("xla fixpoint stepwise n{n} d{d}"), &cfg, || {
            rt.run_fixpoint_stepwise(&step_name, &cons, &vars).unwrap();
        });
        println!("{}", m.line());

        for b in rt.manifest().batch_sizes() {
            let name = format!("fixb{b}_n{n}_d{d}");
            let mut batch = Vec::new();
            for _ in 0..b {
                batch.extend_from_slice(&vars);
            }
            let m = bench(&format!("xla {name} (per-plane)"), &cfg, || {
                rt.run_fixpoint(&name, &cons, &batch).unwrap();
            });
            // report per-plane amortised time too
            println!(
                "{}   => {:.2}µs/plane",
                m.line(),
                m.summary.mean / b as f64
            );
        }
    }

    // native engine on identical instances, for the CPU-vs-XLA overhead
    // comparison quoted in EXPERIMENTS.md §Perf.
    for (n, d) in rt.manifest().buckets(Kind::Fixpoint) {
        let p = random_csp(&RandomSpec::new(
            (n * 4 / 5).max(2),
            d.min(((d * 4) / 5).max(2)),
            0.8,
            0.35,
            7,
        ));
        let m = bench(&format!("native rtac-inc n{n} d{d}"), &cfg, || {
            let mut engine = rtac::ac::rtac::RtacNative::incremental();
            let mut s = State::new(&p);
            s.assign(0, 0);
            let mut c = rtac::ac::Counters::default();
            use rtac::ac::Propagator;
            let _ = engine.enforce(&p, &mut s, &[], &mut c);
        });
        println!("{}", m.line());
    }
}
