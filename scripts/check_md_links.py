#!/usr/bin/env python3
"""Fail on broken relative links in the repo's Markdown files.

The CI `docs` job runs this so README/ARCHITECTURE/docs/* can't rot
silently: every inline Markdown link `[text](target)` whose target is a
relative path must resolve to an existing file or directory.  External
links (http/https/mailto), pure anchors (`#...`), and absolute paths
are skipped — this is a filesystem check, not a crawler.

Usage: python3 scripts/check_md_links.py [repo_root]
Exit status: 0 when every relative link resolves, 1 otherwise (broken
links are listed as `file:line: target`).
"""

import os
import re
import sys

# inline links only, [text](target "optional title"); reference-style
# definitions are rare here and would need a second pass
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_DIRS = {".git", "target", "vendor", "node_modules", ".venv"}
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_file(path, root):
    broken = []
    with open(path, encoding="utf-8") as fh:
        in_code_fence = False
        for lineno, line in enumerate(fh, 1):
            if line.lstrip().startswith("```"):
                in_code_fence = not in_code_fence
                continue
            if in_code_fence:
                continue
            for match in LINK.finditer(line):
                target = match.group(1)
                if target.startswith(SKIP_PREFIXES) or os.path.isabs(target):
                    continue
                # drop any #anchor; an empty remainder means same-file
                target_path = target.split("#", 1)[0]
                if not target_path:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), target_path)
                )
                if not os.path.exists(resolved):
                    rel = os.path.relpath(path, root)
                    broken.append(f"{rel}:{lineno}: {target}")
    return broken


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    broken = []
    checked = 0
    for path in sorted(md_files(root)):
        checked += 1
        broken.extend(check_file(path, root))
    if broken:
        print(f"broken relative links ({len(broken)}):")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"ok: all relative links resolve across {checked} Markdown file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
