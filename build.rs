// Probe the compiler version to decide whether the AVX-512 kernel path in
// `util/simd.rs` can be compiled at all.  The AVX-512 intrinsics and the
// corresponding `is_x86_feature_detected!` tokens were stabilized in Rust
// 1.89; this crate's MSRV is older, so the AVX-512 arm is gated behind a
// `rtac_avx512` cfg that only appears on new-enough compilers.  Runtime
// dispatch still decides per-process whether the CPU actually has AVX-512.

use std::process::Command;

fn rustc_minor() -> Option<(u32, u32)> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (…)" — take the second whitespace token.
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(['.', '-', '+']);
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // Declare the cfg so `-D warnings` builds don't trip check-cfg lints on
    // compilers where we never emit it.  Older rustc ignores unknown
    // `cargo:` directives, so this line is safe everywhere.
    println!("cargo:rustc-check-cfg=cfg(rtac_avx512)");
    if let Some((major, minor)) = rustc_minor() {
        if (major, minor) >= (1, 89) {
            println!("cargo:rustc-cfg=rtac_avx512");
        }
    }
}
