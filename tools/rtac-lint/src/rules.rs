//! The six repo-invariant rules.
//!
//! Each rule is a pure function over lexed source (plus, for the
//! cross-file rules, a second input), returning [`Finding`]s; the
//! driver applies [`suppressed`] afterwards so every rule is waivable
//! with `// lint:allow(rule-name): reason` at the finding site (same
//! line, up to three lines above — attributes in between are fine — or
//! a spanning block comment).
//!
//! | rule | invariant |
//! |---|---|
//! | `safety-comment`   | every `unsafe` is immediately preceded by `// SAFETY:` |
//! | `thread-placement` | no `thread::spawn`/`thread::scope` outside `exec/pool.rs` |
//! | `simd-containment` | no `std::arch`/`core::arch`/`is_x86_feature_detected!` outside `util/simd.rs` |
//! | `metrics-ledger`   | every `u64` counter on `Inner` surfaces in `MetricsSnapshot` *and* `summary()` |
//! | `engine-coverage`  | every `make_engine` name is exercised by name in `rust/tests/engines.rs` |
//! | `bench-doc-drift`  | every BENCH cell key in `to_json` has a backticked row in `docs/BENCHMARKS.md` |

use crate::lexer::Lexed;
use std::collections::HashSet;

pub const SAFETY_COMMENT: &str = "safety-comment";
pub const THREAD_PLACEMENT: &str = "thread-placement";
pub const SIMD_CONTAINMENT: &str = "simd-containment";
pub const METRICS_LEDGER: &str = "metrics-ledger";
pub const ENGINE_COVERAGE: &str = "engine-coverage";
pub const BENCH_DOC_DRIFT: &str = "bench-doc-drift";

/// All rule names (CLI listing + allow-name validation).
pub const ALL_RULES: &[&str] = &[
    SAFETY_COMMENT,
    THREAD_PLACEMENT,
    SIMD_CONTAINMENT,
    METRICS_LEDGER,
    ENGINE_COVERAGE,
    BENCH_DOC_DRIFT,
];

/// One violation, anchored to a file line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Finding {
    fn new(rule: &'static str, file: &str, line: usize, msg: String) -> Finding {
        Finding { rule, file: file.to_string(), line, msg }
    }
}

/// A `lint:allow(rule)` waiver parsed from a comment.
#[derive(Clone, Debug)]
pub struct Allow {
    pub rule: String,
    pub start_line: usize,
    pub end_line: usize,
}

/// Extract every `lint:allow(rule-a, rule-b)` waiver from a file's
/// comments.
pub fn allows(lexed: &Lexed) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in &lexed.comments {
        let mut rest = c.text.as_str();
        while let Some(pos) = rest.find("lint:allow(") {
            rest = &rest[pos + "lint:allow(".len()..];
            let Some(close) = rest.find(')') else { break };
            for rule in rest[..close].split(',') {
                let rule = rule.trim();
                if !rule.is_empty() {
                    out.push(Allow {
                        rule: rule.to_string(),
                        start_line: c.start_line,
                        end_line: c.end_line,
                    });
                }
            }
            rest = &rest[close + 1..];
        }
    }
    out
}

/// Is a finding for `rule` at `line` waived by one of `allows`?  Waivers
/// reach the same line or up to 3 lines below their comment (so one can
/// sit above attributes), and any line a block-comment waiver spans.
pub fn suppressed(allows: &[Allow], rule: &str, line: usize) -> bool {
    allows.iter().any(|a| {
        a.rule == rule
            && ((a.end_line <= line && line - a.end_line <= 3)
                || (a.start_line <= line && line <= a.end_line))
    })
}

// ---------------------------------------------------------------------
// rule 1: safety-comment
// ---------------------------------------------------------------------

/// Every `unsafe` token must be immediately preceded by a comment
/// containing `SAFETY:` — contiguous comment lines count, attribute
/// lines in between are transparent, a blank line breaks adjacency.  A
/// trailing `// SAFETY:` on the `unsafe` line itself also counts.
pub fn check_safety_comments(file: &str, lexed: &Lexed) -> Vec<Finding> {
    let token_lines = lexed.token_lines();
    let first = lexed.first_tok_by_line();
    let comments = lexed.comment_text_by_line();
    let mut out = Vec::new();
    for t in &lexed.tokens {
        if t.tok.ident() != Some("unsafe") {
            continue;
        }
        let line = t.line;
        let mut ok = comments.get(&line).is_some_and(|txt| txt.contains("SAFETY:"));
        let mut l = line;
        while !ok && l > 1 {
            l -= 1;
            let has_code = token_lines.contains(&l);
            if has_code {
                if first.get(&l).is_some_and(|tk| tk.is_punct('#')) {
                    continue; // attribute line: keep walking up
                }
                break; // a real code line ends the search
            }
            match comments.get(&l) {
                Some(txt) if txt.contains("SAFETY:") => ok = true,
                Some(_) => {} // comment block continues upward
                None => break, // blank line: not "immediately preceding"
            }
        }
        if !ok {
            out.push(Finding::new(
                SAFETY_COMMENT,
                file,
                line,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 2: thread-placement
// ---------------------------------------------------------------------

/// `thread::spawn` / `thread::scope` are only allowed in
/// `exec/pool.rs` — everything else should borrow the persistent
/// `WorkerPool` instead of minting threads.
pub fn check_thread_placement(file: &str, lexed: &Lexed) -> Vec<Finding> {
    if norm(file).ends_with("exec/pool.rs") {
        return Vec::new();
    }
    let t = &lexed.tokens;
    let mut out = Vec::new();
    for i in 0..t.len() {
        if t[i].tok.ident() != Some("thread") || i + 3 >= t.len() {
            continue;
        }
        if !(t[i + 1].tok.is_punct(':') && t[i + 2].tok.is_punct(':')) {
            continue;
        }
        if let Some(what) = t[i + 3].tok.ident() {
            if what == "spawn" || what == "scope" {
                out.push(Finding::new(
                    THREAD_PLACEMENT,
                    file,
                    t[i].line,
                    format!(
                        "`thread::{what}` outside exec/pool.rs — threads belong to the \
                         persistent WorkerPool"
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 3: simd-containment
// ---------------------------------------------------------------------

/// Vendor intrinsics (`std::arch` / `core::arch`) and runtime feature
/// detection stay inside `util/simd.rs`, where the scalar oracle and
/// the dispatch safety contract live.
pub fn check_simd_containment(file: &str, lexed: &Lexed) -> Vec<Finding> {
    if norm(file).ends_with("util/simd.rs") {
        return Vec::new();
    }
    let t = &lexed.tokens;
    let mut out = Vec::new();
    for i in 0..t.len() {
        if t[i].tok.ident() == Some("is_x86_feature_detected") {
            out.push(Finding::new(
                SIMD_CONTAINMENT,
                file,
                t[i].line,
                "`is_x86_feature_detected!` outside util/simd.rs — ISA dispatch is decided \
                 once, by `active_isa`"
                    .to_string(),
            ));
            continue;
        }
        let root = match t[i].tok.ident() {
            Some("std") | Some("core") => t[i].tok.ident().unwrap(),
            _ => continue,
        };
        if i + 3 < t.len()
            && t[i + 1].tok.is_punct(':')
            && t[i + 2].tok.is_punct(':')
            && t[i + 3].tok.ident() == Some("arch")
        {
            out.push(Finding::new(
                SIMD_CONTAINMENT,
                file,
                t[i].line,
                format!(
                    "`{root}::arch` intrinsics outside util/simd.rs — kernels live behind \
                     the dispatched word-kernel layer"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------
// token-walking helpers for the cross-file rules
// ---------------------------------------------------------------------

fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

/// Fields of `struct name { ... }`: `(field, line, first type token)`.
/// The type token is the identifier right after the `:` (`u64`,
/// `HashMap`, …) or `"?"` for non-ident types.
fn struct_fields(lexed: &Lexed, name: &str) -> Vec<(String, usize, String)> {
    let t = &lexed.tokens;
    let mut fields = Vec::new();
    let mut start = None;
    for i in 0..t.len() {
        if t[i].tok.ident() == Some("struct")
            && i + 1 < t.len()
            && t[i + 1].tok.ident() == Some(name)
        {
            // skip to the opening brace (no generic structs to handle
            // in this tree, but a `<...>` would be skipped here too)
            let mut j = i + 2;
            while j < t.len() && !t[j].tok.is_punct('{') {
                if t[j].tok.is_punct(';') {
                    break; // unit/tuple struct: no named fields
                }
                j += 1;
            }
            if j < t.len() && t[j].tok.is_punct('{') {
                start = Some(j + 1);
            }
            break;
        }
    }
    let Some(mut i) = start else { return fields };
    let mut depth = 1usize;
    let mut expecting = true;
    while i < t.len() && depth > 0 {
        let tok = &t[i].tok;
        if tok.is_punct('{') {
            depth += 1;
        } else if tok.is_punct('}') {
            depth -= 1;
        } else if depth == 1 {
            if tok.is_punct(',') {
                expecting = true;
            } else if expecting {
                if let Some(w) = tok.ident() {
                    if w == "pub" {
                        // `pub` / `pub(crate)`: stay in field-name state
                        if i + 1 < t.len() && t[i + 1].tok.is_punct('(') {
                            let mut k = i + 1;
                            while k < t.len() && !t[k].tok.is_punct(')') {
                                k += 1;
                            }
                            i = k;
                        }
                    } else if i + 1 < t.len()
                        && t[i + 1].tok.is_punct(':')
                        && !(i + 2 < t.len() && t[i + 2].tok.is_punct(':'))
                    {
                        let ty = t
                            .get(i + 2)
                            .and_then(|x| x.tok.ident())
                            .unwrap_or("?")
                            .to_string();
                        fields.push((w.to_string(), t[i].line, ty));
                        expecting = false;
                    } else {
                        expecting = false;
                    }
                }
            }
        }
        i += 1;
    }
    fields
}

/// Token index range (exclusive end) of the body of `fn name`, searched
/// from token `from`.  Returns the range *inside* the braces.
fn fn_body_range(lexed: &Lexed, name: &str, from: usize) -> Option<(usize, usize)> {
    let t = &lexed.tokens;
    let mut i = from;
    while i + 1 < t.len() {
        if t[i].tok.ident() == Some("fn") && t[i + 1].tok.ident() == Some(name) {
            let mut j = i + 2;
            while j < t.len() && !t[j].tok.is_punct('{') {
                j += 1;
            }
            if j >= t.len() {
                return None;
            }
            let start = j + 1;
            let mut depth = 1usize;
            let mut k = start;
            while k < t.len() && depth > 0 {
                if t[k].tok.is_punct('{') {
                    depth += 1;
                } else if t[k].tok.is_punct('}') {
                    depth -= 1;
                }
                k += 1;
            }
            return Some((start, k.saturating_sub(1)));
        }
        i += 1;
    }
    None
}

/// Identifiers used inside `fn method` of `impl type_name { ... }`.
fn impl_fn_idents(lexed: &Lexed, type_name: &str, method: &str) -> Option<HashSet<String>> {
    let t = &lexed.tokens;
    for i in 0..t.len() {
        if t[i].tok.ident() == Some("impl")
            && i + 2 < t.len()
            && t[i + 1].tok.ident() == Some(type_name)
            && t[i + 2].tok.is_punct('{')
        {
            let (start, end) = fn_body_range(lexed, method, i + 3)?;
            let mut idents = HashSet::new();
            for tok in &t[start..end] {
                if let Some(w) = tok.tok.ident() {
                    idents.insert(w.to_string());
                }
            }
            return Some(idents);
        }
    }
    None
}

// ---------------------------------------------------------------------
// rule 4: metrics-ledger
// ---------------------------------------------------------------------

/// Every `u64` counter on the metrics `Inner` must appear as a
/// `MetricsSnapshot` field *and* be reported by
/// `MetricsSnapshot::summary()` — otherwise the conservation ledger
/// silently loses a column.  Derived counters waive the field with
/// `lint:allow(metrics-ledger)` naming the surfaced form.
pub fn check_metrics_ledger(file: &str, lexed: &Lexed) -> Vec<Finding> {
    let counters: Vec<(String, usize)> = struct_fields(lexed, "Inner")
        .into_iter()
        .filter(|(_, _, ty)| ty == "u64")
        .map(|(name, line, _)| (name, line))
        .collect();
    let snapshot: HashSet<String> =
        struct_fields(lexed, "MetricsSnapshot").into_iter().map(|(n, _, _)| n).collect();
    let summary = impl_fn_idents(lexed, "MetricsSnapshot", "summary");
    if counters.is_empty() || snapshot.is_empty() || summary.is_none() {
        return vec![Finding::new(
            METRICS_LEDGER,
            file,
            1,
            "metrics anchors not found (struct Inner / struct MetricsSnapshot / \
             MetricsSnapshot::summary) — the ledger rule cannot run"
                .to_string(),
        )];
    }
    let summary = summary.unwrap();
    let mut out = Vec::new();
    for (name, line) in counters {
        if !snapshot.contains(&name) {
            out.push(Finding::new(
                METRICS_LEDGER,
                file,
                line,
                format!("counter `{name}` has no MetricsSnapshot field"),
            ));
        } else if !summary.contains(&name) {
            out.push(Finding::new(
                METRICS_LEDGER,
                file,
                line,
                format!("counter `{name}` is not reported by MetricsSnapshot::summary()"),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 5: engine-coverage
// ---------------------------------------------------------------------

/// Every engine name registered in `make_engine` — exact `"name" =>`
/// arms and `starts_with("prefix")` families — must be exercised by
/// name in `rust/tests/engines.rs`.  A prefix family counts as covered
/// when the tests name the bare prefix or `prefix` + digits
/// (`rtac-par3` covers `rtac-par` but not `rtac-par-inc`).
pub fn check_engine_coverage(reg_file: &str, reg: &Lexed, tests: &Lexed) -> Vec<Finding> {
    let Some((start, end)) = fn_body_range(reg, "make_engine", 0) else {
        return vec![Finding::new(
            ENGINE_COVERAGE,
            reg_file,
            1,
            "fn make_engine not found — the engine-coverage rule cannot run".to_string(),
        )];
    };
    let t = &reg.tokens;
    let mut exact: Vec<(String, usize)> = Vec::new();
    let mut prefixes: Vec<(String, usize)> = Vec::new();
    for i in start..end {
        if let Some(s) = t[i].tok.str_lit() {
            if i + 2 < end && t[i + 1].tok.is_punct('=') && t[i + 2].tok.is_punct('>') {
                exact.push((s.to_string(), t[i].line));
            }
        }
        if t[i].tok.ident() == Some("starts_with")
            && i + 2 < end
            && t[i + 1].tok.is_punct('(')
        {
            if let Some(p) = t[i + 2].tok.str_lit() {
                prefixes.push((p.to_string(), t[i].line));
            }
        }
    }
    let exercised: HashSet<&str> =
        tests.tokens.iter().filter_map(|tok| tok.tok.str_lit()).collect();
    let covers_prefix = |p: &str| {
        exercised.iter().any(|name| {
            *name == p
                || (name.starts_with(p)
                    && name.len() > p.len()
                    && name[p.len()..].bytes().all(|b| b.is_ascii_digit()))
        })
    };
    let mut out = Vec::new();
    for (name, line) in exact {
        if !exercised.contains(name.as_str()) {
            out.push(Finding::new(
                ENGINE_COVERAGE,
                reg_file,
                line,
                format!("engine `{name}` is never exercised by name in rust/tests/engines.rs"),
            ));
        }
    }
    for (p, line) in prefixes {
        if !covers_prefix(&p) {
            out.push(Finding::new(
                ENGINE_COVERAGE,
                reg_file,
                line,
                format!(
                    "engine family `{p}[N]` is never exercised by name in \
                     rust/tests/engines.rs"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------
// rule 6: bench-doc-drift
// ---------------------------------------------------------------------

fn ident_like(s: &str) -> bool {
    let mut chars = s.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_lowercase())
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Every BENCH cell key emitted by `to_json` (the `("key", value)`
/// tuple literals) must appear as a backticked token in
/// `docs/BENCHMARKS.md` — a measurement nobody can interpret is a
/// measurement nobody trusts.
pub fn check_bench_doc_drift(bench_file: &str, bench: &Lexed, doc: &str) -> Vec<Finding> {
    let Some((start, end)) = fn_body_range(bench, "to_json", 0) else {
        return vec![Finding::new(
            BENCH_DOC_DRIFT,
            bench_file,
            1,
            "fn to_json not found — the bench-doc-drift rule cannot run".to_string(),
        )];
    };
    let t = &bench.tokens;
    let mut keys: Vec<(String, usize)> = Vec::new();
    for i in start..end {
        if !t[i].tok.is_punct('(') || i + 2 >= end {
            continue;
        }
        let Some(k) = t[i + 1].tok.str_lit() else { continue };
        if t[i + 2].tok.is_punct(',') && ident_like(k) {
            keys.push((k.to_string(), t[i + 1].line));
        }
    }
    let documented: HashSet<&str> =
        doc.split('`').enumerate().filter(|(n, _)| n % 2 == 1).map(|(_, s)| s).collect();
    let mut out = Vec::new();
    let mut seen = HashSet::new();
    for (k, line) in keys {
        if !seen.insert(k.clone()) {
            continue;
        }
        if !documented.contains(k.as_str()) {
            out.push(Finding::new(
                BENCH_DOC_DRIFT,
                bench_file,
                line,
                format!("BENCH cell key `{k}` has no backticked row in docs/BENCHMARKS.md"),
            ));
        }
    }
    out
}
