//! A minimal hand-written Rust lexer — just enough structure for the
//! lint rules: every token knows its line, comments are captured as
//! text spans (the `// SAFETY:` and `lint:allow(...)` carriers), and
//! string/char/raw-string literals are lexed as single tokens so their
//! *content* never masquerades as code.
//!
//! Deliberately not a full Rust lexer: multi-char operators come out as
//! consecutive [`Tok::Punct`] tokens (`::` is `':' ':'`), numeric
//! literals are not value-parsed, and macro bodies are lexed like any
//! other token stream.  The rules only ever match token *sequences*,
//! so that loss of fidelity is free — what matters is that comments,
//! strings and lifetimes can never be confused with identifiers.

use std::collections::{HashMap, HashSet};

/// One lexed token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`unsafe`, `thread`, `make_engine`, …).
    Ident(String),
    /// `'a` — distinguished from char literals by lookahead.
    Lifetime(String),
    /// String literal content (cooked escapes left as written; raw and
    /// byte strings normalize to the same token).
    Str(String),
    /// A char or byte-char literal (content is never rule-relevant).
    Char,
    /// A numeric literal (content is never rule-relevant).
    Num,
    /// Any other single character (`:`, `=`, `{`, `#`, …).
    Punct(char),
}

impl Tok {
    /// The identifier text, if this token is one.
    pub fn ident(&self) -> Option<&str> {
        match self {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The string-literal text, if this token is one.
    pub fn str_lit(&self) -> Option<&str> {
        match self {
            Tok::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(self, Tok::Punct(p) if *p == c)
    }
}

/// A token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

/// A comment span (line comments are one-line spans; block comments may
/// cover several).  `text` is the raw interior, markers stripped.
#[derive(Clone, Debug)]
pub struct Comment {
    pub start_line: usize,
    pub end_line: usize,
    pub text: String,
}

/// The lexed view of one file.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

impl Lexed {
    /// Lines carrying at least one non-comment token.
    pub fn token_lines(&self) -> HashSet<usize> {
        self.tokens.iter().map(|t| t.line).collect()
    }

    /// The first token on each line (attribute-line detection: a line
    /// whose first token is `#` is an attribute).
    pub fn first_tok_by_line(&self) -> HashMap<usize, Tok> {
        let mut map = HashMap::new();
        for t in &self.tokens {
            map.entry(t.line).or_insert_with(|| t.tok.clone());
        }
        map
    }

    /// Concatenated comment text per covered line (a block comment
    /// contributes its whole text to every line it spans).
    pub fn comment_text_by_line(&self) -> HashMap<usize, String> {
        let mut map: HashMap<usize, String> = HashMap::new();
        for c in &self.comments {
            for line in c.start_line..=c.end_line {
                let slot = map.entry(line).or_default();
                slot.push('\n');
                slot.push_str(&c.text);
            }
        }
        map
    }
}

/// Lex `src` into tokens + comments.  Never fails: unterminated
/// constructs are closed at end of input (the rules prefer a lossy
/// token stream over a lint pass that aborts on one odd file).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut tokens: Vec<Token> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // ---- comments ------------------------------------------------
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = line;
            let mut j = i + 2;
            let mut text = String::new();
            while j < n && chars[j] != '\n' {
                text.push(chars[j]);
                j += 1;
            }
            comments.push(Comment { start_line: start, end_line: start, text });
            i = j; // the '\n' is handled by the main loop
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut text = String::new();
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    text.push_str("/*");
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    if depth > 0 {
                        text.push_str("*/");
                    }
                    j += 2;
                } else {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    text.push(chars[j]);
                    j += 1;
                }
            }
            comments.push(Comment { start_line: start, end_line: line, text });
            i = j;
            continue;
        }

        // ---- cooked string literals ----------------------------------
        if c == '"' {
            let tline = line;
            let mut j = i + 1;
            let mut text = String::new();
            while j < n {
                let d = chars[j];
                if d == '\\' {
                    text.push(d);
                    j += 1;
                    if j < n {
                        if chars[j] == '\n' {
                            line += 1;
                        }
                        text.push(chars[j]);
                        j += 1;
                    }
                    continue;
                }
                if d == '"' {
                    j += 1;
                    break;
                }
                if d == '\n' {
                    line += 1;
                }
                text.push(d);
                j += 1;
            }
            tokens.push(Token { tok: Tok::Str(text), line: tline });
            i = j;
            continue;
        }

        // ---- char literal vs lifetime --------------------------------
        if c == '\'' {
            let tline = line;
            if i + 1 < n && chars[i + 1] == '\\' {
                // escaped char literal ('\n', '\x41', '\u{1F600}', …)
                let mut j = i + 2;
                if j < n {
                    j += 1; // the char introducing the escape body
                }
                while j < n && chars[j] != '\'' {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                tokens.push(Token { tok: Tok::Char, line: tline });
                i = if j < n { j + 1 } else { n };
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                // plain char literal 'x' (also non-ASCII 'µ')
                tokens.push(Token { tok: Tok::Char, line: tline });
                i += 3;
                continue;
            }
            // lifetime: 'a, 'scope, '_
            let mut j = i + 1;
            let mut name = String::new();
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                name.push(chars[j]);
                j += 1;
            }
            tokens.push(Token { tok: Tok::Lifetime(name), line: tline });
            i = j;
            continue;
        }

        // ---- numeric literals ----------------------------------------
        if c.is_ascii_digit() {
            let tline = line;
            let mut j = i;
            while j < n {
                let d = chars[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    j += 1;
                } else if d == '.' && j + 1 < n && chars[j + 1].is_ascii_digit() {
                    // consume the fraction, but leave `0..4` as Num ':' ':'
                    j += 1;
                } else if (d == '+' || d == '-')
                    && j > i
                    && (chars[j - 1] == 'e' || chars[j - 1] == 'E')
                    && j + 1 < n
                    && chars[j + 1].is_ascii_digit()
                {
                    // exponent sign: 1e-9
                    j += 1;
                } else {
                    break;
                }
            }
            tokens.push(Token { tok: Tok::Num, line: tline });
            i = j;
            continue;
        }

        // ---- identifiers (and raw/byte-literal prefixes) -------------
        if c.is_alphabetic() || c == '_' {
            let tline = line;
            let mut j = i;
            let mut word = String::new();
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                word.push(chars[j]);
                j += 1;
            }
            // raw strings: r"..." / r#"..."# / br"..." / br##"..."##
            if (word == "r" || word == "br") && j < n && (chars[j] == '"' || chars[j] == '#') {
                let mut hashes = 0usize;
                let mut k = j;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    k += 1;
                    let mut text = String::new();
                    while k < n {
                        if chars[k] == '"' {
                            let mut m = 0usize;
                            while m < hashes && k + 1 + m < n && chars[k + 1 + m] == '#' {
                                m += 1;
                            }
                            if m == hashes {
                                k += 1 + hashes;
                                break;
                            }
                        }
                        if chars[k] == '\n' {
                            line += 1;
                        }
                        text.push(chars[k]);
                        k += 1;
                    }
                    tokens.push(Token { tok: Tok::Str(text), line: tline });
                    i = k;
                    continue;
                }
                if word == "r" && hashes == 1 && k < n && (chars[k].is_alphabetic() || chars[k] == '_')
                {
                    // raw identifier r#type → Ident("type")
                    let mut name = String::new();
                    let mut m = k;
                    while m < n && (chars[m].is_alphanumeric() || chars[m] == '_') {
                        name.push(chars[m]);
                        m += 1;
                    }
                    tokens.push(Token { tok: Tok::Ident(name), line: tline });
                    i = m;
                    continue;
                }
            }
            // byte string b"..." / byte char b'x': re-enter the main
            // loop at the quote — the prefix itself is not a token
            if word == "b" && j < n && (chars[j] == '"' || chars[j] == '\'') {
                i = j;
                continue;
            }
            tokens.push(Token { tok: Tok::Ident(word), line: tline });
            i = j;
            continue;
        }

        // ---- everything else -----------------------------------------
        tokens.push(Token { tok: Tok::Punct(c), line });
        i += 1;
    }

    Lexed { tokens, comments }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(lexed: &Lexed) -> Vec<String> {
        lexed.tokens.iter().filter_map(|t| t.tok.ident().map(String::from)).collect()
    }

    #[test]
    fn code_in_strings_is_not_code() {
        let lexed = lex(r#"let x = "unsafe { thread::spawn } // SAFETY:";"#);
        assert_eq!(idents(&lexed), vec!["let", "x"]);
        assert!(lexed.comments.is_empty(), "string content produced a comment");
        assert_eq!(
            lexed.tokens.iter().filter(|t| matches!(t.tok, Tok::Str(_))).count(),
            1
        );
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let lexed = lex(r#"let s = "a \" b"; unsafe {}"#);
        assert!(idents(&lexed).contains(&"unsafe".to_string()));
        let strs: Vec<&str> = lexed.tokens.iter().filter_map(|t| t.tok.str_lit()).collect();
        assert_eq!(strs, vec![r#"a \" b"#]);
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let lexed = lex("/* outer /* inner */ still comment */ fn f() {}");
        assert_eq!(idents(&lexed), vec!["fn", "f"]);
        assert_eq!(lexed.comments.len(), 1);
        assert!(lexed.comments[0].text.contains("inner"));
        assert!(lexed.comments[0].text.contains("still comment"));
    }

    #[test]
    fn raw_strings_swallow_quotes_and_comment_markers() {
        let lexed = lex(r##"let s = r#"has "quotes" and // no comment"#; fn g() {}"##);
        assert!(lexed.comments.is_empty());
        let strs: Vec<&str> = lexed.tokens.iter().filter_map(|t| t.tok.str_lit()).collect();
        assert_eq!(strs, vec![r#"has "quotes" and // no comment"#]);
        assert!(idents(&lexed).contains(&"g".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lexed = lex("fn f<'scope>(x: &'scope str) { let c = 'a'; let u = '\\n'; }");
        let lifetimes: Vec<&Token> =
            lexed.tokens.iter().filter(|t| matches!(t.tok, Tok::Lifetime(_))).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars: Vec<&Token> =
            lexed.tokens.iter().filter(|t| matches!(t.tok, Tok::Char)).collect();
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn line_numbers_track_every_construct() {
        let src = "fn a() {}\n// comment\n/* block\nspans */\nfn b() {}\n";
        let lexed = lex(src);
        let b = lexed.tokens.iter().find(|t| t.tok.ident() == Some("b")).unwrap();
        assert_eq!(b.line, 5);
        assert_eq!(lexed.comments[0].start_line, 2);
        assert_eq!(lexed.comments[1].start_line, 3);
        assert_eq!(lexed.comments[1].end_line, 4);
    }

    #[test]
    fn multiline_strings_advance_the_line_counter() {
        let lexed = lex("let s = \"line one\nline two\";\nfn tail() {}");
        let tail = lexed.tokens.iter().find(|t| t.tok.ident() == Some("tail")).unwrap();
        assert_eq!(tail.line, 3);
    }

    #[test]
    fn double_colon_is_two_puncts_and_ranges_are_not_fractions() {
        let lexed = lex("thread::spawn(0..4)");
        let toks: Vec<&Tok> = lexed.tokens.iter().map(|t| &t.tok).collect();
        assert_eq!(toks[0].ident(), Some("thread"));
        assert!(toks[1].is_punct(':') && toks[2].is_punct(':'));
        assert_eq!(toks[3].ident(), Some("spawn"));
        // 0..4 must lex as Num '.' '.' Num, not a fractional literal
        let dots = lexed.tokens.iter().filter(|t| t.tok.is_punct('.')).count();
        assert_eq!(dots, 2);
    }

    #[test]
    fn byte_literals_lex_as_literals_not_idents() {
        let lexed = lex(r#"let x = b"bytes"; let y = b'z';"#);
        assert_eq!(idents(&lexed), vec!["let", "x", "let", "y"]);
        assert!(lexed.tokens.iter().any(|t| t.tok.str_lit() == Some("bytes")));
        assert!(lexed.tokens.iter().any(|t| matches!(t.tok, Tok::Char)));
    }

    #[test]
    fn comment_text_by_line_covers_block_spans() {
        let lexed = lex("/* SAFETY: spans\nmore */\nunsafe {}");
        let by_line = lexed.comment_text_by_line();
        assert!(by_line[&1].contains("SAFETY:"));
        assert!(by_line[&2].contains("SAFETY:"));
        assert!(!by_line.contains_key(&3));
    }

    #[test]
    fn attributes_lex_with_hash_first_on_line() {
        let lexed = lex("#[cfg(test)]\nfn f() {}");
        let first = lexed.first_tok_by_line();
        assert!(first[&1].is_punct('#'));
        assert_eq!(first[&2].ident(), Some("fn"));
    }
}
