//! CLI entry point: `cargo run -p rtac-lint [-- --root <path>] [--json]`.
//!
//! Exit status: 0 when every rule passes, 1 on violations, 2 on usage
//! or I/O errors — so CI can distinguish "the tree drifted" from "the
//! lint could not run".

use std::path::PathBuf;
use std::process::ExitCode;

use rtac_lint::{driver, rules};

const USAGE: &str = "\
rtac-lint — repo-invariant static analysis (see docs/CORRECTNESS.md)

USAGE:
    rtac-lint [--root <path>] [--json]

OPTIONS:
    --root <path>   repo checkout to lint (default: current directory)
    --json          machine-readable output
    --rules         list the rules and exit
    -h, --help      this text
";

fn main() -> ExitCode {
    let mut json = false;
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("--root needs a path\n\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--rules" => {
                for rule in rules::ALL_RULES {
                    println!("{rule}");
                }
                return ExitCode::SUCCESS;
            }
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument {other:?}\n\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    match driver::run(&root) {
        Ok(report) => {
            if json {
                print!("{}", driver::render_json(&report));
            } else {
                print!("{}", driver::render_human(&report));
            }
            if report.clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("rtac-lint: {e}");
            ExitCode::from(2)
        }
    }
}
