//! File walking, rule orchestration, suppression and output.

use crate::lexer::{lex, Lexed};
use crate::rules::{self, Allow, Finding};
use std::fs;
use std::path::{Path, PathBuf};

/// The result of one lint pass.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Recursively collect `.rs` files under `dir` (sorted for stable
/// output).
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

fn rel(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Run every rule over `root` (the repo checkout: `rust/src`,
/// `rust/tests`, `docs/BENCHMARKS.md` live beneath it).
pub fn run(root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    for dir in ["rust/src", "rust/tests"] {
        let d = root.join(dir);
        if !d.is_dir() {
            return Err(format!(
                "{} not found under --root {} — run from the repo root",
                dir,
                root.display()
            ));
        }
        walk(&d, &mut files)?;
    }
    files.sort();

    let mut findings: Vec<Finding> = Vec::new();
    // Anchors for the cross-file rules, captured while walking.
    let mut registry: Option<(String, Lexed, Vec<Allow>)> = None;
    let mut engine_tests: Option<Lexed> = None;
    let mut bench: Option<(String, Lexed, Vec<Allow>)> = None;
    let mut saw_metrics = false;

    for path in &files {
        let name = rel(root, path);
        let src = fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let lexed = lex(&src);
        let allow_list = rules::allows(&lexed);

        let mut per_file = Vec::new();
        per_file.extend(rules::check_safety_comments(&name, &lexed));
        per_file.extend(rules::check_thread_placement(&name, &lexed));
        per_file.extend(rules::check_simd_containment(&name, &lexed));
        if name.ends_with("coordinator/metrics.rs") {
            saw_metrics = true;
            per_file.extend(rules::check_metrics_ledger(&name, &lexed));
        }
        findings.extend(
            per_file.into_iter().filter(|f| !rules::suppressed(&allow_list, f.rule, f.line)),
        );

        if name.ends_with("ac/mod.rs") {
            registry = Some((name.clone(), lexed.clone(), allow_list.clone()));
        }
        if name.ends_with("tests/engines.rs") {
            engine_tests = Some(lexed.clone());
        }
        if name.ends_with("bench/rtac_bench.rs") {
            bench = Some((name.clone(), lexed.clone(), allow_list.clone()));
        }
    }

    if !saw_metrics {
        findings.push(Finding {
            rule: rules::METRICS_LEDGER,
            file: "rust/src/coordinator/metrics.rs".to_string(),
            line: 1,
            msg: "coordinator/metrics.rs missing — the metrics-ledger rule cannot run"
                .to_string(),
        });
    }

    match (&registry, &engine_tests) {
        (Some((name, reg, allow_list)), Some(tests)) => {
            findings.extend(
                rules::check_engine_coverage(name, reg, tests)
                    .into_iter()
                    .filter(|f| !rules::suppressed(allow_list, f.rule, f.line)),
            );
        }
        _ => findings.push(Finding {
            rule: rules::ENGINE_COVERAGE,
            file: "rust/src/ac/mod.rs".to_string(),
            line: 1,
            msg: "engine registry (ac/mod.rs) or rust/tests/engines.rs missing — the \
                  engine-coverage rule cannot run"
                .to_string(),
        }),
    }

    let doc_path = root.join("docs/BENCHMARKS.md");
    match (&bench, fs::read_to_string(&doc_path)) {
        (Some((name, lexed, allow_list)), Ok(doc)) => {
            findings.extend(
                rules::check_bench_doc_drift(name, lexed, &doc)
                    .into_iter()
                    .filter(|f| !rules::suppressed(allow_list, f.rule, f.line)),
            );
        }
        _ => findings.push(Finding {
            rule: rules::BENCH_DOC_DRIFT,
            file: "rust/src/bench/rtac_bench.rs".to_string(),
            line: 1,
            msg: "bench/rtac_bench.rs or docs/BENCHMARKS.md missing — the bench-doc-drift \
                  rule cannot run"
                .to_string(),
        }),
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { findings, files_scanned: files.len() })
}

/// Human-readable report: one `file:line: [rule] message` per finding
/// plus a one-line verdict.
pub fn render_human(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.msg));
    }
    if report.clean() {
        out.push_str(&format!(
            "rtac-lint: clean ({} files, {} rules)\n",
            report.files_scanned,
            rules::ALL_RULES.len()
        ));
    } else {
        out.push_str(&format!(
            "rtac-lint: {} violation(s) in {} files scanned\n",
            report.findings.len(),
            report.files_scanned
        ));
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable report (the CI `lint` job consumes this shape).
pub fn render_json(report: &Report) -> String {
    let mut out = String::from("{\n  \"violations\": [");
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            json_escape(f.rule),
            json_escape(&f.file),
            f.line,
            json_escape(&f.msg)
        ));
    }
    if !report.findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str(&format!(
        "],\n  \"count\": {},\n  \"files_scanned\": {}\n}}\n",
        report.findings.len(),
        report.files_scanned
    ));
    out
}
