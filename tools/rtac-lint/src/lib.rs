//! `rtac-lint` — offline static analysis that machine-checks the repo's
//! cross-file conventions (see `docs/CORRECTNESS.md` for the rule
//! catalog and rationale).
//!
//! The binary walks `rust/src` and `rust/tests` with a small
//! hand-written lexer ([`lexer`]) — comments, strings, raw strings and
//! attributes are understood, so a `thread::spawn` in a doc comment is
//! not a violation — and runs six named rules ([`rules`]).  Any
//! violation can be locally waived with a
//! `// lint:allow(rule-name): reason` comment on the offending line or
//! up to three lines above it (so the waiver can sit above attributes).

#![forbid(unsafe_code)]

pub mod lexer;
pub mod rules;
pub mod driver;
