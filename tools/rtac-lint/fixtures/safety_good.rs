// Fixture: every `unsafe` carries a SAFETY comment in one of the
// accepted shapes (above, above an attribute, below an attribute,
// trailing on the same line).  Not compiled — lexed by the rule tests.

pub struct W(*mut u8);

impl W {
    pub fn read(&self) -> u8 {
        // SAFETY: the pointer is non-null and owned by construction.
        unsafe { *self.0 }
    }
}

// SAFETY: callers guarantee AVX2 (the comment may sit above the
// attribute — attribute lines are transparent to the walk-up).
#[target_feature(enable = "avx2")]
pub unsafe fn kernel(x: &mut [u64]) {
    // SAFETY: writes stay in bounds: the pointer is the slice's own.
    unsafe { core::ptr::write(x.as_mut_ptr(), 1) }
}

#[inline]
// SAFETY: comment below the attribute works too.
pub unsafe fn kernel2() {}

pub fn trailing(x: &[u64]) -> u64 {
    unsafe { *x.as_ptr() } // SAFETY: `x` is non-empty (checked by caller)
}
