// Fixture: vendor intrinsics leaking outside util/simd.rs (three
// violations: the import, a core::arch path, and the detection macro).
// Not compiled.

use std::arch::x86_64::_mm256_setzero_si256;

pub fn leak() -> bool {
    let _z = core::arch::x86_64::_mm256_setzero_si256;
    is_x86_feature_detected!("avx2")
}

// talking about std::arch or is_x86_feature_detected! in a comment is fine
pub const DOC: &str = "and std::arch in a string is fine";
