// Fixture: thread APIs that are NOT spawn/scope never trip the rule.
// Not compiled.

use std::thread::JoinHandle;

pub fn builder() -> JoinHandle<()> {
    std::thread::Builder::new().spawn(|| {}).unwrap()
}

pub fn nap() {
    std::thread::sleep(std::time::Duration::from_millis(1));
    std::thread::yield_now();
}
