// Fixture: a counter with no snapshot field (dropped_requests) and one
// that reaches the snapshot but not summary() (responses).  Not
// compiled.

struct Inner {
    requests: u64,
    responses: u64,
    dropped_requests: u64,
}

pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
}

impl MetricsSnapshot {
    pub fn summary(&self) -> String {
        format!("req={}", self.requests)
    }
}
