// Fixture: only `ac3` is really exercised.  `rtac-par-extra` must NOT
// count as covering the `rtac-par` family (the suffix is not digits).
// Not compiled.

#[test]
fn partial_coverage() {
    for name in ["ac3", "rtac-par-extra"] {
        let _ = name;
    }
}
