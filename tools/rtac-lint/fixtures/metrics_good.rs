// Fixture: a miniature metrics module where every u64 counter on Inner
// surfaces in MetricsSnapshot and summary(), except one derived counter
// carrying an explicit waiver.  Not compiled.

struct Inner {
    requests: u64,
    responses: u64,
    batch_occupancy_sum: u64, // lint:allow(metrics-ledger): surfaced as mean_batch_occupancy
    queue_us: f64,
}

pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub mean_batch_occupancy: f64,
}

impl MetricsSnapshot {
    pub fn summary(&self) -> String {
        format!(
            "req={} resp={} occ={:.2}",
            self.requests, self.responses, self.mean_batch_occupancy
        )
    }
}
