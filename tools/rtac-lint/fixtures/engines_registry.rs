// Fixture: a miniature make_engine with two exact arms and two prefix
// families.  Not compiled.

pub fn make_engine(name: &str) -> Result<(), String> {
    match name {
        "ac3" => Ok(()),
        "rtac" => Ok(()),
        other if other.starts_with("rtac-par") => Ok(()),
        other if other.starts_with("sac-par") => Ok(()),
        other => Err(format!("unknown engine {other:?}")),
    }
}
