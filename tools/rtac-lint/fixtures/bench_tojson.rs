// Fixture: a miniature to_json emitting four cell keys (the skip
// marker included — a cell that did not run still appears).  The
// format! string below must NOT be mistaken for a key.  Not compiled.

pub fn to_json(ok: bool) -> Vec<(&'static str, f64)> {
    let mut fields = vec![("bench", 1.0), ("rows", 2.0)];
    if ok {
        fields.push(("simd_kernel_ns", 3.0));
    } else {
        fields.push(("simd_skipped", 0.0));
    }
    let _label = format!("not_a_key {}", fields.len());
    fields
}
