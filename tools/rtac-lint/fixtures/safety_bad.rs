// Fixture: three `unsafe` sites without a SAFETY comment (a bare one,
// one separated from its comment by a blank line, and one whose
// adjacent comment says something else).  Not compiled.

pub fn broken(x: &[u64]) -> u64 {
    let a = unsafe { *x.as_ptr() };

    // this comment is adjacent but carries no safety argument
    let b = unsafe { *x.as_ptr() };
    a + b
}

// SAFETY: this one is stranded — the blank line below breaks adjacency.

pub unsafe fn no_comment() {}

pub fn waived(x: &[u64]) -> u64 {
    // lint:allow(safety-comment): vetted in review, comment pending
    unsafe { *x.as_ptr() }
}
