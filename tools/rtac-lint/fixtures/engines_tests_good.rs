// Fixture: exercises every fixture-registry engine by name — exact
// names literally, families via a digit suffix or the bare prefix.
// Not compiled.

#[test]
fn every_engine_by_name() {
    for name in ["ac3", "rtac", "rtac-par3", "sac-par"] {
        let _ = name;
    }
}
