// Fixture: raw thread spawning outside exec/pool.rs (two violations —
// the comment and string mentions below must NOT count).  Not compiled.

pub fn fan_out() {
    let h = std::thread::spawn(|| 1);
    let _ = h.join();
    std::thread::scope(|s| {
        s.spawn(|| 2);
    });
}

// a doc mention of thread::spawn is fine
pub fn doc_mention() -> &'static str {
    "thread::scope in a string is fine too"
}

pub fn waived() {
    // lint:allow(thread-placement): test-only fake executor
    std::thread::spawn(|| 3).join().unwrap();
}
