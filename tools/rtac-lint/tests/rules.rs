//! Fixture-driven tests: each rule gets a good/bad snippet pair, the
//! `lint:allow` waiver is exercised per rule, and the driver runs
//! end-to-end against a synthesized mini-repo (clean tree exits clean,
//! seeded violations are reported).

use rtac_lint::driver;
use rtac_lint::lexer::lex;
use rtac_lint::rules::{
    self, allows, check_bench_doc_drift, check_engine_coverage, check_metrics_ledger,
    check_safety_comments, check_simd_containment, check_thread_placement, suppressed, Finding,
};

const SAFETY_GOOD: &str = include_str!("../fixtures/safety_good.rs");
const SAFETY_BAD: &str = include_str!("../fixtures/safety_bad.rs");
const THREAD_GOOD: &str = include_str!("../fixtures/thread_good.rs");
const THREAD_BAD: &str = include_str!("../fixtures/thread_bad.rs");
const SIMD_BAD: &str = include_str!("../fixtures/simd_bad.rs");
const METRICS_GOOD: &str = include_str!("../fixtures/metrics_good.rs");
const METRICS_BAD: &str = include_str!("../fixtures/metrics_bad.rs");
const ENGINES_REGISTRY: &str = include_str!("../fixtures/engines_registry.rs");
const ENGINES_TESTS_GOOD: &str = include_str!("../fixtures/engines_tests_good.rs");
const ENGINES_TESTS_BAD: &str = include_str!("../fixtures/engines_tests_bad.rs");
const BENCH_TOJSON: &str = include_str!("../fixtures/bench_tojson.rs");
const BENCH_DOC_GOOD: &str = include_str!("../fixtures/bench_doc_good.md");
const BENCH_DOC_BAD: &str = include_str!("../fixtures/bench_doc_bad.md");

/// Run a single-file rule and drop waived findings, like the driver.
fn surviving(findings: Vec<Finding>, src: &str) -> Vec<Finding> {
    let allow_list = allows(&lex(src));
    findings.into_iter().filter(|f| !suppressed(&allow_list, f.rule, f.line)).collect()
}

// ---- rule 1: safety-comment -----------------------------------------

#[test]
fn safety_good_fixture_is_clean() {
    let f = check_safety_comments("x.rs", &lex(SAFETY_GOOD));
    assert!(f.is_empty(), "false positives: {f:?}");
}

#[test]
fn safety_bad_fixture_flags_each_bare_unsafe() {
    let raw = check_safety_comments("x.rs", &lex(SAFETY_BAD));
    assert_eq!(raw.len(), 4, "{raw:?}");
    let kept = surviving(raw, SAFETY_BAD);
    assert_eq!(kept.len(), 3, "the lint:allow site must be waived: {kept:?}");
    assert!(kept.iter().all(|f| f.rule == rules::SAFETY_COMMENT));
}

// ---- rule 2: thread-placement ---------------------------------------

#[test]
fn thread_good_fixture_is_clean() {
    let f = check_thread_placement("rust/src/search/parallel.rs", &lex(THREAD_GOOD));
    assert!(f.is_empty(), "Builder/sleep/yield_now are not spawn: {f:?}");
}

#[test]
fn thread_bad_fixture_flags_spawn_and_scope_but_not_comments() {
    let raw = check_thread_placement("rust/src/search/parallel.rs", &lex(THREAD_BAD));
    assert_eq!(raw.len(), 3, "{raw:?}");
    let kept = surviving(raw, THREAD_BAD);
    assert_eq!(kept.len(), 2, "the waived spawn must drop: {kept:?}");
}

#[test]
fn thread_rule_exempts_the_pool() {
    let f = check_thread_placement("rust/src/exec/pool.rs", &lex(THREAD_BAD));
    assert!(f.is_empty(), "exec/pool.rs owns thread creation: {f:?}");
}

// ---- rule 3: simd-containment ---------------------------------------

#[test]
fn simd_bad_fixture_flags_arch_and_detection() {
    let f = check_simd_containment("rust/src/core/plane.rs", &lex(SIMD_BAD));
    assert_eq!(f.len(), 3, "{f:?}");
}

#[test]
fn simd_rule_exempts_the_kernel_module() {
    let f = check_simd_containment("rust/src/util/simd.rs", &lex(SIMD_BAD));
    assert!(f.is_empty(), "util/simd.rs owns the intrinsics: {f:?}");
}

// ---- rule 4: metrics-ledger -----------------------------------------

#[test]
fn metrics_good_fixture_waives_the_derived_counter() {
    let raw = check_metrics_ledger("m.rs", &lex(METRICS_GOOD));
    assert_eq!(raw.len(), 1, "only batch_occupancy_sum should raise: {raw:?}");
    assert!(raw[0].msg.contains("batch_occupancy_sum"));
    let kept = surviving(raw, METRICS_GOOD);
    assert!(kept.is_empty(), "the same-line waiver must hold: {kept:?}");
}

#[test]
fn metrics_bad_fixture_flags_field_and_summary_gaps() {
    let f = check_metrics_ledger("m.rs", &lex(METRICS_BAD));
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("dropped_requests")
        && x.msg.contains("no MetricsSnapshot field")));
    assert!(f.iter().any(|x| x.msg.contains("responses") && x.msg.contains("summary")));
}

// ---- rule 5: engine-coverage ----------------------------------------

#[test]
fn engine_coverage_good_fixture_is_clean() {
    let f =
        check_engine_coverage("reg.rs", &lex(ENGINES_REGISTRY), &lex(ENGINES_TESTS_GOOD));
    assert!(f.is_empty(), "bare prefix and digit suffix both cover: {f:?}");
}

#[test]
fn engine_coverage_bad_fixture_flags_uncovered_names() {
    let f = check_engine_coverage("reg.rs", &lex(ENGINES_REGISTRY), &lex(ENGINES_TESTS_BAD));
    assert_eq!(f.len(), 3, "rtac, rtac-par, sac-par must raise: {f:?}");
    assert!(f.iter().any(|x| x.msg.contains("`rtac`")));
    assert!(
        f.iter().any(|x| x.msg.contains("rtac-par[N]")),
        "a non-digit suffix must not cover a family: {f:?}"
    );
    assert!(f.iter().any(|x| x.msg.contains("sac-par[N]")));
}

// ---- rule 6: bench-doc-drift ----------------------------------------

#[test]
fn bench_doc_good_fixture_is_clean() {
    let f = check_bench_doc_drift("b.rs", &lex(BENCH_TOJSON), BENCH_DOC_GOOD);
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn bench_doc_bad_fixture_flags_the_undocumented_key() {
    let f = check_bench_doc_drift("b.rs", &lex(BENCH_TOJSON), BENCH_DOC_BAD);
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].msg.contains("simd_skipped"), "unbackticked mention must not count");
}

// ---- driver end-to-end ----------------------------------------------

struct MiniRepo {
    root: std::path::PathBuf,
}

impl MiniRepo {
    fn new(tag: &str) -> MiniRepo {
        let root = std::env::temp_dir()
            .join(format!("rtac-lint-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for dir in [
            "rust/src/ac",
            "rust/src/bench",
            "rust/src/coordinator",
            "rust/tests",
            "docs",
        ] {
            std::fs::create_dir_all(root.join(dir)).unwrap();
        }
        MiniRepo { root }
    }

    fn write(&self, rel: &str, content: &str) {
        std::fs::write(self.root.join(rel), content).unwrap();
    }

    /// A tree every rule passes on.
    fn clean(tag: &str) -> MiniRepo {
        let repo = MiniRepo::new(tag);
        repo.write("rust/src/ac/mod.rs", ENGINES_REGISTRY);
        repo.write("rust/tests/engines.rs", ENGINES_TESTS_GOOD);
        repo.write("rust/src/bench/rtac_bench.rs", BENCH_TOJSON);
        repo.write("rust/src/coordinator/metrics.rs", METRICS_GOOD);
        repo.write("rust/src/lib.rs", SAFETY_GOOD);
        repo.write("docs/BENCHMARKS.md", BENCH_DOC_GOOD);
        repo
    }
}

impl Drop for MiniRepo {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

#[test]
fn driver_is_clean_on_a_conforming_tree() {
    let repo = MiniRepo::clean("clean");
    let report = driver::run(&repo.root).unwrap();
    assert!(report.clean(), "unexpected findings: {:?}", report.findings);
    assert_eq!(report.files_scanned, 5);
    assert!(driver::render_human(&report).contains("clean"));
    assert!(driver::render_json(&report).contains("\"count\": 0"));
}

#[test]
fn driver_reports_seeded_violations_of_every_rule() {
    let repo = MiniRepo::clean("seeded");
    // seed one violation per rule
    repo.write("rust/src/lib.rs", SAFETY_BAD); // safety-comment
    repo.write("rust/src/search_parallel.rs", THREAD_BAD); // thread-placement
    repo.write("rust/src/core_plane.rs", SIMD_BAD); // simd-containment
    repo.write("rust/src/coordinator/metrics.rs", METRICS_BAD); // metrics-ledger
    repo.write("rust/tests/engines.rs", ENGINES_TESTS_BAD); // engine-coverage
    repo.write("docs/BENCHMARKS.md", BENCH_DOC_BAD); // bench-doc-drift
    let report = driver::run(&repo.root).unwrap();
    for rule in rules::ALL_RULES {
        assert!(
            report.findings.iter().any(|f| f.rule == *rule),
            "rule {rule} raised nothing: {:?}",
            report.findings
        );
    }
    let json = driver::render_json(&report);
    assert!(json.contains("\"rule\": \"safety-comment\""));
    let human = driver::render_human(&report);
    assert!(human.contains("violation(s)"));
}

#[test]
fn driver_flags_missing_anchor_files_instead_of_passing_silently() {
    let repo = MiniRepo::clean("anchors");
    std::fs::remove_file(repo.root.join("rust/src/coordinator/metrics.rs")).unwrap();
    std::fs::remove_file(repo.root.join("docs/BENCHMARKS.md")).unwrap();
    let report = driver::run(&repo.root).unwrap();
    assert!(report.findings.iter().any(|f| f.rule == rules::METRICS_LEDGER));
    assert!(report.findings.iter().any(|f| f.rule == rules::BENCH_DOC_DRIFT));
}
