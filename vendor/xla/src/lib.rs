//! Compile-time stub of the `xla` (PJRT) bindings.
//!
//! The container has no XLA shared libraries and no registry access, so
//! this crate mirrors exactly the API surface `rtac::runtime` uses and
//! fails at *runtime* with a clear "unavailable" error instead of
//! failing the build.  Every entry point that would touch PJRT returns
//! `Err(Error::unavailable())`; since clients can never obtain a
//! `PjRtClient`, the downstream methods are unreachable in practice but
//! still typecheck.  The artifact-gated tests in `rust/tests/` self-skip
//! before reaching any of this.
//!
//! Swap this path dependency for the real `xla` crate (and delete this
//! stub) to run the AOT artifacts.

use std::marker::PhantomData;
use std::rc::Rc;

/// Error type matching the real crate's `xla::Error` usage (`{e:?}`).
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable() -> Error {
        Error { message: "XLA/PJRT runtime unavailable in this build (stubbed xla crate)".into() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

/// Element types transferable to/from device buffers.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// PJRT client handle.  `Rc`-backed in the real crate (not `Send`); the
/// marker preserves that property so threading bugs surface at compile
/// time even against the stub.
pub struct PjRtClient {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::unavailable())
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// A host-side tensor literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: ElementType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal), Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(format!("{err:?}").contains("unavailable"));
    }

    #[test]
    fn hlo_parse_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
