//! Offline stand-in for the `anyhow` crate.
//!
//! The vendored crate set has no registry access, so this crate provides
//! the small slice of anyhow's API the repo uses — `Error`, `Result`,
//! `anyhow!`, `bail!`, and the `Context` extension trait — backed by a
//! plain string context chain.  Semantics match anyhow where it matters
//! here: `Display` shows the outermost context, `{:#}` (alternate) shows
//! the whole chain joined by `": "`, and `?` converts any
//! `std::error::Error` via the blanket `From` impl.

use std::fmt;

/// A context-chained error.  The last element of `chain` is the
/// outermost (most recently attached) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The outermost message.
    fn outer(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: outermost context first, then each cause.
            for (i, layer) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{layer}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outer())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for layer in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {layer}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let text = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(text)
    }

    #[test]
    fn context_chain_renders_outer_first() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(format!("{err}"), "missing value");

        fn bails() -> Result<()> {
            bail!("bad state {}", 7);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "bad state 7");
        let e = anyhow!("x = {}", 1).context("outer");
        assert_eq!(format!("{e:#}"), "outer: x = 1");
    }
}
