//! `RtacParallel` — word-parallel AND thread-parallel RTAC sweeps on
//! the persistent worker pool.
//!
//! The paper's core claim is that each recurrence of Eq. 1 is *fully
//! parallelizable*: every (variable, value) support test of sweep k
//! reads only the sweep k−1 snapshot.  This engine exploits that Jacobi
//! structure on CPU:
//!
//! * Domains live in the flat [`DomainPlane`] arena, double-buffered:
//!   `cur` holds the k−1 snapshot, `next` starts each sweep as a memcpy
//!   of `cur` and receives the sweep's removals as word-masked bit
//!   clears.
//! * Variables are partitioned into contiguous word ranges
//!   ([`DomainPlane::partition`]); each sweep submits one task per
//!   chunk to a persistent [`WorkerPool`], each task owning a
//!   **disjoint `&mut [u64]` slice** of the next plane (`split_at_mut`
//!   — no locks, no atomics on the hot path).  Support tests stream the
//!   packed relation rows against the shared `cur` plane.  The pool is
//!   spawned once and reused across every sweep, every enforcement and
//!   every search node — the number of recurrences is small (3–5), so
//!   per-sweep thread spawning is pure overhead; the old per-sweep
//!   `std::thread::scope` path is kept behind
//!   [`RtacParallel::scoped_spawn`] purely as the bench baseline for
//!   that claim (`BENCH_rtac.json`'s pooled-vs-scoped row).
//! * Within a chunk, each variable is revised a 64-value word at a
//!   time through the runtime-dispatched SIMD kernels
//!   ([`crate::util::simd`], shared with the sequential engine via
//!   `revise_var_fused`): one [`crate::util::simd::supported_mask`]
//!   call per (word, arc) instead of a per-value scan, with fused
//!   changed/wipeout detection replacing the old all-zero row rescan.
//! * Per-worker support counts and changed-variable **bitsets** are
//!   merged at the sweep barrier, in chunk order, so every merged
//!   quantity is deterministic.  The per-worker `ChunkOut` scratch
//!   (one changed bitset each) is pooled on the engine and reused
//!   across sweeps and enforcements.  A shared wipeout [`AtomicBool`]
//!   lets the sweep loop abort further recurrences (and skip trail
//!   replay past the victim) the moment any worker wipes a domain.
//! * **Prop.-2 incremental candidate set** ([`RtacParallel::incremental`],
//!   engine name `rtac-par-inc`): sweep k only re-checks variables with
//!   a neighbour whose domain changed in sweep k−1.  The OR-merged
//!   per-chunk changed bitsets *are* the paper's `@changed` set; the
//!   coordinator thread expands them word-parallel through the
//!   precomputed adjacency bitsets (`expand_affected`) and the
//!   workers read the resulting `affected` bitset read-only.
//!   Identical removals and sweep counts to the dense engine (Prop. 2),
//!   strictly fewer support checks.
//!
//! # Bit-identity contract
//!
//! `RtacParallel` is bit-identical to [`super::rtac::RtacNative::dense`]
//! in outcome (including the wipeout victim) and `#Recurrence` count
//! always, and — on consistent enforcements — in closure, trail order,
//! and every counter (the incremental mode matches
//! [`super::rtac::RtacNative::incremental`]'s support-check count
//! instead of the dense one), for every worker count and spawn mode
//! (asserted by the property suite below).  Two design choices make
//! this hold:
//!
//! 1. Workers always complete their full chunk from the shared
//!    snapshot; the wipeout flag is consulted only *between* sweeps.
//!    Aborting mid-sweep would save a little work but make the victim
//!    (and the trail) depend on thread scheduling.
//! 2. Removals are replayed into the search [`State`] by the
//!    coordinator thread after the barrier, in ascending (variable,
//!    value) order — exactly the order the sequential dense sweep
//!    produces — so `pop_level` restores identically and `dom/wdeg`
//!    heuristics see the same victims.
//!
//! On a *wipeout* sweep the replay deliberately stops at the victim
//! (the sequential engine finishes applying that sweep's removals),
//! so `removals` and the trail tail differ there: the search pops the
//! level immediately, making the extra removals pure overhead.  Do not
//! compare removal counts across the family on wipeout paths.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::ac::rtac::{expand_affected, revise_var_fused};
use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{DomainPlane, PlaneChunk, Problem, State, VarId};
use crate::exec::WorkerPool;
use crate::util::bitset::{ones_in_range, tail_mask, words_for};
use crate::util::simd::{self, Isa};

/// Result of one worker's chunk revision.  Pooled on the engine
/// (`out_pool`) and reused across sweeps — a sweep pops one per chunk,
/// the barrier merge pushes them back.
#[derive(Default)]
struct ChunkOut {
    /// Changed-variable bitset over the whole network (`words_for(n)`
    /// words); a worker only ever sets bits in its chunk's var range.
    changed_bits: Vec<u64>,
    support_checks: u64,
}

impl ChunkOut {
    /// Make the scratch ready for a sweep over an `n_words`-word
    /// changed bitset.
    fn reset(&mut self, n_words: usize) {
        self.changed_bits.clear();
        self.changed_bits.resize(n_words, 0);
        self.support_checks = 0;
    }
}

/// Shared read-only context of one parallel sweep, passed to every
/// chunk task (bundling it keeps [`RtacParallel::revise_chunk`]'s
/// signature small).
#[derive(Clone, Copy)]
struct SweepCtx<'a> {
    isa: Isa,
    problem: &'a Problem,
    /// The sweep k−1 snapshot plane.
    cur: &'a DomainPlane,
    wipeout: &'a AtomicBool,
    /// Prop.-2 candidate bitset (incremental mode only).
    affected: Option<&'a [u64]>,
}

/// How sweep tasks reach the worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SpawnMode {
    /// Persistent [`WorkerPool`], spawned once per engine (default).
    Pooled,
    /// Per-sweep `std::thread::scope` — the pre-pool behaviour, kept
    /// only as the bench baseline for spawn-overhead amortisation.
    Scoped,
}

/// The thread-parallel recurrent engine (dense sweeps, or Prop.-2
/// incremental via [`RtacParallel::incremental`]).
pub struct RtacParallel {
    /// Requested worker count; 0 = auto (available parallelism, scaled
    /// down for small networks where per-sweep coordination dominates).
    workers: usize,
    incremental: bool,
    spawn: SpawnMode,
    pool: Option<WorkerPool>,
    cur: DomainPlane,
    next: DomainPlane,
    chunks: Vec<PlaneChunk>,
    /// Worker count the current `chunks` were planned for.
    planned_workers: usize,
    /// Vars whose domain changed in the previous sweep — the OR-merge
    /// of the per-chunk changed bitsets (`words_for(n)` words).  Both
    /// the trail-replay set and, in incremental mode, the paper's
    /// `@changed` seed for the next sweep.
    changed_bits: Vec<u64>,
    /// Prop.-2 candidate bitset for the coming sweep, expanded from
    /// `changed_bits`; workers read it immutably.
    affected_bits: Vec<u64>,
    /// Reusable per-worker [`ChunkOut`] scratch: popped per sweep,
    /// pushed back at the barrier merge (before any wipeout return).
    out_pool: Vec<ChunkOut>,
}

impl RtacParallel {
    /// Dense sweeps on the persistent pool.  `workers == 0` picks a
    /// count automatically; an explicit count is honoured exactly (the
    /// property tests rely on that).
    pub fn new(workers: usize) -> RtacParallel {
        Self::with_mode(workers, false, SpawnMode::Pooled)
    }

    /// Prop.-2 incremental candidate set on the persistent pool
    /// (`rtac-par-inc`).
    pub fn incremental(workers: usize) -> RtacParallel {
        Self::with_mode(workers, true, SpawnMode::Pooled)
    }

    /// Dense sweeps with per-sweep scoped spawning — the bench baseline
    /// the pool amortises away (`rtac-par-scoped`).
    pub fn scoped_spawn(workers: usize) -> RtacParallel {
        Self::with_mode(workers, false, SpawnMode::Scoped)
    }

    fn with_mode(workers: usize, incremental: bool, spawn: SpawnMode) -> RtacParallel {
        simd::announce_isa_once();
        RtacParallel {
            workers,
            incremental,
            spawn,
            pool: None,
            cur: DomainPlane::empty(),
            next: DomainPlane::empty(),
            chunks: Vec::new(),
            planned_workers: 0,
            changed_bits: Vec::new(),
            affected_bits: Vec::new(),
            out_pool: Vec::new(),
        }
    }

    /// Worker count for an `n`-variable network.
    fn effective_workers(&self, n: usize) -> usize {
        if self.workers > 0 {
            return self.workers.max(1);
        }
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        // auto mode: at least ~16 variables per worker, else the
        // per-sweep coordination costs more than the sweep
        hw.min((n / 16).max(1))
    }

    fn ensure_planes(&mut self, state: &State) {
        let n = state.n_vars();
        let k = self.effective_workers(n);
        if !self.cur.same_layout(state.plane()) {
            self.cur = state.plane().clone();
            self.next = state.plane().clone();
            self.chunks = self.cur.partition(k);
            self.planned_workers = k;
        } else if self.planned_workers != k {
            self.chunks = self.cur.partition(k);
            self.planned_workers = k;
        }
        // The pool outlives plane re-plans, resets and problem changes:
        // it is only (re)spawned when the worker count itself changes.
        if self.spawn == SpawnMode::Pooled && k > 1 {
            let need = match &self.pool {
                Some(p) => p.size() != k,
                None => true,
            };
            if need {
                self.pool = Some(WorkerPool::new(k));
            }
        }
    }

    /// Revise every variable of `chunk` against the `ctx.cur` snapshot,
    /// clearing unsupported bits in `slice` (the chunk's disjoint window
    /// of the next plane) a 64-value word at a time via
    /// [`revise_var_fused`].  In incremental mode only variables set in
    /// the `ctx.affected` bitset are re-checked — walked word-parallel
    /// within the chunk's range by [`ones_in_range`].  Pure function of
    /// the snapshot — safe to run on any thread.
    fn revise_chunk(
        ctx: SweepCtx<'_>,
        chunk: PlaneChunk,
        slice: &mut [u64],
        mut out: ChunkOut,
    ) -> ChunkOut {
        let mut revise_one = |x: VarId, slice: &mut [u64], out: &mut ChunkOut| {
            let base = ctx.cur.offset(x) - chunk.word_start;
            let (x_changed, x_wiped) = revise_var_fused(
                ctx.isa,
                ctx.problem,
                ctx.cur,
                x,
                &mut out.support_checks,
                |wi, _alive, still| slice[base + wi] = still,
            );
            if x_changed {
                out.changed_bits[x / 64] |= 1u64 << (x % 64);
                if x_wiped {
                    ctx.wipeout.store(true, Ordering::Relaxed);
                }
            }
        };
        match ctx.affected {
            Some(aff) => {
                for x in ones_in_range(aff, chunk.var_start, chunk.var_end) {
                    revise_one(x, slice, &mut out);
                }
            }
            None => {
                for x in chunk.var_start..chunk.var_end {
                    revise_one(x, slice, &mut out);
                }
            }
        }
        out
    }

    /// One parallel Jacobi sweep: `next := revise(cur)`.  Returns the
    /// per-chunk outputs in chunk (= ascending variable) order.
    fn sweep(&mut self, isa: Isa, problem: &Problem, wipeout: &AtomicBool) -> Vec<ChunkOut> {
        self.next.copy_words_from(&self.cur);
        let n_words = words_for(self.cur.n_vars());
        let cur = &self.cur;
        let chunks = &self.chunks;
        let affected: Option<&[u64]> =
            if self.incremental { Some(self.affected_bits.as_slice()) } else { None };
        let ctx = SweepCtx { isa, problem, cur, wipeout, affected };
        let slices = split_windows(self.next.words_mut(), chunks);
        // Empty chunks (more workers than variables) revise nothing:
        // don't pay a task submission for them.
        let work: Vec<(PlaneChunk, &mut [u64])> = chunks
            .iter()
            .copied()
            .zip(slices)
            .filter(|(c, _)| !c.is_empty())
            .collect();

        // One pooled scratch per task, reset for this sweep (allocates
        // only until the pool has seen this many chunks at this size).
        let outs: Vec<ChunkOut> = work
            .iter()
            .map(|_| {
                let mut o = self.out_pool.pop().unwrap_or_default();
                o.reset(n_words);
                o
            })
            .collect();

        if work.len() <= 1 {
            // single (or no) worker: skip the threads entirely
            return work
                .into_iter()
                .zip(outs)
                .map(|((chunk, slice), out)| Self::revise_chunk(ctx, chunk, slice, out))
                .collect();
        }

        match self.spawn {
            SpawnMode::Pooled => {
                let pool = self.pool.as_mut().expect("pool sized in ensure_planes");
                let tasks: Vec<_> = work
                    .into_iter()
                    .zip(outs)
                    .map(|((chunk, slice), out)| {
                        move || Self::revise_chunk(ctx, chunk, slice, out)
                    })
                    .collect();
                pool.run_collect(tasks)
            }
            // lint:allow(thread-placement): the Scoped mode IS the bench
            // baseline quantifying what the WorkerPool saves — it must
            // keep spawning per sweep to stay a fair comparison.
            SpawnMode::Scoped => std::thread::scope(|scope| {
                let handles: Vec<_> = work
                    .into_iter()
                    .zip(outs)
                    .map(|((chunk, slice), out)| {
                        scope.spawn(move || Self::revise_chunk(ctx, chunk, slice, out))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
            }),
        }
    }
}

/// Split a plane's word buffer into per-chunk disjoint mutable windows
/// (`chunks` are contiguous and ordered, so this is a straight
/// `split_at_mut` walk).
fn split_windows<'a>(mut words: &'a mut [u64], chunks: &[PlaneChunk]) -> Vec<&'a mut [u64]> {
    let mut out = Vec::with_capacity(chunks.len());
    let mut consumed = 0usize;
    for c in chunks {
        let (head, tail) = words.split_at_mut(c.word_end - consumed);
        out.push(head);
        words = tail;
        consumed = c.word_end;
    }
    out
}

impl Propagator for RtacParallel {
    fn name(&self) -> &'static str {
        match (self.incremental, self.spawn) {
            (true, _) => "rtac-par-inc",
            (false, SpawnMode::Pooled) => "rtac-par",
            (false, SpawnMode::Scoped) => "rtac-par-scoped",
        }
    }

    fn reset(&mut self, _problem: &Problem) {
        // force a re-plan on the next enforce (worker count may differ
        // between problems in auto mode) — but KEEP the worker pool and
        // the ChunkOut scratch pool: surviving reset is the whole point
        // of the persistent runtime (MAC calls reset once per solve,
        // then enforces per node; the scratch resizes itself per sweep).
        self.cur = DomainPlane::empty();
        self.next = DomainPlane::empty();
        self.chunks.clear();
        self.planned_workers = 0;
        self.changed_bits.clear();
        self.affected_bits.clear();
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId], // dense recurrence ignores this; incremental seeds from it
        counters: &mut Counters,
    ) -> Outcome {
        let n = problem.n_vars();
        let n_words = words_for(n);
        let isa = simd::active_isa();
        self.ensure_planes(state);
        self.cur.copy_words_from(state.plane());
        if self.changed_bits.len() != n_words {
            self.changed_bits = vec![0; n_words];
            self.affected_bits = vec![0; n_words];
        }
        if self.incremental {
            // Seed the changed set: the paper's initial `@changed`
            // queue, exactly as RtacNative::incremental seeds it.
            simd::zero_words(isa, &mut self.changed_bits);
            if touched.is_empty() {
                for (wi, w) in self.changed_bits.iter_mut().enumerate() {
                    *w = if wi == n_words - 1 { tail_mask(n) } else { !0u64 };
                }
            } else {
                for &v in touched {
                    self.changed_bits[v / 64] |= 1u64 << (v % 64);
                }
            }
        }
        loop {
            counters.recurrences += 1;
            if self.incremental {
                expand_affected(isa, problem, &self.changed_bits, &mut self.affected_bits);
            }
            let wipeout = AtomicBool::new(false);
            let outs = self.sweep(isa, problem, &wipeout);
            let wiped_somewhere = wipeout.load(Ordering::Relaxed);

            // Merge at the barrier, in chunk order.  All support checks
            // were performed regardless of where a wipeout lands, so
            // account for every chunk before the replay can early-out.
            counters.support_checks += outs.iter().map(|o| o.support_checks).sum::<u64>();
            // OR-merge the per-chunk changed bitsets (word-parallel) and
            // hand the scratch back to the pool — before the replay, so
            // a wipeout early-return cannot leak the buffers.  The
            // merged set is both the replay set and, in incremental
            // mode, the next sweep's `@changed`.
            simd::zero_words(isa, &mut self.changed_bits);
            for out in outs {
                simd::or_words(isa, &mut self.changed_bits, &out.changed_bits);
                self.out_pool.push(out);
            }
            // Trail replay in ascending (var, value) order — identical
            // to the sequential dense sweep's removal order.
            let mut any_changed = false;
            for wi in 0..n_words {
                let mut word = self.changed_bits[wi];
                while word != 0 {
                    let x = wi * 64 + word.trailing_zeros() as usize;
                    word &= word - 1;
                    any_changed = true;
                    let range = self.cur.word_range(x);
                    let cur_row = &self.cur.words()[range.clone()];
                    let next_row = &self.next.words()[range];
                    for (vw, (&c, &nx)) in cur_row.iter().zip(next_row).enumerate() {
                        let mut removed = c & !nx;
                        while removed != 0 {
                            let b = removed.trailing_zeros() as usize;
                            removed &= removed - 1;
                            state.remove(x, vw * 64 + b);
                            counters.removals += 1;
                        }
                    }
                    if wiped_somewhere && simd::row_delta(isa, cur_row, next_row).wiped {
                        // first wiped variable in ascending order: the
                        // same victim the sequential sweep reports.
                        // Later removals are not replayed — the search
                        // pops this level immediately.
                        return Outcome::Wipeout(x);
                    }
                }
            }
            if !any_changed {
                return Outcome::Consistent;
            }
            std::mem::swap(&mut self.cur, &mut self.next);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::rtac::RtacNative;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::gen::{pigeonhole, queens};
    use crate::util::quickcheck::forall;

    fn enforce_with(
        engine: &mut dyn Propagator,
        p: &Problem,
        touched: &[VarId],
    ) -> (Outcome, State, Counters) {
        let mut s = State::new(p);
        let mut c = Counters::default();
        let out = engine.enforce(p, &mut s, touched, &mut c);
        (out, s, c)
    }

    #[test]
    fn bit_identical_to_dense_across_worker_counts() {
        // The tentpole contract: closures, outcomes (victims included)
        // and #Recurrence identical to RtacNative::dense() for 1, 2 and
        // 4 workers on random CSPs.
        forall("rtac-par-vs-dense", 0x9A2, 32, |rng| {
            let spec = RandomSpec::new(
                2 + rng.gen_range(16),
                1 + rng.gen_range(8),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let (o_ref, s_ref, c_ref) = enforce_with(&mut RtacNative::dense(), &p, &[]);
            for workers in [1usize, 2, 4] {
                let (o, s, c) = enforce_with(&mut RtacParallel::new(workers), &p, &[]);
                if o != o_ref {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_ref:?} on {spec:?}"));
                }
                if c.recurrences != c_ref.recurrences {
                    return Err(format!(
                        "{workers}w: {} recurrences vs {} on {spec:?}",
                        c.recurrences, c_ref.recurrences
                    ));
                }
                if o_ref.is_consistent() && s.snapshot() != s_ref.snapshot() {
                    return Err(format!("{workers}w: closure mismatch on {spec:?}"));
                }
                if o_ref.is_consistent()
                    && (c.removals != c_ref.removals || c.support_checks != c_ref.support_checks)
                {
                    return Err(format!("{workers}w: counter mismatch on {spec:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_parallel_bit_identical_to_both_sequential_modes() {
        // rtac-par-inc must match dense in closure/outcome/#Recurrence
        // and rtac-inc in support-check count (same candidate sets).
        forall("rtac-par-inc-vs-seq", 0x1AC, 24, |rng| {
            let spec = RandomSpec::new(
                2 + rng.gen_range(14),
                1 + rng.gen_range(8),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let (o_dense, s_dense, c_dense) = enforce_with(&mut RtacNative::dense(), &p, &[]);
            let (_, _, c_inc) = enforce_with(&mut RtacNative::incremental(), &p, &[]);
            for workers in [1usize, 2, 4] {
                let (o, s, c) = enforce_with(&mut RtacParallel::incremental(workers), &p, &[]);
                if o != o_dense {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_dense:?} on {spec:?}"));
                }
                if c.recurrences != c_dense.recurrences {
                    return Err(format!(
                        "{workers}w: {} recurrences vs {} on {spec:?}",
                        c.recurrences, c_dense.recurrences
                    ));
                }
                if o_dense.is_consistent() {
                    if s.snapshot() != s_dense.snapshot() {
                        return Err(format!("{workers}w: closure mismatch on {spec:?}"));
                    }
                    if c.removals != c_dense.removals {
                        return Err(format!("{workers}w: removal count mismatch on {spec:?}"));
                    }
                    if c.support_checks != c_inc.support_checks {
                        return Err(format!(
                            "{workers}w: {} support checks vs rtac-inc's {} on {spec:?}",
                            c.support_checks, c_inc.support_checks
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scoped_and_pooled_spawn_modes_identical() {
        // the bench baseline must stay bit-identical to the pooled
        // engine — only the spawn mechanism differs.
        forall("rtac-par-scoped-vs-pooled", 0x5C0, 16, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(12),
                2 + rng.gen_range(6),
                rng.next_f64(),
                rng.next_f64() * 0.8,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let (o1, s1, c1) = enforce_with(&mut RtacParallel::new(3), &p, &[]);
            let (o2, s2, c2) = enforce_with(&mut RtacParallel::scoped_spawn(3), &p, &[]);
            if o1 != o2 || c1.recurrences != c2.recurrences {
                return Err(format!("spawn modes diverge on {spec:?}"));
            }
            if o1.is_consistent() && (s1.snapshot() != s2.snapshot() || c1 != c2) {
                return Err(format!("spawn-mode closure/counter mismatch on {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn trail_replay_order_matches_dense() {
        // Same removals in the same order => identical trail deltas.
        forall("rtac-par-trail-order", 0x7A11, 16, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(10),
                2 + rng.gen_range(6),
                0.3 + 0.7 * rng.next_f64(),
                0.6 * rng.next_f64(),
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let run = |engine: &mut dyn Propagator| {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let mark = s.trail_len();
                let out = engine.enforce(&p, &mut s, &[], &mut c);
                (out.is_consistent(), s.removals_since(mark).to_vec())
            };
            let (ok_ref, trail_ref) = run(&mut RtacNative::dense());
            for engine in [&mut RtacParallel::new(3), &mut RtacParallel::incremental(3)] {
                let (ok_par, trail_par) = run(engine);
                if ok_ref != ok_par {
                    return Err(format!("outcome mismatch on {spec:?}"));
                }
                if ok_ref && trail_ref != trail_par {
                    return Err(format!("trail order mismatch on {spec:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn plane_arena_state_survives_push_pop_around_parallel_enforce() {
        // Trail/backtrack invariants on the plane-arena State with the
        // parallel engine in the loop: pop_level must restore bit-exact.
        let p = queens(8);
        let mut engine = RtacParallel::new(4);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        assert!(engine.enforce(&p, &mut s, &[], &mut c).is_consistent());
        let before = s.snapshot();
        for col in 0..4 {
            s.push_level();
            s.assign(0, col);
            let _ = engine.enforce(&p, &mut s, &[0], &mut c);
            s.pop_level();
            assert_eq!(s.snapshot(), before, "column {col} leaked removals");
        }
    }

    #[test]
    fn wipeout_victim_matches_dense() {
        let p = pigeonhole(5, 4);
        let prep = |s: &mut State| {
            s.assign(0, 0);
            s.assign(1, 1);
            s.assign(2, 2);
            s.assign(3, 3);
        };
        let mut s1 = State::new(&p);
        prep(&mut s1);
        let mut c1 = Counters::default();
        let o1 = RtacNative::dense().enforce(&p, &mut s1, &[], &mut c1);
        for workers in [1usize, 2, 4] {
            for engine in
                [&mut RtacParallel::new(workers), &mut RtacParallel::incremental(workers)]
            {
                let mut s2 = State::new(&p);
                prep(&mut s2);
                let mut c2 = Counters::default();
                let o2 = engine.enforce(&p, &mut s2, &[], &mut c2);
                assert_eq!(o1, o2, "{workers} workers ({})", engine.name());
                assert_eq!(c1.recurrences, c2.recurrences, "{workers} workers");
            }
        }
        assert!(matches!(o1, Outcome::Wipeout(_)));
    }

    #[test]
    fn engine_reuse_across_different_problems() {
        // layouts differ (n and widths), planes must re-plan cleanly
        // while the pool survives the transitions
        let mut engine = RtacParallel::new(2);
        for p in [queens(5), pigeonhole(6, 5), queens(9)] {
            let (o, s, _) = {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = engine.enforce(&p, &mut s, &[], &mut c);
                (o, s, c)
            };
            let (o_ref, s_ref, _) = {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = RtacNative::dense().enforce(&p, &mut s, &[], &mut c);
                (o, s, c)
            };
            assert_eq!(o, o_ref, "{}", p.name());
            if o.is_consistent() {
                assert_eq!(s.snapshot(), s_ref.snapshot(), "{}", p.name());
            }
        }
    }

    #[test]
    fn pooled_back_to_back_enforcements_bit_identical_to_rtac() {
        // Satellite contract: ONE pool, many consecutive enforcements
        // (the MAC pattern — root + per-assignment calls + resets) must
        // stay bit-identical to a fresh sequential dense engine each
        // time.
        let p = queens(8);
        let mut engine = RtacParallel::new(3);
        for round in 0..3 {
            // root enforcement
            let (o, s, c) = {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = engine.enforce(&p, &mut s, &[], &mut c);
                (o, s, c)
            };
            let (o_ref, s_ref, c_ref) = {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = RtacNative::dense().enforce(&p, &mut s, &[], &mut c);
                (o, s, c)
            };
            assert_eq!(o, o_ref, "round {round}");
            assert_eq!(s.snapshot(), s_ref.snapshot(), "round {round}");
            assert_eq!(c, c_ref, "round {round}");
            // assignment-shaped follow-up enforcements on a shared state
            let mut sp = State::new(&p);
            let mut sq = State::new(&p);
            let mut cp = Counters::default();
            let mut cq = Counters::default();
            let mut fresh = RtacNative::dense();
            assert!(engine.enforce(&p, &mut sp, &[], &mut cp).is_consistent());
            assert!(fresh.enforce(&p, &mut sq, &[], &mut cq).is_consistent());
            for col in [0usize, 3, 6] {
                sp.push_level();
                sq.push_level();
                sp.assign(0, col);
                sq.assign(0, col);
                let op = engine.enforce(&p, &mut sp, &[0], &mut cp);
                let oq = fresh.enforce(&p, &mut sq, &[0], &mut cq);
                assert_eq!(op, oq, "round {round} col {col}");
                if op.is_consistent() {
                    assert_eq!(sp.snapshot(), sq.snapshot(), "round {round} col {col}");
                }
                sp.pop_level();
                sq.pop_level();
            }
            engine.reset(&p); // MAC resets between solves; pool survives
        }
    }

    #[test]
    fn auto_mode_scales_workers_down_for_tiny_networks() {
        let engine = RtacParallel::new(0);
        assert_eq!(engine.effective_workers(4), 1);
        let k = engine.effective_workers(10_000);
        assert!(k >= 1);
        let explicit = RtacParallel::new(7);
        assert_eq!(explicit.effective_workers(4), 7);
    }
}
