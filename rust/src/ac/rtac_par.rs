//! `RtacParallel` — word-parallel AND thread-parallel RTAC sweeps.
//!
//! The paper's core claim is that each recurrence of Eq. 1 is *fully
//! parallelizable*: every (variable, value) support test of sweep k
//! reads only the sweep k−1 snapshot.  This engine exploits that Jacobi
//! structure on CPU:
//!
//! * Domains live in the flat [`DomainPlane`] arena, double-buffered:
//!   `cur` holds the k−1 snapshot, `next` starts each sweep as a memcpy
//!   of `cur` and receives the sweep's removals as word-masked bit
//!   clears.
//! * Variables are partitioned into contiguous word ranges
//!   ([`DomainPlane::partition`]); a `std::thread::scope` spawns one
//!   worker per chunk, each owning a **disjoint `&mut [u64]` slice** of
//!   the next plane (`split_at_mut` — no locks, no atomics on the hot
//!   path).  Support tests stream the packed relation rows against the
//!   shared `cur` plane.
//! * Per-worker [`Counters`] and changed-variable lists are merged at
//!   sweep end, in chunk order, so every merged quantity is
//!   deterministic.  A shared wipeout [`AtomicBool`] lets the sweep
//!   loop abort further recurrences (and skip trail replay past the
//!   victim) the moment any worker wipes a domain.
//!
//! # Bit-identity contract
//!
//! `RtacParallel` is bit-identical to [`super::rtac::RtacNative::dense`]
//! in outcome (including the wipeout victim) and `#Recurrence` count
//! always, and — on consistent enforcements — in closure, trail order,
//! and every counter, for every worker count (asserted by the property
//! suite below).  Two design choices make this hold:
//!
//! 1. Workers always complete their full chunk from the shared
//!    snapshot; the wipeout flag is consulted only *between* sweeps.
//!    Aborting mid-sweep would save a little work but make the victim
//!    (and the trail) depend on thread scheduling.
//! 2. Removals are replayed into the search [`State`] by the
//!    coordinator thread after the join, in ascending (variable, value)
//!    order — exactly the order the sequential dense sweep produces —
//!    so `pop_level` restores identically and `dom/wdeg` heuristics see
//!    the same victims.
//!
//! On a *wipeout* sweep the replay deliberately stops at the victim
//! (the sequential engine finishes applying that sweep's removals),
//! so `removals` and the trail tail differ there: the search pops the
//! level immediately, making the extra removals pure overhead.  Do not
//! compare removal counts across the family on wipeout paths.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{DomainPlane, PlaneChunk, Problem, State, VarId};

/// Result of one worker's chunk revision.
#[derive(Default)]
struct ChunkOut {
    /// Chunk-local changed variables, ascending.
    changed: Vec<VarId>,
    support_checks: u64,
}

/// The thread-parallel recurrent engine (dense sweeps only — the
/// incremental candidate set is inherently sequential bookkeeping; see
/// [`super::rtac::RtacNative::incremental`] for Prop. 2).
pub struct RtacParallel {
    /// Requested worker count; 0 = auto (available parallelism, scaled
    /// down for small networks where spawn overhead would dominate).
    workers: usize,
    cur: DomainPlane,
    next: DomainPlane,
    chunks: Vec<PlaneChunk>,
    /// Worker count the current `chunks` were planned for.
    planned_workers: usize,
}

impl RtacParallel {
    /// `workers == 0` picks a count automatically; an explicit count is
    /// honoured exactly (the property tests rely on that).
    pub fn new(workers: usize) -> RtacParallel {
        RtacParallel {
            workers,
            cur: DomainPlane::empty(),
            next: DomainPlane::empty(),
            chunks: Vec::new(),
            planned_workers: 0,
        }
    }

    /// Worker count for an `n`-variable network.
    fn effective_workers(&self, n: usize) -> usize {
        if self.workers > 0 {
            return self.workers.max(1);
        }
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        // auto mode: at least ~16 variables per worker, else the scoped
        // spawns cost more than the sweep
        hw.min((n / 16).max(1))
    }

    fn ensure_planes(&mut self, state: &State) {
        let n = state.n_vars();
        let k = self.effective_workers(n);
        if !self.cur.same_layout(state.plane()) {
            self.cur = state.plane().clone();
            self.next = state.plane().clone();
            self.chunks = self.cur.partition(k);
            self.planned_workers = k;
        } else if self.planned_workers != k {
            self.chunks = self.cur.partition(k);
            self.planned_workers = k;
        }
    }

    /// Revise every variable of `chunk` against the `cur` snapshot,
    /// clearing unsupported bits in `slice` (the chunk's disjoint window
    /// of the next plane).  Pure function of the snapshot — safe to run
    /// on any thread.
    fn revise_chunk(
        problem: &Problem,
        cur: &DomainPlane,
        chunk: PlaneChunk,
        slice: &mut [u64],
        wipeout: &AtomicBool,
    ) -> ChunkOut {
        let mut out = ChunkOut::default();
        for x in chunk.var_start..chunk.var_end {
            let base = cur.offset(x) - chunk.word_start;
            let mut x_changed = false;
            'vals: for a in cur.bits(x).iter_ones() {
                for &arc in problem.arcs_of(x) {
                    out.support_checks += 1;
                    let other = problem.arc_other(arc);
                    if !problem.arc_support_row(arc, a).intersects(cur.bits(other)) {
                        slice[base + a / 64] &= !(1u64 << (a % 64));
                        x_changed = true;
                        continue 'vals;
                    }
                }
            }
            if x_changed {
                out.changed.push(x);
                let row = &slice[base..base + cur.word_range(x).len()];
                if row.iter().all(|&w| w == 0) {
                    wipeout.store(true, Ordering::Relaxed);
                }
            }
        }
        out
    }

    /// One parallel Jacobi sweep: `next := revise(cur)`.  Returns the
    /// per-chunk outputs in chunk (= ascending variable) order.
    fn sweep(&mut self, problem: &Problem, wipeout: &AtomicBool) -> Vec<ChunkOut> {
        self.next.copy_words_from(&self.cur);
        let cur = &self.cur;
        let chunks = &self.chunks;
        let slices = split_windows(self.next.words_mut(), chunks);
        // Empty chunks (more workers than variables) revise nothing:
        // don't pay a thread spawn for them.
        let work: Vec<(PlaneChunk, &mut [u64])> = chunks
            .iter()
            .copied()
            .zip(slices)
            .filter(|(c, _)| !c.is_empty())
            .collect();

        if work.len() <= 1 {
            // single (or no) worker: skip the thread scope entirely
            return work
                .into_iter()
                .map(|(chunk, slice)| Self::revise_chunk(problem, cur, chunk, slice, wipeout))
                .collect();
        }

        std::thread::scope(|scope| {
            let handles: Vec<_> = work
                .into_iter()
                .map(|(chunk, slice)| {
                    scope.spawn(move || {
                        Self::revise_chunk(problem, cur, chunk, slice, wipeout)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
        })
    }
}

/// Split a plane's word buffer into per-chunk disjoint mutable windows
/// (`chunks` are contiguous and ordered, so this is a straight
/// `split_at_mut` walk).
fn split_windows<'a>(mut words: &'a mut [u64], chunks: &[PlaneChunk]) -> Vec<&'a mut [u64]> {
    let mut out = Vec::with_capacity(chunks.len());
    let mut consumed = 0usize;
    for c in chunks {
        let (head, tail) = words.split_at_mut(c.word_end - consumed);
        out.push(head);
        words = tail;
        consumed = c.word_end;
    }
    out
}

impl Propagator for RtacParallel {
    fn name(&self) -> &'static str {
        "rtac-par"
    }

    fn reset(&mut self, _problem: &Problem) {
        // force a re-plan on the next enforce (worker count may differ
        // between problems in auto mode)
        self.cur = DomainPlane::empty();
        self.next = DomainPlane::empty();
        self.chunks.clear();
        self.planned_workers = 0;
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId], // dense recurrence: the whole plane each sweep
        counters: &mut Counters,
    ) -> Outcome {
        self.ensure_planes(state);
        self.cur.copy_words_from(state.plane());
        loop {
            counters.recurrences += 1;
            let wipeout = AtomicBool::new(false);
            let outs = self.sweep(problem, &wipeout);
            let wiped_somewhere = wipeout.load(Ordering::Relaxed);

            // Merge at sweep end, in chunk order.  All support checks
            // were performed regardless of where a wipeout lands, so
            // account for every chunk before the replay can early-out.
            counters.support_checks += outs.iter().map(|o| o.support_checks).sum::<u64>();
            // Trail replay in ascending (var, value) order — identical
            // to the sequential dense sweep's removal order.
            let mut any_changed = false;
            for out in &outs {
                for &x in &out.changed {
                    any_changed = true;
                    for a in self.cur.bits(x).iter_ones() {
                        if !self.next.get(x, a) {
                            state.remove(x, a);
                            counters.removals += 1;
                        }
                    }
                    if wiped_somewhere && state.wiped(x) {
                        // first wiped variable in ascending order: the
                        // same victim the sequential sweep reports.
                        // Later chunks' removals are not replayed — the
                        // search pops this level immediately.
                        return Outcome::Wipeout(x);
                    }
                }
            }
            if !any_changed {
                return Outcome::Consistent;
            }
            std::mem::swap(&mut self.cur, &mut self.next);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::rtac::RtacNative;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::gen::{pigeonhole, queens};
    use crate::util::quickcheck::forall;

    fn enforce_with(
        engine: &mut dyn Propagator,
        p: &Problem,
        touched: &[VarId],
    ) -> (Outcome, State, Counters) {
        let mut s = State::new(p);
        let mut c = Counters::default();
        let out = engine.enforce(p, &mut s, touched, &mut c);
        (out, s, c)
    }

    #[test]
    fn bit_identical_to_dense_across_worker_counts() {
        // The tentpole contract: closures, outcomes (victims included)
        // and #Recurrence identical to RtacNative::dense() for 1, 2 and
        // 4 workers on random CSPs.
        forall("rtac-par-vs-dense", 0x9A2, 32, |rng| {
            let spec = RandomSpec::new(
                2 + rng.gen_range(16),
                1 + rng.gen_range(8),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let (o_ref, s_ref, c_ref) = enforce_with(&mut RtacNative::dense(), &p, &[]);
            for workers in [1usize, 2, 4] {
                let (o, s, c) = enforce_with(&mut RtacParallel::new(workers), &p, &[]);
                if o != o_ref {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_ref:?} on {spec:?}"));
                }
                if c.recurrences != c_ref.recurrences {
                    return Err(format!(
                        "{workers}w: {} recurrences vs {} on {spec:?}",
                        c.recurrences, c_ref.recurrences
                    ));
                }
                if o_ref.is_consistent() && s.snapshot() != s_ref.snapshot() {
                    return Err(format!("{workers}w: closure mismatch on {spec:?}"));
                }
                if o_ref.is_consistent()
                    && (c.removals != c_ref.removals || c.support_checks != c_ref.support_checks)
                {
                    return Err(format!("{workers}w: counter mismatch on {spec:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trail_replay_order_matches_dense() {
        // Same removals in the same order => identical trail deltas.
        forall("rtac-par-trail-order", 0x7A11, 16, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(10),
                2 + rng.gen_range(6),
                0.3 + 0.7 * rng.next_f64(),
                0.6 * rng.next_f64(),
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let run = |engine: &mut dyn Propagator| {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let mark = s.trail_len();
                let out = engine.enforce(&p, &mut s, &[], &mut c);
                (out.is_consistent(), s.removals_since(mark).to_vec())
            };
            let (ok_ref, trail_ref) = run(&mut RtacNative::dense());
            let (ok_par, trail_par) = run(&mut RtacParallel::new(3));
            if ok_ref != ok_par {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if ok_ref && trail_ref != trail_par {
                return Err(format!("trail order mismatch on {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn plane_arena_state_survives_push_pop_around_parallel_enforce() {
        // Trail/backtrack invariants on the plane-arena State with the
        // parallel engine in the loop: pop_level must restore bit-exact.
        let p = queens(8);
        let mut engine = RtacParallel::new(4);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        assert!(engine.enforce(&p, &mut s, &[], &mut c).is_consistent());
        let before = s.snapshot();
        for col in 0..4 {
            s.push_level();
            s.assign(0, col);
            let _ = engine.enforce(&p, &mut s, &[0], &mut c);
            s.pop_level();
            assert_eq!(s.snapshot(), before, "column {col} leaked removals");
        }
    }

    #[test]
    fn wipeout_victim_matches_dense() {
        let p = pigeonhole(5, 4);
        let prep = |s: &mut State| {
            s.assign(0, 0);
            s.assign(1, 1);
            s.assign(2, 2);
            s.assign(3, 3);
        };
        let mut s1 = State::new(&p);
        prep(&mut s1);
        let mut c1 = Counters::default();
        let o1 = RtacNative::dense().enforce(&p, &mut s1, &[], &mut c1);
        for workers in [1usize, 2, 4] {
            let mut s2 = State::new(&p);
            prep(&mut s2);
            let mut c2 = Counters::default();
            let o2 = RtacParallel::new(workers).enforce(&p, &mut s2, &[], &mut c2);
            assert_eq!(o1, o2, "{workers} workers");
            assert_eq!(c1.recurrences, c2.recurrences, "{workers} workers");
        }
        assert!(matches!(o1, Outcome::Wipeout(_)));
    }

    #[test]
    fn engine_reuse_across_different_problems() {
        // layouts differ (n and widths), planes must re-plan cleanly
        let mut engine = RtacParallel::new(2);
        for p in [queens(5), pigeonhole(6, 5), queens(9)] {
            let (o, s, _) = {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = engine.enforce(&p, &mut s, &[], &mut c);
                (o, s, c)
            };
            let (o_ref, s_ref, _) = {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = RtacNative::dense().enforce(&p, &mut s, &[], &mut c);
                (o, s, c)
            };
            assert_eq!(o, o_ref, "{}", p.name());
            if o.is_consistent() {
                assert_eq!(s.snapshot(), s_ref.snapshot(), "{}", p.name());
            }
        }
    }

    #[test]
    fn auto_mode_scales_workers_down_for_tiny_networks() {
        let engine = RtacParallel::new(0);
        assert_eq!(engine.effective_workers(4), 1);
        let k = engine.effective_workers(10_000);
        assert!(k >= 1);
        let explicit = RtacParallel::new(7);
        assert_eq!(explicit.effective_workers(4), 7);
    }
}
