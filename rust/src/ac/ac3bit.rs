//! AC3^bit — AC-3 with bitwise support tests (Lecoutre & Vion 2008, [8]).
//!
//! Identical propagation structure to [`super::ac3::Ac3`] (FIFO queue),
//! but the per-value support scan is a single word-wise
//! `row & domain != 0` test instead of a value loop.  On dense domains
//! this is the strongest *sequential* baseline in the suite — exactly
//! the representational trick the paper generalises to tensors.

use std::collections::VecDeque;

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{Arc, Problem, State, VarId};

/// The bitwise AC-3 engine.
pub struct Ac3Bit {
    queue: VecDeque<Arc>,
    in_queue: Vec<bool>,
    vals_buf: Vec<usize>,
}

#[inline]
fn arc_id(a: Arc) -> usize {
    a.cons * 2 + a.is_x as usize
}

impl Ac3Bit {
    pub fn new() -> Ac3Bit {
        Ac3Bit { queue: VecDeque::new(), in_queue: Vec::new(), vals_buf: Vec::new() }
    }

    fn push(&mut self, a: Arc) {
        let id = arc_id(a);
        if !self.in_queue[id] {
            self.in_queue[id] = true;
            self.queue.push_back(a);
        }
    }

    fn revise(
        &mut self,
        problem: &Problem,
        state: &mut State,
        arc: Arc,
        counters: &mut Counters,
    ) -> (bool, bool) {
        counters.revisions += 1;
        let var = problem.arc_var(arc);
        let other = problem.arc_other(arc);
        self.vals_buf.clear();
        self.vals_buf.extend(state.dom(var).iter_ones());
        let vals = std::mem::take(&mut self.vals_buf);
        let mut changed = false;
        for &a in &vals {
            counters.support_checks += 1; // one bit-parallel test
            if !problem.arc_support_row(arc, a).intersects(state.dom(other)) {
                state.remove(var, a);
                counters.removals += 1;
                changed = true;
            }
        }
        self.vals_buf = vals;
        (changed, changed && state.wiped(var))
    }
}

impl Default for Ac3Bit {
    fn default() -> Self {
        Self::new()
    }
}

impl Propagator for Ac3Bit {
    fn name(&self) -> &'static str {
        "ac3bit"
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.queue.clear();
        self.in_queue.clear();
        self.in_queue.resize(problem.n_constraints() * 2, false);
        if touched.is_empty() {
            for a in problem.all_arcs() {
                self.push(a);
            }
        } else {
            for &v in touched {
                for &a in problem.arcs_of(v) {
                    self.push(Arc { cons: a.cons, is_x: !a.is_x });
                }
            }
        }
        while let Some(arc) = self.queue.pop_front() {
            self.in_queue[arc_id(arc)] = false;
            let (changed, wiped) = self.revise(problem, state, arc, counters);
            if wiped {
                return Outcome::Wipeout(problem.arc_var(arc));
            }
            if changed {
                let var = problem.arc_var(arc);
                let witness = problem.arc_other(arc);
                for &a in problem.arcs_of(var) {
                    let neighbour_arc = Arc { cons: a.cons, is_x: !a.is_x };
                    if problem.arc_var(neighbour_arc) != witness {
                        self.push(neighbour_arc);
                    }
                }
            }
        }
        Outcome::Consistent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3::{Ac3, QueueOrder};
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn matches_ac3_on_random_instances() {
        forall("ac3bit-vs-ac3", 0xB17, 20, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(10),
                1 + rng.gen_range(7),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c1 = Counters::default();
            let mut c2 = Counters::default();
            let o1 = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s1, &[], &mut c1);
            let o2 = Ac3Bit::new().enforce(&p, &mut s2, &[], &mut c2);
            if o1.is_consistent() != o2.is_consistent() {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if o1.is_consistent() && s1.snapshot() != s2.snapshot() {
                return Err(format!("closure mismatch on {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fewer_support_checks_than_scalar_ac3() {
        let p = random_csp(&RandomSpec::new(20, 12, 0.8, 0.4, 77));
        let mut s1 = State::new(&p);
        let mut s2 = State::new(&p);
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s1, &[], &mut c1);
        Ac3Bit::new().enforce(&p, &mut s2, &[], &mut c2);
        assert!(
            c2.support_checks < c1.support_checks,
            "bitwise {} vs scalar {}",
            c2.support_checks,
            c1.support_checks
        );
        // same queue discipline => identical revision counts
        assert_eq!(c1.revisions, c2.revisions);
    }

    #[test]
    fn wipeout_on_pigeonhole_after_assignments() {
        let p = crate::gen::pigeonhole(4, 3);
        let mut s = State::new(&p);
        s.assign(0, 0);
        s.assign(1, 1);
        s.assign(2, 2);
        let mut c = Counters::default();
        let out = Ac3Bit::new().enforce(&p, &mut s, &[0, 1, 2], &mut c);
        assert!(matches!(out, Outcome::Wipeout(_)));
    }
}
