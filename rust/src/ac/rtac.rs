//! Native RTAC — the paper's recurrent arc consistency (Eq. 1) as a CPU
//! engine, mirroring exactly what the tensor path computes.
//!
//! Each *recurrence* is a synchronous (Jacobi-style) sweep: supports are
//! tested against a **snapshot** of the domains taken at sweep start, so
//! every removal of sweep k is justified purely by the state after sweep
//! k−1 — precisely Eq. 1, and bit-for-bit the tensor model's
//! `while_loop` body.  The sweep count (`Counters::recurrences`) is the
//! paper's `#Recurrence` (Table 1) and is asserted equal to the XLA
//! executable's `iters` output by the runtime integration tests.
//!
//! Two variants:
//! * **dense** — every sweep re-checks every (variable, value): what the
//!   static-shape tensor artifact does.
//! * **incremental** — Prop. 2: sweep k only re-checks variables with a
//!   neighbour whose domain changed in sweep k−1 (the paper's
//!   `@changed` set).  Identical removals and sweep counts (asserted in
//!   tests), strictly less CPU work.
//!
//! Domains snapshot into a flat [`DomainPlane`] arena, so taking the
//! per-sweep snapshot is one memcpy over the whole network.  The sweep
//! itself is *fused*: `revise_var_fused` revises a 64-value window of
//! a variable's row per iteration through the runtime-dispatched word
//! kernels in [`crate::util::simd`] (AVX-512/AVX2/scalar), and the
//! Prop.-2 candidate set is expanded word-parallel from precomputed
//! adjacency bitsets (`expand_affected`) instead of per-var arc scans.
//! The thread-parallel variant of the same recurrence lives in
//! [`super::rtac_par`].

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{DomainPlane, Problem, State, VarId};
use crate::util::bitset::{tail_mask, words_for};
use crate::util::simd::{self, Isa};

/// Derive the Prop.-2 candidate set for a sweep, word-parallel: clear
/// `affected`, then OR in the precomputed neighbour bitset
/// ([`Problem::neighbor_words`]) of every variable whose domain changed
/// in the previous sweep.
///
/// Shared by every engine that implements the incremental recurrence
/// ([`RtacNative`], [`super::rtac_par::RtacParallel`], and the batched
/// SAC probe fixpoint in `super::sac`), so the candidate-set semantics
/// cannot silently diverge between them.  Both bitsets are
/// `words_for(n_vars)` words.
pub(crate) fn expand_affected(isa: Isa, problem: &Problem, changed: &[u64], affected: &mut [u64]) {
    simd::zero_words(isa, affected);
    let mut wi = 0usize;
    for &w in changed {
        let mut word = w;
        while word != 0 {
            let v = wi * 64 + word.trailing_zeros() as usize;
            word &= word - 1;
            simd::or_words(isa, affected, problem.neighbor_words(v));
        }
        wi += 1;
    }
}

/// Revise one variable against a domain snapshot with the fused word
/// kernels: for each 64-value window of `x`'s row, run the arc loop on
/// the whole window via [`simd::supported_mask`] — `still` starts as the
/// snapshot word and loses the bits an arc leaves unsupported, with the
/// classic early exit once it empties.
///
/// `support_checks` accounting is bit-compatible with the per-value
/// scalar loop: each arc adds `popcount(still)` *before* filtering, so a
/// value that fails at arc `j` contributes `j+1` checks and a survivor
/// contributes one per arc — exactly the scalar early-exit totals.
///
/// `sink(wi, alive, still)` is invoked for every window that changed
/// (`still != alive`), in ascending window order; the caller applies the
/// removals to its own buffer (trailed state, next-sweep plane slice, or
/// probe plane).  Returns `(changed, wiped)` — `wiped` means the row has
/// no surviving value, equivalent to a post-pass `is_wiped(x)` rescan
/// but computed in the same pass.
pub(crate) fn revise_var_fused(
    isa: Isa,
    problem: &Problem,
    snap: &DomainPlane,
    x: VarId,
    support_checks: &mut u64,
    mut sink: impl FnMut(usize, u64, u64),
) -> (bool, bool) {
    let arcs = problem.arcs_of(x);
    let width = snap.width(x);
    let words = snap.words();
    let mut x_changed = false;
    let mut any_alive = 0u64;
    for (wi, w) in snap.word_range(x).enumerate() {
        let alive = words[w];
        if alive == 0 {
            continue;
        }
        let mut still = alive;
        let base = wi * 64;
        let n_rows = (width - base).min(64);
        for &arc in arcs {
            *support_checks += still.count_ones() as u64;
            let (rows, rw) = problem.arc_support_rows(arc);
            let other = problem.arc_other(arc);
            let dom = &words[snap.word_range(other)];
            still = simd::supported_mask(
                isa,
                still,
                &rows[base * rw..(base + n_rows) * rw],
                rw,
                dom,
            );
            if still == 0 {
                break;
            }
        }
        any_alive |= still;
        if still != alive {
            x_changed = true;
            sink(wi, alive, still);
        }
    }
    (x_changed, x_changed && any_alive == 0)
}

/// The native recurrent engine.
pub struct RtacNative {
    incremental: bool,
    /// Flat domain-plane snapshot at sweep start: refreshed by a single
    /// memcpy from the state's arena (reused across calls).
    snapshot: DomainPlane,
    /// Vars whose domain changed in the previous sweep, as a
    /// `words_for(n)`-word bitset.
    changed_bits: Vec<u64>,
    /// Vars to re-check this sweep (incremental candidates), expanded
    /// word-parallel from `changed_bits` via the adjacency bitsets.
    affected_bits: Vec<u64>,
}

impl RtacNative {
    pub fn dense() -> RtacNative {
        Self::with_mode(false)
    }

    pub fn incremental() -> RtacNative {
        Self::with_mode(true)
    }

    fn with_mode(incremental: bool) -> RtacNative {
        simd::announce_isa_once();
        RtacNative {
            incremental,
            snapshot: DomainPlane::empty(),
            changed_bits: Vec::new(),
            affected_bits: Vec::new(),
        }
    }

    fn take_snapshot(&mut self, state: &State) {
        if self.snapshot.same_layout(state.plane()) {
            self.snapshot.copy_words_from(state.plane());
        } else {
            self.snapshot = state.plane().clone();
        }
    }

    /// One synchronous sweep.  Returns the first wiped variable, if any.
    ///
    /// The revise loop is [`revise_var_fused`] — shared verbatim with
    /// `super::rtac_par::RtacParallel::revise_chunk` and
    /// `super::sac::plane_fixpoint`, which differ only in their removal
    /// sinks (this one trails removals into the search state).
    fn sweep(
        &mut self,
        isa: Isa,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Option<VarId> {
        self.take_snapshot(state);
        let n = problem.n_vars();
        let nw = words_for(n);

        // Candidate set: in incremental mode, variables adjacent to a
        // change from the previous sweep; in dense mode, everyone.
        if self.incremental {
            expand_affected(isa, problem, &self.changed_bits, &mut self.affected_bits);
        }
        simd::zero_words(isa, &mut self.changed_bits);

        let Counters { support_checks, removals, .. } = counters;
        let mut wiped: Option<VarId> = None;
        for wi in 0..nw {
            let full = if wi == nw - 1 { tail_mask(n) } else { !0u64 };
            let mut word = if self.incremental { self.affected_bits[wi] } else { full };
            while word != 0 {
                let x = wi * 64 + word.trailing_zeros() as usize;
                word &= word - 1;
                let sink = |vw: usize, alive: u64, still: u64| {
                    let mut removed = alive & !still;
                    while removed != 0 {
                        let b = removed.trailing_zeros() as usize;
                        removed &= removed - 1;
                        state.remove(x, vw * 64 + b);
                        *removals += 1;
                    }
                };
                let (x_changed, x_wiped) =
                    revise_var_fused(isa, problem, &self.snapshot, x, support_checks, sink);
                if x_changed {
                    self.changed_bits[wi] |= 1u64 << (x % 64);
                    if x_wiped {
                        wiped = wiped.or(Some(x));
                    }
                }
            }
        }
        wiped
    }
}

impl Propagator for RtacNative {
    fn name(&self) -> &'static str {
        if self.incremental {
            "rtac-inc"
        } else {
            "rtac"
        }
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        let n = problem.n_vars();
        let nw = words_for(n);
        let isa = simd::active_isa();
        if self.changed_bits.len() != nw {
            self.changed_bits = vec![0; nw];
            self.affected_bits = vec![0; nw];
        }
        // Seed the changed set: the paper's initial `@changed` queue.
        simd::zero_words(isa, &mut self.changed_bits);
        if touched.is_empty() {
            // dense first sweep in incremental mode too: mark everyone
            // affected by seeding `changed` with all vars; `affected`
            // is derived from neighbours, so unconstrained vars (which
            // can never lose values) are correctly never revised.
            for (wi, w) in self.changed_bits.iter_mut().enumerate() {
                *w = if wi == nw - 1 { tail_mask(n) } else { !0u64 };
            }
        } else {
            for &v in touched {
                self.changed_bits[v / 64] |= 1u64 << (v % 64);
            }
        }
        loop {
            counters.recurrences += 1;
            if let Some(w) = self.sweep(isa, problem, state, counters) {
                return Outcome::Wipeout(w);
            }
            if self.changed_bits.iter().all(|&w| w == 0) {
                return Outcome::Consistent;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3::{Ac3, QueueOrder};
    use crate::core::Relation;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn equality_chain_needs_one_sweep_per_hop() {
        let n = 8;
        let p = {
            let mut p = Problem::new("chain", n, 4);
            let eq = Relation::from_fn(4, 4, |a, b| a == b);
            for v in 0..n - 1 {
                p.add_constraint(v, v + 1, eq.clone());
            }
            p
        };
        let mut s = State::new(&p);
        s.assign(0, 3);
        let mut c = Counters::default();
        let out = RtacNative::dense().enforce(&p, &mut s, &[0], &mut c);
        assert!(out.is_consistent());
        for v in 0..n {
            assert_eq!(s.value(v), Some(3));
        }
        // information travels one hop per sweep + the final empty sweep
        assert_eq!(c.recurrences as usize, n);
    }

    #[test]
    fn already_consistent_is_one_recurrence() {
        let p = crate::gen::queens(5);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        assert!(RtacNative::dense().enforce(&p, &mut s, &[], &mut c).is_consistent());
        let mut c2 = Counters::default();
        let out = RtacNative::dense().enforce(&p, &mut s, &[], &mut c2);
        assert!(out.is_consistent());
        assert_eq!(c2.recurrences, 1);
        assert_eq!(c2.removals, 0);
    }

    #[test]
    fn wipeout_aborts_immediately() {
        let mut p = Problem::new("unsat", 3, 2);
        p.add_constraint(0, 1, Relation::forbid_all(2, 2));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = RtacNative::dense().enforce(&p, &mut s, &[], &mut c);
        assert!(matches!(out, Outcome::Wipeout(_)));
        assert_eq!(c.recurrences, 1);
    }

    #[test]
    fn dense_and_incremental_identical() {
        forall("rtac-dense-vs-inc", 0x57AC, 24, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(12),
                1 + rng.gen_range(7),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c1 = Counters::default();
            let mut c2 = Counters::default();
            let o1 = RtacNative::dense().enforce(&p, &mut s1, &[], &mut c1);
            let o2 = RtacNative::incremental().enforce(&p, &mut s2, &[], &mut c2);
            if o1.is_consistent() != o2.is_consistent() {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if c1.recurrences != c2.recurrences {
                return Err(format!(
                    "sweep count {} vs {} on {spec:?}",
                    c1.recurrences, c2.recurrences
                ));
            }
            if o1.is_consistent() && s1.snapshot() != s2.snapshot() {
                return Err(format!("closure mismatch on {spec:?}"));
            }
            if c2.support_checks > c1.support_checks {
                return Err("incremental did MORE work than dense".into());
            }
            Ok(())
        });
    }

    #[test]
    fn matches_ac3_closure() {
        forall("rtac-vs-ac3", 0x7AC3, 24, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(12),
                1 + rng.gen_range(7),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s1, &[], &mut c);
            let o2 = RtacNative::dense().enforce(&p, &mut s2, &[], &mut c);
            if o1.is_consistent() != o2.is_consistent() {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if o1.is_consistent() && s1.snapshot() != s2.snapshot() {
                return Err(format!("closure mismatch on {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn touched_seeding_sound_after_prior_ac() {
        let p = crate::gen::queens(6);
        let mut engine = RtacNative::incremental();
        let mut c = Counters::default();
        let mut s1 = State::new(&p);
        assert!(engine.enforce(&p, &mut s1, &[], &mut c).is_consistent());
        s1.push_level();
        s1.assign(2, 3);
        let o1 = engine.enforce(&p, &mut s1, &[2], &mut c);

        let mut s2 = State::new(&p);
        s2.assign(2, 3);
        let o2 = RtacNative::dense().enforce(&p, &mut s2, &[], &mut c);
        assert_eq!(o1.is_consistent(), o2.is_consistent());
        if o1.is_consistent() {
            assert_eq!(s1.snapshot(), s2.snapshot());
        }
    }

    #[test]
    fn recurrences_scale_weakly_with_density() {
        // the paper's headline observation (Table 1): #Recurrence stays
        // ~3-5 across densities while AC-3 revisions explode.
        for &density in &[0.1, 0.5, 1.0] {
            let p = random_csp(&RandomSpec::new(30, 10, density, 0.3, 9));
            let mut s = State::new(&p);
            s.assign(0, 0);
            let mut c = Counters::default();
            let out = RtacNative::dense().enforce(&p, &mut s, &[0], &mut c);
            if out.is_consistent() {
                assert!(c.recurrences <= 8, "density {density}: {} sweeps", c.recurrences);
            }
        }
    }
}
