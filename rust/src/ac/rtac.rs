//! Native RTAC — the paper's recurrent arc consistency (Eq. 1) as a CPU
//! engine, mirroring exactly what the tensor path computes.
//!
//! Each *recurrence* is a synchronous (Jacobi-style) sweep: supports are
//! tested against a **snapshot** of the domains taken at sweep start, so
//! every removal of sweep k is justified purely by the state after sweep
//! k−1 — precisely Eq. 1, and bit-for-bit the tensor model's
//! `while_loop` body.  The sweep count (`Counters::recurrences`) is the
//! paper's `#Recurrence` (Table 1) and is asserted equal to the XLA
//! executable's `iters` output by the runtime integration tests.
//!
//! Two variants:
//! * **dense** — every sweep re-checks every (variable, value): what the
//!   static-shape tensor artifact does.
//! * **incremental** — Prop. 2: sweep k only re-checks variables with a
//!   neighbour whose domain changed in sweep k−1 (the paper's
//!   `@changed` set).  Identical removals and sweep counts (asserted in
//!   tests), strictly less CPU work.
//!
//! Domains snapshot into a flat [`DomainPlane`] arena, so taking the
//! per-sweep snapshot is one memcpy over the whole network.  The
//! thread-parallel variant of the same recurrence lives in
//! [`super::rtac_par`].

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{DomainPlane, Problem, State, VarId};

/// Derive the Prop.-2 candidate set for a sweep: reset the previously
/// set `affected` flags (named exactly by `affected_list` — the
/// invariant every caller maintains), then flag each neighbour of a
/// variable whose domain changed in the previous sweep.
///
/// Shared by every engine that implements the incremental recurrence
/// ([`RtacNative`], [`super::rtac_par::RtacParallel`], and the batched
/// SAC probe fixpoint in `super::sac`), so the candidate-set semantics
/// cannot silently diverge between them.
pub(crate) fn derive_affected(
    problem: &Problem,
    changed: &[VarId],
    affected: &mut [bool],
    affected_list: &mut Vec<VarId>,
) {
    for &v in affected_list.iter() {
        affected[v] = false;
    }
    affected_list.clear();
    for &v in changed {
        for &arc in problem.arcs_of(v) {
            let other = problem.arc_other(arc);
            if !affected[other] {
                affected[other] = true;
                affected_list.push(other);
            }
        }
    }
}

/// The native recurrent engine.
pub struct RtacNative {
    incremental: bool,
    /// Flat domain-plane snapshot at sweep start: refreshed by a single
    /// memcpy from the state's arena (reused across calls).
    snapshot: DomainPlane,
    /// Vars whose domain changed in the previous sweep.
    changed_list: Vec<VarId>,
    /// Next sweep's changed list, built in place and swapped in.
    scratch_list: Vec<VarId>,
    /// Vars to re-check this sweep (incremental candidates).  The flag
    /// vector is sized once per enforcement; per sweep only the entries
    /// named by `affected_list` are reset.
    affected: Vec<bool>,
    affected_list: Vec<VarId>,
    vals_buf: Vec<usize>,
}

impl RtacNative {
    pub fn dense() -> RtacNative {
        Self::with_mode(false)
    }

    pub fn incremental() -> RtacNative {
        Self::with_mode(true)
    }

    fn with_mode(incremental: bool) -> RtacNative {
        RtacNative {
            incremental,
            snapshot: DomainPlane::empty(),
            changed_list: Vec::new(),
            scratch_list: Vec::new(),
            affected: Vec::new(),
            affected_list: Vec::new(),
            vals_buf: Vec::new(),
        }
    }

    fn take_snapshot(&mut self, state: &State) {
        if self.snapshot.same_layout(state.plane()) {
            self.snapshot.copy_words_from(state.plane());
        } else {
            self.snapshot = state.plane().clone();
        }
    }

    /// One synchronous sweep.  Returns the first wiped variable, if any.
    ///
    /// Keep the revise loop semantically in sync with
    /// `super::rtac_par::RtacParallel::revise_chunk` and
    /// `super::sac::plane_fixpoint` — same support predicate and
    /// counter accounting, different removal sinks.
    fn sweep(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Option<VarId> {
        self.take_snapshot(state);
        let n = problem.n_vars();

        // Candidate set: in incremental mode, variables adjacent to a
        // change from the previous sweep; in dense mode, everyone.
        if self.incremental {
            derive_affected(
                problem,
                &self.changed_list,
                &mut self.affected,
                &mut self.affected_list,
            );
        }

        self.scratch_list.clear();
        let mut wiped: Option<VarId> = None;
        for x in 0..n {
            if self.incremental && !self.affected[x] {
                continue;
            }
            self.vals_buf.clear();
            self.vals_buf.extend(self.snapshot.bits(x).iter_ones());
            let mut x_changed = false;
            'vals: for &a in &self.vals_buf {
                for &arc in problem.arcs_of(x) {
                    counters.support_checks += 1;
                    let other = problem.arc_other(arc);
                    if !problem.arc_support_row(arc, a).intersects(self.snapshot.bits(other)) {
                        state.remove(x, a);
                        counters.removals += 1;
                        x_changed = true;
                        continue 'vals;
                    }
                }
            }
            if x_changed {
                self.scratch_list.push(x);
                if state.wiped(x) {
                    wiped = wiped.or(Some(x));
                }
            }
        }
        std::mem::swap(&mut self.changed_list, &mut self.scratch_list);
        wiped
    }
}

impl Propagator for RtacNative {
    fn name(&self) -> &'static str {
        if self.incremental {
            "rtac-inc"
        } else {
            "rtac"
        }
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        let n = problem.n_vars();
        // Seed the changed set: the paper's initial `@changed` queue.
        self.changed_list.clear();
        if touched.is_empty() {
            self.changed_list.extend(0..n);
            // dense first sweep in incremental mode too: mark everyone
            // affected by seeding `changed` with all vars; `affected`
            // is derived from neighbours, so ALSO check isolated vars by
            // the dense path below.
        } else {
            self.changed_list.extend_from_slice(touched);
        }
        // Size the affected flags once per enforcement, not per sweep;
        // each sweep resets only the entries it set (tracked by
        // `affected_list`, whose invariant — it names exactly the true
        // flags — holds across enforcements of the same problem).
        if self.incremental && self.affected.len() != n {
            self.affected.clear();
            self.affected.resize(n, false);
            self.affected_list.clear();
        }
        // Root enforcement must examine every variable once even in
        // incremental mode (a variable with an unsatisfiable relation
        // pair needs no prior change to lose values).  `affected` from
        // "neighbours of everyone" covers exactly the constrained vars,
        // which is sufficient: unconstrained vars can never lose values.
        loop {
            counters.recurrences += 1;
            if let Some(w) = self.sweep(problem, state, counters) {
                return Outcome::Wipeout(w);
            }
            if self.changed_list.is_empty() {
                return Outcome::Consistent;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3::{Ac3, QueueOrder};
    use crate::core::Relation;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn equality_chain_needs_one_sweep_per_hop() {
        let n = 8;
        let p = {
            let mut p = Problem::new("chain", n, 4);
            let eq = Relation::from_fn(4, 4, |a, b| a == b);
            for v in 0..n - 1 {
                p.add_constraint(v, v + 1, eq.clone());
            }
            p
        };
        let mut s = State::new(&p);
        s.assign(0, 3);
        let mut c = Counters::default();
        let out = RtacNative::dense().enforce(&p, &mut s, &[0], &mut c);
        assert!(out.is_consistent());
        for v in 0..n {
            assert_eq!(s.value(v), Some(3));
        }
        // information travels one hop per sweep + the final empty sweep
        assert_eq!(c.recurrences as usize, n);
    }

    #[test]
    fn already_consistent_is_one_recurrence() {
        let p = crate::gen::queens(5);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        assert!(RtacNative::dense().enforce(&p, &mut s, &[], &mut c).is_consistent());
        let mut c2 = Counters::default();
        let out = RtacNative::dense().enforce(&p, &mut s, &[], &mut c2);
        assert!(out.is_consistent());
        assert_eq!(c2.recurrences, 1);
        assert_eq!(c2.removals, 0);
    }

    #[test]
    fn wipeout_aborts_immediately() {
        let mut p = Problem::new("unsat", 3, 2);
        p.add_constraint(0, 1, Relation::forbid_all(2, 2));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = RtacNative::dense().enforce(&p, &mut s, &[], &mut c);
        assert!(matches!(out, Outcome::Wipeout(_)));
        assert_eq!(c.recurrences, 1);
    }

    #[test]
    fn dense_and_incremental_identical() {
        forall("rtac-dense-vs-inc", 0x57AC, 24, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(12),
                1 + rng.gen_range(7),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c1 = Counters::default();
            let mut c2 = Counters::default();
            let o1 = RtacNative::dense().enforce(&p, &mut s1, &[], &mut c1);
            let o2 = RtacNative::incremental().enforce(&p, &mut s2, &[], &mut c2);
            if o1.is_consistent() != o2.is_consistent() {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if c1.recurrences != c2.recurrences {
                return Err(format!(
                    "sweep count {} vs {} on {spec:?}",
                    c1.recurrences, c2.recurrences
                ));
            }
            if o1.is_consistent() && s1.snapshot() != s2.snapshot() {
                return Err(format!("closure mismatch on {spec:?}"));
            }
            if c2.support_checks > c1.support_checks {
                return Err("incremental did MORE work than dense".into());
            }
            Ok(())
        });
    }

    #[test]
    fn matches_ac3_closure() {
        forall("rtac-vs-ac3", 0x7AC3, 24, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(12),
                1 + rng.gen_range(7),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s1, &[], &mut c);
            let o2 = RtacNative::dense().enforce(&p, &mut s2, &[], &mut c);
            if o1.is_consistent() != o2.is_consistent() {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if o1.is_consistent() && s1.snapshot() != s2.snapshot() {
                return Err(format!("closure mismatch on {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn touched_seeding_sound_after_prior_ac() {
        let p = crate::gen::queens(6);
        let mut engine = RtacNative::incremental();
        let mut c = Counters::default();
        let mut s1 = State::new(&p);
        assert!(engine.enforce(&p, &mut s1, &[], &mut c).is_consistent());
        s1.push_level();
        s1.assign(2, 3);
        let o1 = engine.enforce(&p, &mut s1, &[2], &mut c);

        let mut s2 = State::new(&p);
        s2.assign(2, 3);
        let o2 = RtacNative::dense().enforce(&p, &mut s2, &[], &mut c);
        assert_eq!(o1.is_consistent(), o2.is_consistent());
        if o1.is_consistent() {
            assert_eq!(s1.snapshot(), s2.snapshot());
        }
    }

    #[test]
    fn recurrences_scale_weakly_with_density() {
        // the paper's headline observation (Table 1): #Recurrence stays
        // ~3-5 across densities while AC-3 revisions explode.
        for &density in &[0.1, 0.5, 1.0] {
            let p = random_csp(&RandomSpec::new(30, 10, density, 0.3, 9));
            let mut s = State::new(&p);
            s.assign(0, 0);
            let mut c = Counters::default();
            let out = RtacNative::dense().enforce(&p, &mut s, &[0], &mut c);
            if out.is_consistent() {
                assert!(c.recurrences <= 8, "density {density}: {} sweeps", c.recurrences);
            }
        }
    }
}
