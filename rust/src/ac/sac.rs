//! Singleton arc consistency (SAC) — a stronger consistency built *on
//! top of* any [`Propagator`]: value (x, a) is SAC iff the subproblem
//! with x := a is arc consistent.  This is the natural "next level" the
//! paper's recurrent formulation extends to (each singleton probe is an
//! independent enforcement — massively parallel in the tensor setting,
//! and a natural batch for the coordinator).
//!
//! Implementation: SAC-1 (Debruyne & Bessière).  Probes run on a scratch
//! level of the trail; confirmed removals propagate through the inner
//! engine until a fixpoint over all (var, value) pairs.

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{Problem, State, VarId};

/// SAC-1 enforcer wrapping an inner AC engine.
pub struct Sac1<E: Propagator> {
    inner: E,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
}

impl<E: Propagator> Sac1<E> {
    pub fn new(inner: E) -> Sac1<E> {
        Sac1 { inner, probes: 0 }
    }

    /// Enforce SAC.  Returns the outcome; `counters` accumulates the
    /// inner engine's work across all probes.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        // start from the AC closure
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        loop {
            let mut removed_any = false;
            for x in 0..problem.n_vars() {
                let vals: Vec<usize> = state.dom(x).iter_ones().collect();
                if vals.len() <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                for a in vals {
                    if !state.contains(x, a) {
                        continue; // removed by an earlier probe's fallout
                    }
                    self.probes += 1;
                    state.push_level();
                    state.assign(x, a);
                    let probe = self.inner.enforce(problem, state, &[x], counters);
                    state.pop_level();
                    if !probe.is_consistent() {
                        state.remove(x, a);
                        removed_any = true;
                        if state.wiped(x) {
                            return Outcome::Wipeout(x);
                        }
                        // re-establish AC after a confirmed removal
                        let out = self.inner.enforce(problem, state, &[x], counters);
                        if !out.is_consistent() {
                            return out;
                        }
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl<E: Propagator> Propagator for Sac1<E> {
    fn name(&self) -> &'static str {
        "sac1"
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.probes = 0;
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3bit::Ac3Bit;
    use crate::ac::rtac::RtacNative;
    use crate::core::Relation;
    use crate::gen::random::{random_csp, RandomSpec};

    #[test]
    fn sac_strictly_stronger_than_ac_on_known_gadget() {
        // x0,x1,x2 pairwise != over d=2: AC-consistent (every value has
        // a support on each edge) but no solution — SAC detects it.
        let p = crate::gen::pigeonhole(3, 2);
        let mut s_ac = State::new(&p);
        let mut c = Counters::default();
        assert!(Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c).is_consistent());
        assert_eq!(s_ac.total_size(), 6); // AC removes nothing

        let mut s_sac = State::new(&p);
        let out = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
        assert!(!out.is_consistent(), "SAC must refute pigeonhole(3,2)");
    }

    #[test]
    fn sac_equals_ac_when_already_sac() {
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = Sac1::new(RtacNative::dense()).enforce_sac(&p, &mut s, &mut c);
        assert!(out.is_consistent());
        assert_eq!(s.total_size(), 12); // equality chain: everything SAC
    }

    #[test]
    fn sac_closure_engine_independent() {
        for seed in [11u64, 29, 47] {
            let p = random_csp(&RandomSpec::new(8, 4, 0.7, 0.45, seed));
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s1, &mut c);
            let o2 = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s2, &mut c);
            assert_eq!(o1.is_consistent(), o2.is_consistent(), "seed {seed}");
            if o1.is_consistent() {
                assert_eq!(s1.snapshot(), s2.snapshot(), "seed {seed}");
            }
        }
    }

    #[test]
    fn sac_subset_of_ac_closure() {
        for seed in [5u64, 17] {
            let p = random_csp(&RandomSpec::new(9, 4, 0.8, 0.5, seed));
            let mut s_ac = State::new(&p);
            let mut s_sac = State::new(&p);
            let mut c = Counters::default();
            let o_ac = Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c);
            let o_sac = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
            if !o_ac.is_consistent() || !o_sac.is_consistent() {
                continue;
            }
            for v in 0..p.n_vars() {
                for a in s_sac.dom(v).iter_ones() {
                    assert!(s_ac.contains(v, a), "SAC kept a value AC removed");
                }
            }
        }
    }
}
