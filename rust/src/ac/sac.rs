//! Singleton arc consistency (SAC) — a stronger consistency built *on
//! top of* any [`Propagator`]: value (x, a) is SAC iff the subproblem
//! with x := a is arc consistent.  This is the natural "next level" the
//! paper's recurrent formulation extends to: each singleton probe is an
//! **independent enforcement** — massively parallel in the tensor
//! setting, and a natural batch for the coordinator
//! ([`crate::coordinator::Handle::submit_batch`] is the tensor-route
//! twin of the CPU batching below).
//!
//! Two enforcers:
//!
//! * [`Sac1`] — sequential SAC-1 (Debruyne & Bessière) wrapping any
//!   inner AC engine.  Probes run on a scratch level of the trail;
//!   confirmed removals propagate through the inner engine until a
//!   fixpoint over all (var, value) pairs.
//! * [`SacParallel`] (`sac-par[N]`) — batched SAC-1 on the persistent
//!   [`WorkerPool`]: K probes run concurrently, each on a private
//!   scratch plane pair checked out of a [`PlaneSlab`] (one memcpy
//!   each), with the recurrent fixpoint run directly on the planes (no
//!   trail — probe domains are discarded).  Sound because probe
//!   failure is **monotone**: a probe that is AC-inconsistent against
//!   the batch's launch domains stays inconsistent under the smaller
//!   domains later removals produce, so every failed probe of a batch
//!   can be removed; stale *successes* are caught by the outer
//!   fixpoint loop re-probing until a full pass removes nothing.  The
//!   SAC closure is unique, so the batched engine reaches bit-the-same
//!   final domains as [`Sac1`] (property-tested at 1/2/4 workers).

use crate::ac::rtac::{derive_affected, RtacNative};
use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{DomainPlane, PlaneSlab, Problem, State, Val, VarId};
use crate::exec::WorkerPool;

/// SAC-1 enforcer wrapping an inner AC engine.
pub struct Sac1<E: Propagator> {
    inner: E,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
    /// Reusable value-collection buffer — hoisted out of the probe loop
    /// so the hot path stops allocating one `Vec` per (pass, variable).
    vals_buf: Vec<usize>,
}

impl<E: Propagator> Sac1<E> {
    pub fn new(inner: E) -> Sac1<E> {
        Sac1 { inner, probes: 0, vals_buf: Vec::new() }
    }

    /// Enforce SAC.  Returns the outcome; `counters` accumulates the
    /// inner engine's work across all probes.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        // start from the AC closure
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        loop {
            let mut removed_any = false;
            for x in 0..problem.n_vars() {
                self.vals_buf.clear();
                self.vals_buf.extend(state.dom(x).iter_ones());
                if self.vals_buf.len() <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                for &a in &self.vals_buf {
                    if !state.contains(x, a) {
                        continue; // removed by an earlier probe's fallout
                    }
                    self.probes += 1;
                    state.push_level();
                    state.assign(x, a);
                    let probe = self.inner.enforce(problem, state, &[x], counters);
                    state.pop_level();
                    if !probe.is_consistent() {
                        state.remove(x, a);
                        removed_any = true;
                        if state.wiped(x) {
                            return Outcome::Wipeout(x);
                        }
                        // re-establish AC after a confirmed removal
                        let out = self.inner.enforce(problem, state, &[x], counters);
                        if !out.is_consistent() {
                            return out;
                        }
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl<E: Propagator> Propagator for Sac1<E> {
    fn name(&self) -> &'static str {
        "sac1"
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.probes = 0;
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

/// Reusable per-probe fixpoint bookkeeping (changed lists + Prop.-2
/// flags), pooled by [`SacParallel`] alongside the scratch planes so a
/// steady-state probe performs no heap allocation at all.  The
/// "`affected_list` names exactly the true flags" invariant carries
/// across probes: [`derive_affected`] resets precisely those entries at
/// each sweep start.
#[derive(Default)]
struct ProbeScratch {
    changed: Vec<VarId>,
    next_changed: Vec<VarId>,
    affected: Vec<bool>,
    affected_list: Vec<VarId>,
}

/// Run the recurrent AC fixpoint directly on a plane pair — the probe
/// body of batched SAC.  `plane` holds the live domains (with the probe
/// assignment already applied); `snap` is the per-sweep Jacobi snapshot
/// buffer.  Prop.-2 incremental candidate sets, seeded from `seed`.
/// No trail: probe domains are scratch and discarded.  Returns true iff
/// the fixpoint is consistent (no domain wiped out).
///
/// The revise loop below must stay semantically in sync with its two
/// siblings — `RtacNative::sweep` (removal sink: trailed
/// `State::remove`) and `RtacParallel::revise_chunk` (removal sink:
/// chunk-relative word masking); this one clears bits on the scratch
/// plane.  Only the sink differs; the support predicate and counter
/// accounting are the bit-identity contract.
fn plane_fixpoint(
    problem: &Problem,
    plane: &mut DomainPlane,
    snap: &mut DomainPlane,
    seed: VarId,
    scratch: &mut ProbeScratch,
    counters: &mut Counters,
) -> bool {
    let n = problem.n_vars();
    if scratch.affected.len() != n {
        scratch.affected.clear();
        scratch.affected.resize(n, false);
        scratch.affected_list.clear();
    }
    scratch.changed.clear();
    scratch.changed.push(seed);
    loop {
        counters.recurrences += 1;
        snap.copy_words_from(plane);
        derive_affected(
            problem,
            &scratch.changed,
            &mut scratch.affected,
            &mut scratch.affected_list,
        );
        scratch.next_changed.clear();
        for x in 0..n {
            if !scratch.affected[x] {
                continue;
            }
            let mut x_changed = false;
            'vals: for a in snap.bits(x).iter_ones() {
                for &arc in problem.arcs_of(x) {
                    counters.support_checks += 1;
                    let other = problem.arc_other(arc);
                    if !problem.arc_support_row(arc, a).intersects(snap.bits(other)) {
                        plane.clear(x, a);
                        counters.removals += 1;
                        x_changed = true;
                        continue 'vals;
                    }
                }
            }
            if x_changed {
                scratch.next_changed.push(x);
                if plane.is_wiped(x) {
                    return false;
                }
            }
        }
        if scratch.next_changed.is_empty() {
            return true;
        }
        std::mem::swap(&mut scratch.changed, &mut scratch.next_changed);
    }
}

/// Batched SAC-1 on the persistent worker pool (`sac-par[N]`).
pub struct SacParallel {
    /// Requested probe workers; 0 = auto (available parallelism).
    workers: usize,
    /// State-level AC for the root closure and post-removal
    /// re-propagation (the probes themselves run plane-level).
    inner: RtacNative,
    pool: Option<WorkerPool>,
    slab: PlaneSlab,
    /// Pooled per-probe fixpoint bookkeeping (see [`ProbeScratch`]).
    scratch_pool: Vec<ProbeScratch>,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
    /// Candidate (var, value) pairs of the current pass.
    pairs: Vec<(VarId, Val)>,
}

impl SacParallel {
    pub fn new(workers: usize) -> SacParallel {
        SacParallel {
            workers,
            inner: RtacNative::incremental(),
            pool: None,
            slab: PlaneSlab::new(),
            scratch_pool: Vec::new(),
            probes: 0,
            pairs: Vec::new(),
        }
    }

    fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }

    /// Enforce SAC with batched probes.  Returns the outcome; `counters`
    /// accumulates the work of every probe plus the state-level AC runs.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        let k = self.effective_workers();
        let need_pool = match &self.pool {
            Some(p) => p.size() != k,
            None => true,
        };
        if need_pool {
            self.pool = Some(WorkerPool::new(k));
        }
        loop {
            let mut removed_any = false;
            // This pass's candidates: every live value of every
            // non-singleton variable (SAC-1's probe set).
            self.pairs.clear();
            for x in 0..problem.n_vars() {
                if state.dom_size(x) <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                self.pairs.extend(state.dom(x).iter_ones().map(|a| (x, a)));
            }
            let mut start = 0usize;
            while start < self.pairs.len() {
                let end = (start + k).min(self.pairs.len());
                // Launch up to k probes against the CURRENT domains,
                // skipping values an earlier batch's fallout removed.
                // Each probe checks out a plane pair and owns it for
                // the probe's lifetime: the live plane is a memcpy of
                // the current domains, the snapshot buffer is
                // uninitialised scratch (the fixpoint overwrites it
                // before reading).
                let mut jobs: Vec<(VarId, Val, DomainPlane, DomainPlane, ProbeScratch)> =
                    Vec::with_capacity(end - start);
                for &(x, a) in &self.pairs[start..end] {
                    // skip values already removed, and variables an
                    // earlier removal's fallout reduced to a singleton
                    // (a singleton that survived AC is SAC — the probe
                    // outcome is known)
                    if !state.contains(x, a) || state.dom_size(x) <= 1 {
                        continue;
                    }
                    let cur = self.slab.checkout(state.plane());
                    let snap = self.slab.checkout_scratch(state.plane());
                    let scratch = self.scratch_pool.pop().unwrap_or_default();
                    jobs.push((x, a, cur, snap, scratch));
                }
                start = end;
                if jobs.is_empty() {
                    continue;
                }
                self.probes += jobs.len() as u64;
                let tasks: Vec<_> = jobs
                    .into_iter()
                    .map(|(x, a, mut cur, mut snap, mut scratch)| {
                        move || {
                            let mut c = Counters::default();
                            cur.assign(x, a);
                            let consistent = plane_fixpoint(
                                problem,
                                &mut cur,
                                &mut snap,
                                x,
                                &mut scratch,
                                &mut c,
                            );
                            (x, a, consistent, cur, snap, scratch, c)
                        }
                    })
                    .collect();
                let results = self.pool.as_mut().expect("pool sized above").run_collect(tasks);
                // Merge in launch order: counters stay deterministic and
                // the scratch buffers go back to their pools before any
                // state-level propagation runs.
                let mut failed: Vec<(VarId, Val)> = Vec::new();
                for (x, a, consistent, cur, snap, scratch, c) in results {
                    counters.add(&c);
                    self.slab.checkin(cur);
                    self.slab.checkin(snap);
                    self.scratch_pool.push(scratch);
                    if !consistent {
                        failed.push((x, a));
                    }
                }
                // Probe failure is monotone (see module docs): every
                // failed probe's value goes, each followed by AC
                // re-propagation — exactly SAC-1's confirmed-removal
                // step, just k at a time.
                for (x, a) in failed {
                    if !state.contains(x, a) {
                        continue; // an earlier removal's fallout got it
                    }
                    state.remove(x, a);
                    removed_any = true;
                    if state.wiped(x) {
                        return Outcome::Wipeout(x);
                    }
                    let out = self.inner.enforce(problem, state, &[x], counters);
                    if !out.is_consistent() {
                        return out;
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl Propagator for SacParallel {
    fn name(&self) -> &'static str {
        "sac-par"
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.probes = 0;
        // pool and slab survive: the persistent runtime is the point
        // (the slab drops stale-layout planes lazily on checkout)
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3bit::Ac3Bit;
    use crate::ac::rtac::RtacNative;
    use crate::core::Relation;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn sac_strictly_stronger_than_ac_on_known_gadget() {
        // x0,x1,x2 pairwise != over d=2: AC-consistent (every value has
        // a support on each edge) but no solution — SAC detects it.
        let p = crate::gen::pigeonhole(3, 2);
        let mut s_ac = State::new(&p);
        let mut c = Counters::default();
        assert!(Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c).is_consistent());
        assert_eq!(s_ac.total_size(), 6); // AC removes nothing

        let mut s_sac = State::new(&p);
        let out = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
        assert!(!out.is_consistent(), "SAC must refute pigeonhole(3,2)");

        let mut s_par = State::new(&p);
        let out_par = SacParallel::new(2).enforce_sac(&p, &mut s_par, &mut c);
        assert!(!out_par.is_consistent(), "batched SAC must refute pigeonhole(3,2)");
    }

    #[test]
    fn sac_equals_ac_when_already_sac() {
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = Sac1::new(RtacNative::dense()).enforce_sac(&p, &mut s, &mut c);
        assert!(out.is_consistent());
        assert_eq!(s.total_size(), 12); // equality chain: everything SAC

        let mut s_par = State::new(&p);
        let out_par = SacParallel::new(3).enforce_sac(&p, &mut s_par, &mut c);
        assert!(out_par.is_consistent());
        assert_eq!(s_par.total_size(), 12);
    }

    #[test]
    fn sac_closure_engine_independent() {
        for seed in [11u64, 29, 47] {
            let p = random_csp(&RandomSpec::new(8, 4, 0.7, 0.45, seed));
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s1, &mut c);
            let o2 = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s2, &mut c);
            assert_eq!(o1.is_consistent(), o2.is_consistent(), "seed {seed}");
            if o1.is_consistent() {
                assert_eq!(s1.snapshot(), s2.snapshot(), "seed {seed}");
            }
        }
    }

    #[test]
    fn sac_subset_of_ac_closure() {
        for seed in [5u64, 17] {
            let p = random_csp(&RandomSpec::new(9, 4, 0.8, 0.5, seed));
            let mut s_ac = State::new(&p);
            let mut s_sac = State::new(&p);
            let mut c = Counters::default();
            let o_ac = Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c);
            let o_sac = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
            if !o_ac.is_consistent() || !o_sac.is_consistent() {
                continue;
            }
            for v in 0..p.n_vars() {
                for a in s_sac.dom(v).iter_ones() {
                    assert!(s_ac.contains(v, a), "SAC kept a value AC removed");
                }
            }
        }
    }

    #[test]
    fn batched_sac_same_fixpoint_as_sequential_across_worker_counts() {
        // Satellite contract: sac-par at 1/2/4 workers reaches the SAME
        // fixpoint (final domains + outcome) as sequential SAC-1 on
        // random dense instances — the SAC closure is unique, so probe
        // batching must not change it.
        forall("sac-par-vs-sac1", 0x5AC2, 12, |rng| {
            let spec = RandomSpec::new(
                4 + rng.gen_range(6),
                2 + rng.gen_range(4),
                0.6 + 0.4 * rng.next_f64(),
                0.55 * rng.next_f64(),
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s_ref = State::new(&p);
            let mut c_ref = Counters::default();
            let o_ref =
                Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_ref, &mut c_ref);
            for workers in [1usize, 2, 4] {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = SacParallel::new(workers).enforce_sac(&p, &mut s, &mut c);
                if o.is_consistent() != o_ref.is_consistent() {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_ref:?} on {spec:?}"));
                }
                if o_ref.is_consistent() && s.snapshot() != s_ref.snapshot() {
                    return Err(format!("{workers}w: fixpoint mismatch on {spec:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batched_sac_engine_reuse_across_problems() {
        // one engine (one pool + slab) across layout changes: the slab
        // must drop stale planes and the fixpoints must stay right.
        let mut engine = SacParallel::new(2);
        for p in [
            crate::gen::pigeonhole(3, 2),
            random_csp(&RandomSpec::new(7, 5, 0.8, 0.4, 23)),
            crate::gen::pigeonhole(4, 3),
        ] {
            let mut s_par = State::new(&p);
            let mut s_seq = State::new(&p);
            let mut c = Counters::default();
            let o_par = engine.enforce_sac(&p, &mut s_par, &mut c);
            let o_seq = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_seq, &mut c);
            assert_eq!(o_par.is_consistent(), o_seq.is_consistent(), "{}", p.name());
            if o_par.is_consistent() {
                assert_eq!(s_par.snapshot(), s_seq.snapshot(), "{}", p.name());
            }
            engine.reset(&p);
        }
    }

    #[test]
    fn probe_counts_match_between_sequential_and_batched() {
        // both engines probe the same (var, value) pairs per pass when
        // no removals interleave; on an already-SAC instance the counts
        // are exactly equal (one full pass each).
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut c = Counters::default();
        let mut seq = Sac1::new(RtacNative::incremental());
        let mut s1 = State::new(&p);
        assert!(seq.enforce_sac(&p, &mut s1, &mut c).is_consistent());
        let mut par = SacParallel::new(3);
        let mut s2 = State::new(&p);
        assert!(par.enforce_sac(&p, &mut s2, &mut c).is_consistent());
        assert_eq!(seq.probes, par.probes);
        assert!(par.probes > 0);
    }
}
