//! Singleton arc consistency (SAC) — a stronger consistency built *on
//! top of* any [`Propagator`]: value (x, a) is SAC iff the subproblem
//! with x := a is arc consistent.  This is the natural "next level" the
//! paper's recurrent formulation extends to: each singleton probe is an
//! **independent enforcement** — massively parallel in the tensor
//! setting, and a natural batch for the coordinator
//! ([`crate::coordinator::Handle::submit_batch`] is the tensor-route
//! twin of the CPU batching below).
//!
//! Four enforcers:
//!
//! * [`Sac1`] — sequential SAC-1 (Debruyne & Bessière) wrapping any
//!   inner AC engine.  Probes run on a scratch level of the trail;
//!   confirmed removals propagate through the inner engine until a
//!   fixpoint over all (var, value) pairs.
//! * [`SacParallel`] — batched SAC-1 behind the **probe-backend seam**
//!   ([`ProbeBackend`]): the SAC-1 merge loop (launch K probes against
//!   the current domains, remove every failed probe's value, AC
//!   re-propagate, repeat until a full pass removes nothing) is
//!   backend-independent; only *where* the probe fixpoints run differs.
//!   Sound for any backend because probe failure is **monotone**: a
//!   probe that is AC-inconsistent against the batch's launch domains
//!   stays inconsistent under the smaller domains later removals
//!   produce, so every failed probe of a batch can be removed; stale
//!   *successes* are caught by the outer fixpoint loop re-probing until
//!   a full pass removes nothing.  The SAC closure is unique, so every
//!   backend reaches bit-the-same final domains as [`Sac1`].
//!   - [`CpuProbeBackend`] (`sac-par[N]`) — K probes concurrently on
//!     the persistent [`WorkerPool`], each on a private scratch plane
//!     pair checked out of a [`PlaneSlab`] (one memcpy each), the
//!     recurrent fixpoint run directly on the planes (no trail — probe
//!     domains are discarded).  Property-tested at 1/2/4 workers.
//!   - [`XlaProbeBackend`] (`sac-xla[N]`) — K probes staged straight
//!     from the [`DomainPlane`] arena (`runtime::encode_vars_into`,
//!     one base encoding per round + a single-row edit per probe) and
//!     submitted through the coordinator onto the compiled `fixb*`
//!     executables: the coordinator's dynamic batcher fuses the round
//!     into as few executions as the compiled batch sizes allow.  In
//!     its default **delta mode** the backend attaches a session
//!     client ([`crate::coordinator::Handle::attach`]) and each round
//!     ships one base plane
//!     ([`crate::coordinator::Handle::upload_base`], skipped while the
//!     launch domains are unchanged) plus one single-row
//!     [`crate::runtime::PlaneDelta`] per probe
//!     ([`crate::coordinator::Handle::submit_batch_delta`]) instead of
//!     K full planes.  Per-client base slots keep several delta
//!     writers on one session independent; if this client's slot is
//!     nonetheless evicted under the session's `base_slots` cap, the
//!     dropped round is retried with fresh base uploads through the
//!     shared session [`RetryPolicy`] (bounded attempts, never a wrong
//!     verdict — the budget exhausting surfaces as an error).
//!     [`XlaProbeBackend::full_plane`] keeps the PR-3 full-plane
//!     submission as the upload-volume baseline.  [`SacXla`] wraps
//!     this backend together with a lazily-started coordinator session
//!     into a self-contained engine for `make_engine("sac-xla[N]")`.
//!   - [`MixedProbeBackend`] (`sac-mixed[N]`) — each round is **split**
//!     between the CPU and tensor backends by a cost model (see
//!     [`MixedProbeBackend::auto_split`]): the tensor share is
//!     submitted first (non-blocking), the CPU share runs on the pool
//!     while the fused executions are in flight, and the verdicts are
//!     merged in probe order.  Merging failed-probe sets from both
//!     halves is sound because probe failure is monotone *regardless of
//!     which backend observed it* — both probe the same launch domains.
//!     A tensor-side failure falls back to re-probing that share on the
//!     CPU (same launch domains ⇒ same verdicts), so the engine
//!     degrades instead of poisoning.  [`SacMixed`] wraps it with a
//!     lazily-started session and runs CPU-only when no artifacts are
//!     available.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::ac::rtac::{expand_affected, revise_var_fused, RtacNative};
use crate::ac::{Counters, Outcome, Propagator};
use crate::coordinator::{FixCache, Handle, Response, Retry, RetryPolicy, StaleTracker};
use crate::core::{DomainPlane, PlaneSlab, Problem, State, Val, VarId};
use crate::exec::WorkerPool;
use crate::runtime::{encode_vars_into, plane_fingerprint, PlaneDelta};
use crate::util::bitset::words_for;
use crate::util::simd;

/// SAC-1 enforcer wrapping an inner AC engine.
pub struct Sac1<E: Propagator> {
    inner: E,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
    /// Reusable value-collection buffer — hoisted out of the probe loop
    /// so the hot path stops allocating one `Vec` per (pass, variable).
    vals_buf: Vec<usize>,
}

impl<E: Propagator> Sac1<E> {
    pub fn new(inner: E) -> Sac1<E> {
        Sac1 { inner, probes: 0, vals_buf: Vec::new() }
    }

    /// Enforce SAC.  Returns the outcome; `counters` accumulates the
    /// inner engine's work across all probes.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        // start from the AC closure
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        loop {
            let mut removed_any = false;
            for x in 0..problem.n_vars() {
                self.vals_buf.clear();
                self.vals_buf.extend(state.dom(x).iter_ones());
                if self.vals_buf.len() <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                for &a in &self.vals_buf {
                    if !state.contains(x, a) {
                        continue; // removed by an earlier probe's fallout
                    }
                    self.probes += 1;
                    state.push_level();
                    state.assign(x, a);
                    let probe = self.inner.enforce(problem, state, &[x], counters);
                    state.pop_level();
                    if !probe.is_consistent() {
                        state.remove(x, a);
                        removed_any = true;
                        if state.wiped(x) {
                            return Outcome::Wipeout(x);
                        }
                        // re-establish AC after a confirmed removal
                        let out = self.inner.enforce(problem, state, &[x], counters);
                        if !out.is_consistent() {
                            return out;
                        }
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl<E: Propagator> Propagator for Sac1<E> {
    fn name(&self) -> &'static str {
        "sac1"
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.probes = 0;
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

/// Reusable per-probe fixpoint bookkeeping (changed / Prop.-2 affected
/// var bitsets, one bit per variable), pooled by [`SacParallel`]
/// alongside the scratch planes so a steady-state probe performs no
/// heap allocation at all.  [`expand_affected`] rebuilds `affected_bits`
/// from `changed_bits` at each sweep start by OR-ing precomputed
/// arc-adjacency rows, so neither buffer needs clearing between probes.
#[derive(Default)]
struct ProbeScratch {
    changed_bits: Vec<u64>,
    affected_bits: Vec<u64>,
}

/// Run the recurrent AC fixpoint directly on a plane pair — the probe
/// body of batched SAC.  `plane` holds the live domains (with the probe
/// assignment already applied); `snap` is the per-sweep Jacobi snapshot
/// buffer.  Prop.-2 incremental candidate sets, seeded from `seed`.
/// No trail: probe domains are scratch and discarded.  Returns true iff
/// the fixpoint is consistent (no domain wiped out).
///
/// The revise loop shares [`revise_var_fused`] with its two siblings —
/// `RtacNative::sweep` (removal sink: trailed `State::remove`) and
/// `RtacParallel::revise_chunk` (removal sink: chunk-relative word
/// masking); this one writes surviving words straight onto the scratch
/// plane.  Only the sink differs; the support predicate and counter
/// accounting live in the shared kernel and are the bit-identity
/// contract.
fn plane_fixpoint(
    problem: &Problem,
    plane: &mut DomainPlane,
    snap: &mut DomainPlane,
    seed: VarId,
    scratch: &mut ProbeScratch,
    counters: &mut Counters,
) -> bool {
    let n = problem.n_vars();
    let nw = words_for(n);
    let isa = simd::active_isa();
    if scratch.changed_bits.len() != nw {
        scratch.changed_bits.clear();
        scratch.changed_bits.resize(nw, 0);
        scratch.affected_bits.clear();
        scratch.affected_bits.resize(nw, 0);
    }
    simd::zero_words(isa, &mut scratch.changed_bits);
    scratch.changed_bits[seed / 64] |= 1u64 << (seed % 64);
    loop {
        counters.recurrences += 1;
        snap.copy_words_from(plane);
        expand_affected(isa, problem, &scratch.changed_bits, &mut scratch.affected_bits);
        simd::zero_words(isa, &mut scratch.changed_bits);
        let Counters { support_checks, removals, .. } = counters;
        let mut any_changed = false;
        let pw = plane.words_mut();
        for wi in 0..nw {
            let mut word = scratch.affected_bits[wi];
            while word != 0 {
                let x = wi * 64 + word.trailing_zeros() as usize;
                word &= word - 1;
                let off = snap.offset(x);
                let (x_changed, x_wiped) =
                    revise_var_fused(isa, problem, snap, x, support_checks, |vw, alive, still| {
                        *removals += (alive & !still).count_ones() as u64;
                        pw[off + vw] = still;
                    });
                if x_changed {
                    scratch.changed_bits[x / 64] |= 1u64 << (x % 64);
                    any_changed = true;
                    if x_wiped {
                        return false;
                    }
                }
            }
        }
        if !any_changed {
            return true;
        }
    }
}

/// The probe-execution seam of batched SAC (the probe-backend decision
/// recorded in ROADMAP.md).  A backend runs one *round* of singleton
/// probes — each asking "is the subproblem with x := a arc consistent?"
/// — against the launch domains in `state` and reports, per probe,
/// whether the probe's AC fixpoint stayed consistent.  The surrounding
/// SAC-1 merge loop in [`SacParallel`] (monotone failed-probe removal +
/// AC re-propagation until a clean pass) is backend-independent.
pub trait ProbeBackend {
    /// Probes submitted per round — the K of the batch loop.
    fn batch(&self) -> usize;

    /// Engine name the wrapping [`Propagator`] reports.
    fn engine_name(&self) -> &'static str;

    /// Run one round of probes against the domains in `state`.  The
    /// caller has already filtered `probes` to live, non-singleton
    /// (var, value) pairs.  Returns one verdict per probe, in order:
    /// `true` iff the probe fixpoint is consistent.  `Err` poisons the
    /// wrapping engine (tensor route: coordinator/session failure — the
    /// CPU backend is infallible).
    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>>;

    /// Per-problem reset hook.
    fn reset(&mut self, _problem: &Problem) {}
}

/// CPU probe backend (`sac-par[N]`): K probes concurrently on the
/// persistent [`WorkerPool`], each on a private scratch plane pair from
/// the [`PlaneSlab`], running the plane-level recurrent fixpoint
/// (`plane_fixpoint`, no trail).
pub struct CpuProbeBackend {
    /// Requested probe workers; 0 = auto (available parallelism).
    workers: usize,
    pool: Option<WorkerPool>,
    slab: PlaneSlab,
    /// Pooled per-probe fixpoint bookkeeping (see [`ProbeScratch`]).
    scratch_pool: Vec<ProbeScratch>,
}

impl CpuProbeBackend {
    pub fn new(workers: usize) -> CpuProbeBackend {
        simd::announce_isa_once();
        CpuProbeBackend { workers, pool: None, slab: PlaneSlab::new(), scratch_pool: Vec::new() }
    }
}

impl ProbeBackend for CpuProbeBackend {
    fn batch(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }

    fn engine_name(&self) -> &'static str {
        "sac-par"
    }

    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>> {
        let k = self.batch();
        let need_pool = match &self.pool {
            Some(p) => p.size() != k,
            None => true,
        };
        if need_pool {
            self.pool = Some(WorkerPool::new(k));
        }
        // Each probe checks out a plane pair and owns it for the
        // probe's lifetime: the live plane is a memcpy of the current
        // domains, the snapshot buffer is uninitialised scratch (the
        // fixpoint overwrites it before reading).
        let mut jobs: Vec<(VarId, Val, DomainPlane, DomainPlane, ProbeScratch)> =
            Vec::with_capacity(probes.len());
        for &(x, a) in probes {
            let cur = self.slab.checkout(state.plane());
            let snap = self.slab.checkout_scratch(state.plane());
            let scratch = self.scratch_pool.pop().unwrap_or_default();
            jobs.push((x, a, cur, snap, scratch));
        }
        let tasks: Vec<_> = jobs
            .into_iter()
            .map(|(x, a, mut cur, mut snap, mut scratch)| {
                move || {
                    let mut c = Counters::default();
                    cur.assign(x, a);
                    let consistent =
                        plane_fixpoint(problem, &mut cur, &mut snap, x, &mut scratch, &mut c);
                    (consistent, cur, snap, scratch, c)
                }
            })
            .collect();
        let results = self.pool.as_mut().expect("pool sized above").run_collect(tasks);
        // Merge in launch order: counters stay deterministic and the
        // scratch buffers go back to their pools before any state-level
        // propagation runs.
        let mut verdicts = Vec::with_capacity(probes.len());
        for (consistent, cur, snap, scratch, c) in results {
            counters.add(&c);
            self.slab.checkin(cur);
            self.slab.checkin(snap);
            self.scratch_pool.push(scratch);
            verdicts.push(consistent);
        }
        Ok(verdicts)
    }

    // pool and slab survive reset: the persistent runtime is the point
    // (the slab drops stale-layout planes lazily on checkout)
}

/// Default probe round size of the tensor route — the largest batch the
/// AOT pipeline compiles (`python/compile/aot.py` BATCHES).
pub const DEFAULT_TENSOR_PROBE_BATCH: usize = 8;

/// Tensor probe backend (`sac-xla[N]`): probes are staged straight from
/// the domain-plane arena and routed through the coordinator onto the
/// compiled `fixb*` executables.  One [`encode_vars_into`] pass per
/// round stages the launch domains; each probe plane is then the staged
/// base with a single row edited to the singleton `{a}` — no per-probe
/// re-gather.
///
/// Three submission shapes:
/// * **fused delta** ([`XlaProbeBackend::new`], the default) — the
///   backend attaches its own session client; the staged base is
///   uploaded into that client's slot once per round
///   ([`Handle::upload_base`], skipped while unchanged) and each probe
///   ships only its single-row [`PlaneDelta`] through
///   [`Handle::submit_batch_delta`]: a K-probe round moves one plane +
///   K rows host→executor, and concurrent delta writers on the same
///   session stay independent (per-client base slots).
/// * **fused full** ([`XlaProbeBackend::full_plane`]) — K full planes
///   through [`Handle::submit_batch`]; the PR-3 behavior, kept as the
///   upload-volume baseline.
/// * **per-probe** ([`XlaProbeBackend::per_probe`]) — one blocking
///   full-plane request at a time: every probe gambles against the
///   executor's `max_wait` deadline on its own (the occupancy baseline
///   `rtac serve --sac-probe` measures against).
pub struct XlaProbeBackend {
    handle: Handle,
    /// This backend's session client + its stale-drop watermark (the
    /// shared stale-vs-fatal classifier,
    /// [`crate::coordinator::StaleTracker`]).  `Some` iff this backend
    /// ships deltas; the full-plane/per-probe baselines attach nothing
    /// ([`Handle::attach`] is for delta writers).
    client: Option<StaleTracker>,
    /// Probes per round; 0 = auto ([`DEFAULT_TENSOR_PROBE_BATCH`]).
    batch: usize,
    /// Round staging buffer: the launch domains, encoded once per round.
    staging: Vec<f32>,
    /// Fused (`submit_batch`) vs per-probe (`enforce_blocking`) routing.
    fused: bool,
    /// Ship rounds as base + delta rows instead of full planes.
    delta: bool,
    /// Fingerprint of the last base plane this backend uploaded.  When
    /// consecutive rounds launch from unchanged domains (the common
    /// case on consistent instances: whole passes remove nothing), the
    /// staged plane — and thus its fingerprint — is identical, so the
    /// re-upload is skipped and a pass ships ONE base total.  Sound
    /// because the slot is keyed to this backend's client (no other
    /// writer replaces it) and content-fingerprinted; if the slot is
    /// *evicted* under the session's cap, the stale round is retried
    /// with fresh uploads under `retry`'s bounded budget (see
    /// `collect_round_with_recovery`).
    last_base_fp: Option<u64>,
    /// The shared session retry policy behind the fresh-base recovery:
    /// bounded resubmission attempts, stale drops classified transient,
    /// everything else fatal (see `coordinator::retry`).
    retry: RetryPolicy,
    /// Fingerprint of the problem this backend first probed.  The
    /// session's constraint tensor is device-resident and per-problem,
    /// so probing a *different* problem through the same handle would
    /// silently evaluate against the wrong constraints — detected here
    /// and surfaced as a poisoning error instead.
    bound: Option<u64>,
}

impl XlaProbeBackend {
    /// Fused delta-mode backend — the default submission shape.
    /// Attaches a fresh session client for its base slot.
    pub fn new(handle: Handle, batch: usize) -> XlaProbeBackend {
        let tracker = StaleTracker::attach(&handle);
        XlaProbeBackend { client: Some(tracker), ..XlaProbeBackend::shape(handle, batch) }
    }

    /// Fused full-plane backend: the upload-volume baseline (no session
    /// client — nothing delta-shaped is shipped).
    pub fn full_plane(handle: Handle, batch: usize) -> XlaProbeBackend {
        XlaProbeBackend { delta: false, ..XlaProbeBackend::shape(handle, batch) }
    }

    /// The per-probe submission baseline: same backend, but every probe
    /// gambles against the executor's `max_wait` deadline on its own.
    pub fn per_probe(handle: Handle, batch: usize) -> XlaProbeBackend {
        XlaProbeBackend { fused: false, delta: false, ..XlaProbeBackend::shape(handle, batch) }
    }

    /// The common field layout (fused delta shape, no client attached —
    /// the public constructors override from here).
    fn shape(handle: Handle, batch: usize) -> XlaProbeBackend {
        XlaProbeBackend {
            handle,
            client: None,
            batch,
            staging: Vec::new(),
            fused: true,
            delta: true,
            last_base_fp: None,
            retry: RetryPolicy::no_backoff(3),
            bound: None,
        }
    }

    /// Did the last failed round die because OUR base slot went stale
    /// (evicted/out of sync) rather than because the session is gone?
    /// Delegates to the shared [`StaleTracker`]; always false for the
    /// non-delta baselines (no client attached).
    fn absorb_stale_drop(&mut self) -> bool {
        match &mut self.client {
            Some(tracker) => tracker.absorb_stale_drop(&self.handle),
            None => false,
        }
    }

    /// Largest compiled `fixb*` capacity of the session — how many
    /// probes one fused execution can amortise its dispatch over.  The
    /// mixed scheduler's cost model reads this.
    pub fn fused_capacity(&self) -> usize {
        self.handle.compiled_batches.last().copied().unwrap_or(1)
    }

    /// One full probe plane derived from the staged base: row `x`
    /// reduced to the singleton `{a}` — the single definition of the
    /// probe shape shared by every full-plane submission path.
    fn probe_plane(&self, x: VarId, a: Val) -> Vec<f32> {
        let d = self.handle.bucket.d;
        let mut plane = self.staging.clone();
        let row = &mut plane[x * d..(x + 1) * d];
        row.fill(0.0);
        row[a] = 1.0;
        plane
    }

    /// The handle's session owns a device-resident constraint tensor
    /// for ONE problem; refuse to probe a different one (the
    /// fingerprint walk is microseconds next to an XLA round-trip).
    fn check_bound(&mut self, problem: &Problem) -> anyhow::Result<()> {
        let fp = problem_fingerprint(problem);
        match self.bound {
            None => self.bound = Some(fp),
            Some(bound) if bound != fp => anyhow::bail!(
                "tensor probe backend is bound to another problem's session (the \
                 constraint tensor is device-resident) — build a new \
                 SacParallel::tensor against a fresh session, or use SacXla which \
                 restarts sessions on problem switches"
            ),
            Some(_) => {}
        }
        Ok(())
    }

    /// Stage one fused round (encode the launch domains once, derive
    /// each probe by a row edit — shipped as deltas or full planes) and
    /// submit it without blocking.  Returns the response receivers in
    /// probe order; [`XlaProbeBackend::collect_round`] turns them into
    /// verdicts.  The split lets the mixed scheduler overlap the CPU
    /// share of a round with the in-flight fused executions.
    fn submit_round(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
    ) -> anyhow::Result<Vec<mpsc::Receiver<Response>>> {
        debug_assert!(self.fused, "per-probe submission has no staged round");
        self.check_bound(problem)?;
        let bucket = self.handle.bucket;
        encode_vars_into(state.plane(), bucket, &mut self.staging)?;
        if self.delta {
            let client = self
                .client
                .as_ref()
                .expect("delta backends attach at construction")
                .client();
            let fp = plane_fingerprint(&self.staging);
            if self.last_base_fp != Some(fp) {
                let uploaded = self.handle.upload_base(client, self.staging.clone())?;
                debug_assert_eq!(uploaded, fp);
                self.last_base_fp = Some(fp);
            }
            let deltas: Vec<PlaneDelta> = probes
                .iter()
                .map(|&(x, a)| PlaneDelta::singleton(fp, x, a, bucket))
                .collect();
            self.handle.submit_batch_delta(client, deltas)
        } else {
            let planes: Vec<Vec<f32>> =
                probes.iter().map(|&(x, a)| self.probe_plane(x, a)).collect();
            self.handle.submit_batch(planes)
        }
    }

    /// Block for a staged round's responses and fold them into
    /// verdicts (`true` = the probe's fixpoint stayed consistent).
    /// The round's work accounting comes back ALONGSIDE the verdicts
    /// instead of being merged into the caller's counters directly, so
    /// a round that fails mid-collect contributes nothing — the mixed
    /// scheduler then re-probes the share on the CPU without
    /// double-counting the partially-received tensor responses.
    fn collect_round(
        &self,
        receivers: Vec<mpsc::Receiver<Response>>,
    ) -> anyhow::Result<CollectedRound> {
        let mut round = CollectedRound {
            verdicts: Vec::with_capacity(receivers.len()),
            recurrences: 0,
            latency: std::time::Duration::ZERO,
        };
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx
                .recv()
                .map_err(|_| self.handle.dropped_err().context(format!("staged probe {i}")))?;
            // joint sweep count of the fused execution that served
            // this probe — the tensor-side #Recurrence
            round.recurrences += r.iters.max(0) as u64;
            // executor-side latency (submit → response), so the mixed
            // scheduler's EWMA is not polluted by whatever the caller
            // did between submit and collect
            round.latency = round.latency.max(r.total_time);
            round.verdicts.push(!r.wiped());
        }
        Ok(round)
    }

    /// Collect a staged round, recovering base-slot evictions through
    /// the session [`RetryPolicy`]: attempt 0 collects the receivers
    /// already in flight; each later attempt re-uploads a fresh base
    /// (`last_base_fp = None`) and restages the whole round.  A failure
    /// the [`StaleTracker`] attributes to OUR slot going stale is
    /// classified [`Retry::Transient`] (an eviction under the session's
    /// cap — re-upload and go again); anything else is
    /// [`Retry::Fatal`] (the session is dead, moribund, or past its
    /// deadline).  Shared by the standalone fused path and the mixed
    /// scheduler's tensor share, replacing their former one-shot
    /// ad-hoc retries.
    fn collect_round_with_recovery(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        receivers: Vec<mpsc::Receiver<Response>>,
    ) -> anyhow::Result<CollectedRound> {
        let retry = self.retry;
        let mut staged = Some(receivers);
        let mut recovered = false;
        let round = retry.run(
            "fused probe round kept dying to base-slot eviction — more concurrent \
             delta writers than the session's base_slots cap (raise --base-slots \
             or shrink the writer count)",
            |attempt| {
                let receivers = match staged.take() {
                    Some(receivers) => receivers,
                    None => {
                        // a previous attempt observed a stale drop:
                        // force a fresh base upload and restage
                        self.last_base_fp = None;
                        self.submit_round(problem, state, probes).map_err(Retry::Fatal)?
                    }
                };
                match self.collect_round(receivers) {
                    Ok(round) => {
                        recovered = attempt > 0;
                        Ok(round)
                    }
                    Err(e) => {
                        if self.absorb_stale_drop() {
                            Err(Retry::Transient(e))
                        } else {
                            Err(Retry::Fatal(e))
                        }
                    }
                }
            },
        )?;
        if recovered {
            // the failed round's TAIL deltas (behind the one whose drop
            // we observed) were also dropped stale, after the absorb
            // that classified the failure — absorb them too, or the
            // next fatal failure would be misclassified as a stale
            // slot.  Safe here: the retried round completed, so no
            // delta of ours is in flight.
            let _ = self.absorb_stale_drop();
        }
        Ok(round)
    }
}

/// One successfully collected fused probe round (see
/// [`XlaProbeBackend`]'s `collect_round`).
struct CollectedRound {
    verdicts: Vec<bool>,
    /// Summed tensor-side `#Recurrence` of the round's executions.
    recurrences: u64,
    /// Largest `Response::total_time` across the round.
    latency: std::time::Duration,
}

impl ProbeBackend for XlaProbeBackend {
    fn batch(&self) -> usize {
        if self.batch > 0 {
            self.batch
        } else {
            DEFAULT_TENSOR_PROBE_BATCH
        }
    }

    fn engine_name(&self) -> &'static str {
        "sac-xla"
    }

    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>> {
        if self.fused {
            // stale drops (our base slot evicted under the session's
            // cap by another writer's upload while we were skipping
            // re-uploads) are recovered with fresh bases under the
            // bounded session RetryPolicy — degradation to a few extra
            // planes, never a poisoned engine or a wrong verdict
            let receivers = self.submit_round(problem, state, probes)?;
            let round = self.collect_round_with_recovery(problem, state, probes, receivers)?;
            counters.recurrences += round.recurrences;
            return Ok(round.verdicts);
        }
        self.check_bound(problem)?;
        let bucket = self.handle.bucket;
        encode_vars_into(state.plane(), bucket, &mut self.staging)?;
        let responses = probes
            .iter()
            .map(|&(x, a)| self.handle.enforce_blocking(self.probe_plane(x, a)))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(responses
            .into_iter()
            .map(|r| {
                counters.recurrences += r.iters.max(0) as u64;
                !r.wiped()
            })
            .collect())
    }
}

/// Exponentially weighted moving average of per-probe latency (µs) —
/// the measured half of the mixed scheduler's cost model.
struct Ewma {
    value: Option<f64>,
}

impl Ewma {
    const ALPHA: f64 = 0.3;

    fn new() -> Ewma {
        Ewma { value: None }
    }

    fn observe(&mut self, v: f64) {
        self.value = Some(match self.value {
            None => v,
            Some(prev) => Self::ALPHA * v + (1.0 - Self::ALPHA) * prev,
        });
    }

    fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Routing counters of the [`MixedProbeBackend`], shared (`Arc`) so the
/// bench and `rtac serve --sac-probe` can report how a run actually
/// split after the engine is boxed behind the [`ProbeBackend`] seam.
#[derive(Debug, Default)]
pub struct MixedStats {
    cpu_probes: AtomicU64,
    tensor_probes: AtomicU64,
    tensor_fallbacks: AtomicU64,
}

impl MixedStats {
    /// Probes that ran on the CPU pool (including tensor-share probes
    /// re-run on the CPU after a tensor-route failure).
    pub fn cpu_probes(&self) -> u64 {
        self.cpu_probes.load(Ordering::Relaxed)
    }

    /// Probes served by fused tensor executions.
    pub fn tensor_probes(&self) -> u64 {
        self.tensor_probes.load(Ordering::Relaxed)
    }

    /// Tensor-route failures that degraded the backend to CPU-only.
    pub fn tensor_fallbacks(&self) -> u64 {
        self.tensor_fallbacks.load(Ordering::Relaxed)
    }
}

/// How a [`MixedProbeBackend`] divides each probe round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixedSplit {
    /// Cost-model split (the default): see
    /// [`MixedProbeBackend::auto_split`].
    Auto,
    /// Force every probe onto the CPU pool (also the effective mode
    /// whenever no tensor session is available).
    CpuOnly,
    /// Force every probe onto the tensor route (testing/benching the
    /// tensor half in isolation; still falls back on failure).
    TensorOnly,
}

/// Probe-size-aware mixed CPU/tensor scheduling (`sac-mixed[N]`): each
/// round is split between [`CpuProbeBackend`] and [`XlaProbeBackend`]
/// by a cost model, the tensor share submitted first so its fused
/// executions overlap the CPU share running on the pool.
///
/// The cost model estimates per-probe latency for each backend:
/// * **CPU** — seeded from the domain-plane word count (a probe
///   fixpoint sweeps the whole plane a few times), then replaced by a
///   measured EWMA;
/// * **tensor** — seeded from a fixed dispatch overhead amortised over
///   the largest compiled `fixb*` capacity
///   ([`XlaProbeBackend::fused_capacity`]), then replaced by a measured
///   EWMA of fused round latency per probe.
///
/// Small planes therefore start CPU-heavy (dispatch overhead dominates
/// — the Tardivo-style kernel-vs-host crossover), large planes start
/// tensor-heavy, and the measured EWMAs correct both within a few
/// rounds.  Merging the two halves' failed-probe sets is sound because
/// probe failure is monotone regardless of which backend observed it;
/// the SAC closure is unique, so any split reaches the same fixpoint.
pub struct MixedProbeBackend {
    cpu: CpuProbeBackend,
    /// The tensor half; `None` = offline (or degraded after a failure):
    /// every probe runs on the CPU.
    tensor: Option<XlaProbeBackend>,
    split: MixedSplit,
    /// Measured per-probe latency (µs), one EWMA per backend.
    cpu_ewma: Ewma,
    tensor_ewma: Ewma,
    /// Rounds since each route last received probes.  A route whose
    /// share hits 0 stops producing latency observations, so one
    /// anomalous measurement (a cold first execution, a transient
    /// stall) could freeze its EWMA and starve it forever; after
    /// [`MixedProbeBackend::EXPLORE_EVERY`] such rounds the starved
    /// route gets one probe to re-measure with.
    rounds_since_tensor: u32,
    rounds_since_cpu: u32,
    stats: Arc<MixedStats>,
}

impl MixedProbeBackend {
    /// Cost-model seed: µs of CPU probe time per domain-plane word.
    const SEED_CPU_US_PER_WORD: f64 = 0.05;
    /// Cost-model seed: µs of fixed dispatch overhead per fused tensor
    /// execution (amortised over the compiled batch capacity).
    const SEED_TENSOR_DISPATCH_US: f64 = 200.0;
    /// Auto-split exploration cadence: a route that has been starved
    /// for this many consecutive rounds gets one probe to refresh its
    /// latency EWMA (see `rounds_since_tensor`/`rounds_since_cpu`).
    const EXPLORE_EVERY: u32 = 8;

    /// CPU-only backend (`workers` 0 = auto) — what `sac-mixed[N]`
    /// degrades to without compiled artifacts.
    pub fn cpu_only(workers: usize) -> MixedProbeBackend {
        MixedProbeBackend {
            cpu: CpuProbeBackend::new(workers),
            tensor: None,
            split: MixedSplit::Auto,
            cpu_ewma: Ewma::new(),
            tensor_ewma: Ewma::new(),
            rounds_since_tensor: 0,
            rounds_since_cpu: 0,
            stats: Arc::new(MixedStats::default()),
        }
    }

    /// Mixed backend over an existing session, tensor rounds shipped as
    /// **full planes** — the upload-volume baseline (and the shape to
    /// force when comparing against delta rounds).
    pub fn with_tensor(workers: usize, handle: Handle, tensor_batch: usize) -> MixedProbeBackend {
        MixedProbeBackend {
            tensor: Some(XlaProbeBackend::full_plane(handle, tensor_batch)),
            ..MixedProbeBackend::cpu_only(workers)
        }
    }

    /// Mixed backend over any session, tensor rounds shipped in delta
    /// form (one base + K rows) on the backend's own session client —
    /// what [`SacMixed`] and the `sac-mixed` search workers build.
    /// Per-client base slots keep concurrent writers on a shared
    /// session from invalidating each other.
    pub fn with_tensor_delta(
        workers: usize,
        handle: Handle,
        tensor_batch: usize,
    ) -> MixedProbeBackend {
        MixedProbeBackend {
            tensor: Some(XlaProbeBackend::new(handle, tensor_batch)),
            ..MixedProbeBackend::cpu_only(workers)
        }
    }

    /// Pin the split policy (builder-style); [`MixedSplit::Auto`] is
    /// the default.
    pub fn with_split(mut self, split: MixedSplit) -> MixedProbeBackend {
        self.split = split;
        self
    }

    /// Shared routing counters (clone before boxing the backend).
    pub fn stats(&self) -> Arc<MixedStats> {
        self.stats.clone()
    }

    /// The pure split rule: given per-probe latency estimates (µs) for
    /// the two concurrent backends, send each a share inversely
    /// proportional to its latency, so both halves of the round finish
    /// together.  Returns the tensor share of `len` probes.
    pub fn auto_split(cpu_probe_us: f64, tensor_probe_us: f64, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let total = cpu_probe_us + tensor_probe_us;
        if !total.is_finite() || total <= 0.0 {
            return len / 2; // degenerate estimates: split evenly
        }
        let tensor_frac = cpu_probe_us / total;
        (((len as f64) * tensor_frac).round() as usize).min(len)
    }

    /// Tensor share of the next `len`-probe round against `state`.
    fn tensor_share(&self, state: &State, len: usize) -> usize {
        let Some(tensor) = &self.tensor else { return 0 };
        match self.split {
            MixedSplit::CpuOnly => 0,
            MixedSplit::TensorOnly => len,
            MixedSplit::Auto => {
                let words = state.plane().total_words().max(1) as f64;
                let cpu_us =
                    self.cpu_ewma.get().unwrap_or(words * Self::SEED_CPU_US_PER_WORD);
                let tensor_us = self.tensor_ewma.get().unwrap_or(
                    Self::SEED_TENSOR_DISPATCH_US / tensor.fused_capacity().max(1) as f64,
                );
                Self::auto_split(cpu_us, tensor_us, len)
            }
        }
    }

    /// Drop the tensor half after a failure: the backend degrades to
    /// CPU-only instead of poisoning the engine (the CPU route answers
    /// every probe the tensor route would have).
    fn degrade(&mut self, stage: &str, e: &anyhow::Error) {
        eprintln!("sac-mixed: tensor route failed at {stage}, degrading to CPU-only: {e:#}");
        self.stats.tensor_fallbacks.fetch_add(1, Ordering::Relaxed);
        self.tensor = None;
    }
}

impl ProbeBackend for MixedProbeBackend {
    fn batch(&self) -> usize {
        match (&self.tensor, self.split) {
            (None, _) | (Some(_), MixedSplit::CpuOnly) => self.cpu.batch(),
            (Some(t), MixedSplit::TensorOnly) => t.batch(),
            // a mixed round keeps both backends busy at once
            (Some(t), MixedSplit::Auto) => self.cpu.batch() + t.batch(),
        }
    }

    fn engine_name(&self) -> &'static str {
        "sac-mixed"
    }

    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>> {
        let mut n_tensor = self.tensor_share(state, probes.len());
        // exploration: a route starved for EXPLORE_EVERY rounds gets one
        // probe back so its latency EWMA can recover from an anomalous
        // observation (otherwise a share of 0 is self-perpetuating)
        if self.tensor.is_some() && self.split == MixedSplit::Auto && probes.len() > 1 {
            if n_tensor == 0 && self.rounds_since_tensor >= Self::EXPLORE_EVERY {
                n_tensor = 1;
            } else if n_tensor == probes.len() && self.rounds_since_cpu >= Self::EXPLORE_EVERY {
                n_tensor = probes.len() - 1;
            }
        }
        if n_tensor == 0 {
            self.rounds_since_tensor += 1;
        } else {
            self.rounds_since_tensor = 0;
        }
        if n_tensor == probes.len() {
            self.rounds_since_cpu += 1;
        } else {
            self.rounds_since_cpu = 0;
        }
        let (tensor_probes, cpu_probes) = probes.split_at(n_tensor);
        // 1. submit the tensor share without blocking
        let staged = if tensor_probes.is_empty() {
            None
        } else {
            let tensor = self.tensor.as_mut().expect("tensor_share > 0 implies a tensor half");
            match tensor.submit_round(problem, state, tensor_probes) {
                Ok(receivers) => Some(receivers),
                Err(e) => {
                    self.degrade("submit", &e);
                    None
                }
            }
        };
        // 2. the CPU share runs while the fused executions are in flight
        let t_cpu = Instant::now();
        let mut cpu_verdicts = if cpu_probes.is_empty() {
            Vec::new()
        } else {
            self.cpu.run_probes(problem, state, cpu_probes, counters)?
        };
        if !cpu_probes.is_empty() {
            let us = t_cpu.elapsed().as_secs_f64() * 1e6;
            self.cpu_ewma.observe(us / cpu_probes.len() as f64);
            self.stats.cpu_probes.fetch_add(cpu_probes.len() as u64, Ordering::Relaxed);
        }
        // 3. collect the tensor share; eviction-induced stale drops are
        // recovered with fresh base uploads under the shared session
        // RetryPolicy (the exact recovery loop of the standalone
        // backend, so sac-mixed on a crowded session does not shed its
        // tensor half permanently); on a fatal failure or an exhausted
        // retry budget (or a failed submit), re-probe that share on the
        // CPU — same launch domains, same verdicts, so the merge loop
        // never notices
        let mut tensor_verdicts = match staged {
            Some(receivers) => {
                let tensor = self.tensor.as_mut().expect("tensor half still present");
                let collected =
                    tensor.collect_round_with_recovery(problem, state, tensor_probes, receivers);
                match collected {
                    Ok(round) => {
                        // the round's work counts only on success: a
                        // failed collect re-probes on the CPU below, and
                        // merging partial tensor responses too would
                        // double-count #Recurrence for those probes
                        counters.recurrences += round.recurrences;
                        // executor-side round latency (max submit→response
                        // across the share): unlike wall time here, it does
                        // NOT include the CPU share that ran in between, so
                        // the cost model is not biased against the tensor
                        // route when the CPU share is the slow half
                        let us = round.latency.as_secs_f64() * 1e6;
                        self.tensor_ewma.observe(us / tensor_probes.len() as f64);
                        self.stats
                            .tensor_probes
                            .fetch_add(tensor_probes.len() as u64, Ordering::Relaxed);
                        round.verdicts
                    }
                    Err(e) => {
                        self.degrade("collect", &e);
                        self.stats
                            .cpu_probes
                            .fetch_add(tensor_probes.len() as u64, Ordering::Relaxed);
                        self.cpu.run_probes(problem, state, tensor_probes, counters)?
                    }
                }
            }
            None if !tensor_probes.is_empty() => {
                // submit failed above: the share still must be answered
                self.stats.cpu_probes.fetch_add(tensor_probes.len() as u64, Ordering::Relaxed);
                self.cpu.run_probes(problem, state, tensor_probes, counters)?
            }
            None => Vec::new(),
        };
        // 4. merge in probe order: [tensor share | cpu share]
        tensor_verdicts.append(&mut cpu_verdicts);
        Ok(tensor_verdicts)
    }

    fn reset(&mut self, problem: &Problem) {
        self.cpu.reset(problem);
        if let Some(t) = &mut self.tensor {
            t.reset(problem);
        }
    }
}

/// Batched SAC-1 over a [`ProbeBackend`] — `sac-par[N]` on the CPU
/// pool, `sac-xla[N]` through the coordinator.
pub struct SacParallel {
    /// State-level AC for the root closure and post-removal
    /// re-propagation (the probes themselves run backend-side).
    inner: RtacNative,
    backend: Box<dyn ProbeBackend>,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
    /// Candidate (var, value) pairs of the current pass.
    pairs: Vec<(VarId, Val)>,
    /// Set on a backend failure (tensor route only): the engine is then
    /// poisoned and reports wipeouts, like `TensorEngine`.
    pub failed: Option<String>,
    /// Optional probe-round memo ([`SacParallel::with_fixcache`]): a
    /// round keyed by `(problem fingerprint, launch domains + probe
    /// list)` replays its verdict vector AND its counter delta, so
    /// repeated rounds — SAC's final clean pass, re-enforcement at
    /// repeated search nodes, restarts — short-circuit bit-identically.
    /// Entries are content-addressed, so the cache stays valid across
    /// `reset` and across problems.
    fixcache: Option<Arc<FixCache>>,
}

/// Fingerprint of one probe round's inputs: the launch domain words
/// plus the ordered probe list (FNV-1a, the repo-wide fingerprint
/// idiom).  Combined with [`problem_fingerprint`] this keys a round's
/// memo entry ([`FixCache::insert_round`]).
fn probe_round_fingerprint(state: &State, round: &[(VarId, Val)]) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01b3)
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &w in state.plane().words() {
        h = mix(h, w);
    }
    for &(x, a) in round {
        h = mix(h, ((x as u64) << 32) | a as u64);
    }
    h
}

impl SacParallel {
    /// CPU-pool probes (`sac-par[N]`); `workers` 0 = auto.
    pub fn new(workers: usize) -> SacParallel {
        SacParallel::with_backend(Box::new(CpuProbeBackend::new(workers)))
    }

    /// Coordinator-routed probes (`sac-xla[N]`) against an existing
    /// session; `batch` 0 = auto.
    pub fn tensor(handle: Handle, batch: usize) -> SacParallel {
        SacParallel::with_backend(Box::new(XlaProbeBackend::new(handle, batch)))
    }

    /// Any probe backend — the seam the tests and `rtac serve` use.
    pub fn with_backend(backend: Box<dyn ProbeBackend>) -> SacParallel {
        SacParallel {
            inner: RtacNative::incremental(),
            backend,
            probes: 0,
            pairs: Vec::new(),
            failed: None,
            fixcache: None,
        }
    }

    /// Attach (or detach, with `None`) a probe-round memo — typically a
    /// per-shard cache shared with the serving tier, or a private one
    /// from [`FixCache::shared`].  Soundness: the AC closure is unique
    /// (Prop. 1), so an identical round can only ever produce the
    /// identical verdict vector and counter delta — a hit is
    /// bit-identical to the run it skips.
    pub fn with_fixcache(mut self, fixcache: Option<Arc<FixCache>>) -> SacParallel {
        self.fixcache = fixcache;
        self
    }

    /// Enforce SAC with batched probes.  Returns the outcome; `counters`
    /// accumulates the work of every probe plus the state-level AC runs.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        // the memo key's constraint half, once per enforcement
        // (microseconds next to a single probe round)
        let cons_fp = self.fixcache.as_ref().map(|_| problem_fingerprint(problem));
        let k = self.backend.batch().max(1);
        loop {
            let mut removed_any = false;
            // This pass's candidates: every live value of every
            // non-singleton variable (SAC-1's probe set).
            self.pairs.clear();
            for x in 0..problem.n_vars() {
                if state.dom_size(x) <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                self.pairs.extend(state.dom(x).iter_ones().map(|a| (x, a)));
            }
            let mut start = 0usize;
            while start < self.pairs.len() {
                let end = (start + k).min(self.pairs.len());
                // Launch up to k probes against the CURRENT domains,
                // skipping values already removed by an earlier round's
                // fallout, and variables that fallout reduced to a
                // singleton (a singleton that survived AC is SAC — the
                // probe outcome is known).
                let round: Vec<(VarId, Val)> = self.pairs[start..end]
                    .iter()
                    .copied()
                    .filter(|&(x, a)| state.contains(x, a) && state.dom_size(x) > 1)
                    .collect();
                start = end;
                if round.is_empty() {
                    continue;
                }
                self.probes += round.len() as u64;
                // consult the round memo first: a hit replays the
                // verdict vector and the counter delta of the original
                // run (unique closure ⇒ bit-identical), skipping the
                // backend entirely; a miss runs the round against a
                // fresh delta so the admitted entry attributes exactly
                // this round's work
                let memo = self.fixcache.clone().map(|cache| {
                    let fp = probe_round_fingerprint(state, &round);
                    (cache, cons_fp.expect("fingerprinted when a cache is attached"), fp)
                });
                let cached =
                    memo.as_ref().and_then(|(cache, cf, rfp)| cache.lookup_round(*cf, *rfp));
                let verdicts = if let Some((verdicts, delta)) = cached {
                    counters.add(&delta);
                    verdicts
                } else if let Some((cache, cf, rfp)) = &memo {
                    let mut delta = Counters::default();
                    match self.backend.run_probes(problem, state, &round, &mut delta) {
                        Ok(verdicts) => {
                            counters.add(&delta);
                            cache.insert_round(*cf, *rfp, &verdicts, &delta);
                            verdicts
                        }
                        Err(e) => {
                            // a session accident, not a content-
                            // addressed fact: nothing is admitted
                            self.failed = Some(format!("{e:#}"));
                            return Outcome::Wipeout(0);
                        }
                    }
                } else {
                    match self.backend.run_probes(problem, state, &round, counters) {
                        Ok(v) => v,
                        Err(e) => {
                            self.failed = Some(format!("{e:#}"));
                            return Outcome::Wipeout(0);
                        }
                    }
                };
                debug_assert_eq!(verdicts.len(), round.len());
                // Probe failure is monotone (see module docs): every
                // failed probe's value goes, each followed by AC
                // re-propagation — exactly SAC-1's confirmed-removal
                // step, just k at a time.
                for ((x, a), consistent) in round.into_iter().zip(verdicts) {
                    if consistent {
                        continue;
                    }
                    if !state.contains(x, a) {
                        continue; // an earlier removal's fallout got it
                    }
                    state.remove(x, a);
                    removed_any = true;
                    if state.wiped(x) {
                        return Outcome::Wipeout(x);
                    }
                    let out = self.inner.enforce(problem, state, &[x], counters);
                    if !out.is_consistent() {
                        return out;
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl Propagator for SacParallel {
    fn name(&self) -> &'static str {
        self.backend.engine_name()
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.backend.reset(problem);
        self.probes = 0;
        self.failed = None;
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

/// `sac-xla[N]` as a self-contained engine: lazily starts — and owns —
/// a coordinator session for the problem it enforces on, then runs
/// [`SacParallel`] with the [`XlaProbeBackend`].  Sessions are
/// per-problem (the constraint tensor is device-resident), so the
/// session restarts when the problem changes (`reset`, or a different
/// problem fingerprint at `enforce`).  Artifact-gated: without compiled
/// artifacts the first enforcement poisons the engine (`failed`) and
/// reports wipeout, like `TensorEngine` on a coordinator failure.
pub struct SacXla {
    /// Probes per round; 0 = auto.
    batch: usize,
    artifact_dir: std::path::PathBuf,
    session: Option<(crate::coordinator::Coordinator, SacParallel)>,
    /// Fingerprint of the problem the live session serves.
    session_key: Option<u64>,
    pub failed: Option<String>,
}

/// Content fingerprint of a problem: variable count, domain sizes, and
/// every constraint's scope + relation bits.  Guards [`SacXla`]'s
/// session reuse — the constraint tensor is device-resident, so reusing
/// a session for a same-*shaped* but different problem would silently
/// probe against the wrong constraints — and keys session *placement*
/// in the fleet tier ([`crate::coordinator::fleet`]): identical
/// constraint content from different clients hashes to the same shard
/// and shares one compiled session there.  O(e·d²), but the serving
/// paths only fingerprint bucket-sized problems, where that is
/// microseconds.
pub fn problem_fingerprint(problem: &Problem) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01b3) // FNV-1a step
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, problem.n_vars() as u64);
    for v in 0..problem.n_vars() {
        h = mix(h, problem.dom_size(v) as u64);
    }
    for c in problem.constraints() {
        h = mix(h, ((c.x as u64) << 32) | c.y as u64);
        for a in 0..c.rel.dx() {
            for b in c.rel.row_fwd(a).iter_ones() {
                h = mix(h, ((a as u64) << 32) | b as u64);
            }
        }
    }
    h
}

impl SacXla {
    /// Engine against `runtime::default_artifact_dir()` (what
    /// `make_engine("sac-xla[N]")` constructs).
    pub fn new(batch: usize) -> SacXla {
        SacXla::with_artifact_dir(batch, crate::runtime::default_artifact_dir())
    }

    pub fn with_artifact_dir(batch: usize, artifact_dir: std::path::PathBuf) -> SacXla {
        SacXla { batch, artifact_dir, session: None, session_key: None, failed: None }
    }

    fn ensure_session(&mut self, problem: &Problem) -> anyhow::Result<()> {
        let key = problem_fingerprint(problem);
        if self.session.is_some() && self.session_key == Some(key) {
            return Ok(());
        }
        self.session = None;
        self.session_key = None;
        let config = crate::coordinator::CoordinatorConfig {
            artifact_dir: self.artifact_dir.clone(),
            // adaptive batching: probe rounds arrive as contiguous
            // bursts, so the executor sizes its window from what it
            // actually sees instead of a fixed policy
            policy: crate::coordinator::BatchPolicy { adaptive: true, ..Default::default() },
        };
        let coordinator = crate::coordinator::Coordinator::start(problem, config)?;
        let engine = SacParallel::tensor(coordinator.handle(), self.batch);
        self.session = Some((coordinator, engine));
        self.session_key = Some(key);
        Ok(())
    }
}

impl Propagator for SacXla {
    fn name(&self) -> &'static str {
        "sac-xla"
    }

    fn reset(&mut self, _problem: &Problem) {
        // per-problem session: tear it down; the next enforcement
        // starts a fresh one (and re-uploads the constraint tensor)
        self.session = None;
        self.session_key = None;
        self.failed = None;
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        if let Err(e) = self.ensure_session(problem) {
            let msg = format!("starting coordinator session: {e:#}");
            eprintln!("sac-xla: {msg}");
            self.failed = Some(msg);
            return Outcome::Wipeout(0);
        }
        let (_, engine) = self.session.as_mut().expect("session ensured above");
        let out = engine.enforce_sac(problem, state, counters);
        if let Some(e) = engine.failed.clone() {
            eprintln!("sac-xla: {e}");
            self.failed = Some(e);
        }
        out
    }
}

/// `sac-mixed[N]` as a self-contained engine: lazily starts — and owns
/// — a coordinator session for the problem it enforces on, then runs
/// [`SacParallel`] with a [`MixedProbeBackend`] whose tensor half ships
/// delta rounds on its own session client.  Without compiled
/// artifacts (or after a session start failure) the engine runs
/// **CPU-only instead of poisoning**: the mixed scheduler's contract is
/// that the CPU route can always answer every probe, so offline
/// environments get `sac-par`-equivalent behavior under the same name.
/// Sessions are per-problem (the constraint tensor is device-resident),
/// so the session restarts when the problem changes.
pub struct SacMixed {
    /// CPU probe workers (0 = auto) — the N of `sac-mixed[N]`.
    workers: usize,
    artifact_dir: std::path::PathBuf,
    /// The owned session backing the tensor half (None offline).
    session: Option<crate::coordinator::Coordinator>,
    engine: Option<SacParallel>,
    /// Fingerprint of the problem the live engine serves.
    session_key: Option<u64>,
    /// Routing counters of the live backend (None before first use).
    stats: Option<Arc<MixedStats>>,
    pub failed: Option<String>,
}

impl SacMixed {
    /// Engine against `runtime::default_artifact_dir()` (what
    /// `make_engine("sac-mixed[N]")` constructs).
    pub fn new(workers: usize) -> SacMixed {
        SacMixed::with_artifact_dir(workers, crate::runtime::default_artifact_dir())
    }

    pub fn with_artifact_dir(workers: usize, artifact_dir: std::path::PathBuf) -> SacMixed {
        SacMixed {
            workers,
            artifact_dir,
            session: None,
            engine: None,
            session_key: None,
            stats: None,
            failed: None,
        }
    }

    /// Routing counters of the current problem's backend, if any round
    /// ran (how many probes went to each half, and whether the tensor
    /// route degraded).
    pub fn stats(&self) -> Option<Arc<MixedStats>> {
        self.stats.clone()
    }

    fn ensure_engine(&mut self, problem: &Problem) {
        let key = problem_fingerprint(problem);
        if self.engine.is_some() && self.session_key == Some(key) {
            return;
        }
        self.session = None;
        let config = crate::coordinator::CoordinatorConfig {
            artifact_dir: self.artifact_dir.clone(),
            policy: crate::coordinator::BatchPolicy { adaptive: true, ..Default::default() },
        };
        let backend = match crate::coordinator::Coordinator::start(problem, config) {
            Ok(coord) => {
                // delta rounds on this engine's own session client
                // (base + rows per round; per-client slots make this
                // safe even if the session were shared)
                let backend =
                    MixedProbeBackend::with_tensor_delta(self.workers, coord.handle(), 0);
                self.session = Some(coord);
                backend
            }
            Err(e) => {
                // offline is a designed mode, not an error: note it once
                // per session and serve from the CPU pool
                eprintln!("sac-mixed: no tensor session ({e:#}); running CPU-only");
                MixedProbeBackend::cpu_only(self.workers)
            }
        };
        self.stats = Some(backend.stats());
        self.engine = Some(SacParallel::with_backend(Box::new(backend)));
        self.session_key = Some(key);
    }
}

impl Propagator for SacMixed {
    fn name(&self) -> &'static str {
        "sac-mixed"
    }

    fn reset(&mut self, _problem: &Problem) {
        // per-problem session: tear everything down; the next
        // enforcement rebuilds (and re-uploads the constraint tensor)
        self.session = None;
        self.engine = None;
        self.session_key = None;
        self.stats = None;
        self.failed = None;
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        self.ensure_engine(problem);
        let engine = self.engine.as_mut().expect("engine ensured above");
        let out = engine.enforce_sac(problem, state, counters);
        if let Some(e) = engine.failed.clone() {
            // only reachable if the CPU route itself errored — the
            // tensor half degrades instead of failing
            eprintln!("sac-mixed: {e}");
            self.failed = Some(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3bit::Ac3Bit;
    use crate::ac::rtac::RtacNative;
    use crate::core::Relation;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn sac_strictly_stronger_than_ac_on_known_gadget() {
        // x0,x1,x2 pairwise != over d=2: AC-consistent (every value has
        // a support on each edge) but no solution — SAC detects it.
        let p = crate::gen::pigeonhole(3, 2);
        let mut s_ac = State::new(&p);
        let mut c = Counters::default();
        assert!(Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c).is_consistent());
        assert_eq!(s_ac.total_size(), 6); // AC removes nothing

        let mut s_sac = State::new(&p);
        let out = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
        assert!(!out.is_consistent(), "SAC must refute pigeonhole(3,2)");

        let mut s_par = State::new(&p);
        let out_par = SacParallel::new(2).enforce_sac(&p, &mut s_par, &mut c);
        assert!(!out_par.is_consistent(), "batched SAC must refute pigeonhole(3,2)");
    }

    #[test]
    fn sac_equals_ac_when_already_sac() {
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = Sac1::new(RtacNative::dense()).enforce_sac(&p, &mut s, &mut c);
        assert!(out.is_consistent());
        assert_eq!(s.total_size(), 12); // equality chain: everything SAC

        let mut s_par = State::new(&p);
        let out_par = SacParallel::new(3).enforce_sac(&p, &mut s_par, &mut c);
        assert!(out_par.is_consistent());
        assert_eq!(s_par.total_size(), 12);
    }

    #[test]
    fn sac_closure_engine_independent() {
        for seed in [11u64, 29, 47] {
            let p = random_csp(&RandomSpec::new(8, 4, 0.7, 0.45, seed));
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s1, &mut c);
            let o2 = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s2, &mut c);
            assert_eq!(o1.is_consistent(), o2.is_consistent(), "seed {seed}");
            if o1.is_consistent() {
                assert_eq!(s1.snapshot(), s2.snapshot(), "seed {seed}");
            }
        }
    }

    #[test]
    fn sac_subset_of_ac_closure() {
        for seed in [5u64, 17] {
            let p = random_csp(&RandomSpec::new(9, 4, 0.8, 0.5, seed));
            let mut s_ac = State::new(&p);
            let mut s_sac = State::new(&p);
            let mut c = Counters::default();
            let o_ac = Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c);
            let o_sac = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
            if !o_ac.is_consistent() || !o_sac.is_consistent() {
                continue;
            }
            for v in 0..p.n_vars() {
                for a in s_sac.dom(v).iter_ones() {
                    assert!(s_ac.contains(v, a), "SAC kept a value AC removed");
                }
            }
        }
    }

    #[test]
    fn batched_sac_same_fixpoint_as_sequential_across_worker_counts() {
        // Satellite contract: sac-par at 1/2/4 workers reaches the SAME
        // fixpoint (final domains + outcome) as sequential SAC-1 on
        // random dense instances — the SAC closure is unique, so probe
        // batching must not change it.
        forall("sac-par-vs-sac1", 0x5AC2, 12, |rng| {
            let spec = RandomSpec::new(
                4 + rng.gen_range(6),
                2 + rng.gen_range(4),
                0.6 + 0.4 * rng.next_f64(),
                0.55 * rng.next_f64(),
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s_ref = State::new(&p);
            let mut c_ref = Counters::default();
            let o_ref =
                Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_ref, &mut c_ref);
            for workers in [1usize, 2, 4] {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = SacParallel::new(workers).enforce_sac(&p, &mut s, &mut c);
                if o.is_consistent() != o_ref.is_consistent() {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_ref:?} on {spec:?}"));
                }
                if o_ref.is_consistent() && s.snapshot() != s_ref.snapshot() {
                    return Err(format!("{workers}w: fixpoint mismatch on {spec:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batched_sac_engine_reuse_across_problems() {
        // one engine (one pool + slab) across layout changes: the slab
        // must drop stale planes and the fixpoints must stay right.
        let mut engine = SacParallel::new(2);
        for p in [
            crate::gen::pigeonhole(3, 2),
            random_csp(&RandomSpec::new(7, 5, 0.8, 0.4, 23)),
            crate::gen::pigeonhole(4, 3),
        ] {
            let mut s_par = State::new(&p);
            let mut s_seq = State::new(&p);
            let mut c = Counters::default();
            let o_par = engine.enforce_sac(&p, &mut s_par, &mut c);
            let o_seq = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_seq, &mut c);
            assert_eq!(o_par.is_consistent(), o_seq.is_consistent(), "{}", p.name());
            if o_par.is_consistent() {
                assert_eq!(s_par.snapshot(), s_seq.snapshot(), "{}", p.name());
            }
            engine.reset(&p);
        }
    }

    /// Seam double: answers every probe "consistent" and records what it
    /// was asked, so the merge loop's filtering contract is observable.
    struct RecordingBackend {
        rounds: std::rc::Rc<std::cell::RefCell<Vec<Vec<(VarId, Val)>>>>,
        k: usize,
        fail_after: Option<usize>,
    }

    impl ProbeBackend for RecordingBackend {
        fn batch(&self) -> usize {
            self.k
        }
        fn engine_name(&self) -> &'static str {
            "sac-test"
        }
        fn run_probes(
            &mut self,
            _problem: &Problem,
            state: &State,
            probes: &[(VarId, Val)],
            _counters: &mut Counters,
        ) -> anyhow::Result<Vec<bool>> {
            let mut rounds = self.rounds.borrow_mut();
            if let Some(limit) = self.fail_after {
                if rounds.len() >= limit {
                    anyhow::bail!("backend exploded");
                }
            }
            for &(x, a) in probes {
                assert!(state.contains(x, a), "backend got a dead probe ({x}, {a})");
                assert!(state.dom_size(x) > 1, "backend got a singleton probe ({x}, {a})");
            }
            rounds.push(probes.to_vec());
            Ok(vec![true; probes.len()])
        }
    }

    #[test]
    fn merge_loop_hands_backends_filtered_rounds_of_at_most_k() {
        // equality chain: root AC keeps every domain full, so the probe
        // set is deterministic (12 pairs -> rounds of <= 3)
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let rounds = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let backend = RecordingBackend { rounds: rounds.clone(), k: 3, fail_after: None };
        let mut engine = SacParallel::with_backend(Box::new(backend));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce_sac(&p, &mut s, &mut c);
        assert!(out.is_consistent(), "all-consistent verdicts cannot wipe anything");
        assert_eq!(engine.name(), "sac-test");
        let rounds = rounds.borrow();
        assert!(!rounds.is_empty());
        assert!(rounds.iter().all(|r| !r.is_empty() && r.len() <= 3), "round sizes: {rounds:?}");
        let probed: u64 = rounds.iter().map(|r| r.len() as u64).sum();
        assert_eq!(probed, engine.probes);
    }

    #[test]
    fn probe_round_memo_replays_rounds_without_rerunning_the_backend() {
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let rounds = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let backend = RecordingBackend { rounds: rounds.clone(), k: 3, fail_after: None };
        let cache = FixCache::shared(64);
        let mut engine =
            SacParallel::with_backend(Box::new(backend)).with_fixcache(cache.clone());
        let mut s1 = State::new(&p);
        let mut c1 = Counters::default();
        assert!(engine.enforce_sac(&p, &mut s1, &mut c1).is_consistent());
        let cold_rounds = rounds.borrow().len();
        assert!(cold_rounds > 0);
        let mut s2 = State::new(&p);
        let mut c2 = Counters::default();
        assert!(engine.enforce_sac(&p, &mut s2, &mut c2).is_consistent());
        assert_eq!(
            rounds.borrow().len(),
            cold_rounds,
            "every warm round must be served from the memo, not the backend"
        );
        assert_eq!(s1.snapshot(), s2.snapshot(), "replayed verdicts reach the same closure");
        assert_eq!(c1, c2, "replayed counter deltas keep the work ledger bit-identical");
        let stats = cache.expect("attached").stats();
        assert_eq!(stats.hits as usize, cold_rounds, "one hit per memoised round");
        assert_eq!(stats.misses as usize, cold_rounds, "one miss per cold round");
    }

    #[test]
    fn probe_round_memo_is_bit_identical_to_the_uncached_engine() {
        // the sac.rs half of the differential battery: cache off vs on
        // vs capacity-1 — identical outcome, closure, and counters on
        // real CPU probe work (capacity 1 thrashes, which must change
        // nothing but the hit rate)
        let p = random_csp(&RandomSpec::new(7, 5, 0.8, 0.4, 23));
        let mut off_state = State::new(&p);
        let mut off_c = Counters::default();
        let off_out = SacParallel::new(2).enforce_sac(&p, &mut off_state, &mut off_c);
        for entries in [64usize, 1] {
            let cache = FixCache::shared(entries);
            let mut engine = SacParallel::new(2).with_fixcache(cache.clone());
            // cold pass, then a (partially) warm repeat
            for _ in 0..2 {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let out = engine.enforce_sac(&p, &mut s, &mut c);
                assert_eq!(out.is_consistent(), off_out.is_consistent());
                assert_eq!(s.snapshot(), off_state.snapshot(), "cap {entries}");
                assert_eq!(c, off_c, "cache (cap {entries}) must not change the work ledger");
            }
            let stats = cache.expect("attached").stats();
            assert!(stats.misses > 0, "cold rounds miss (cap {entries})");
            if entries > 1 {
                assert!(stats.hits > 0, "the warm repeat must hit (cap {entries})");
            }
        }
    }

    #[test]
    fn backend_failure_poisons_the_engine() {
        // pigeonhole(3,2) is AC-consistent with full domains: the merge
        // loop reliably reaches a second probe round (6 pairs, k = 2)
        let p = crate::gen::pigeonhole(3, 2);
        let rounds = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let backend = RecordingBackend { rounds, k: 2, fail_after: Some(1) };
        let mut engine = SacParallel::with_backend(Box::new(backend));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce_sac(&p, &mut s, &mut c);
        assert!(!out.is_consistent(), "a failed backend must not report consistent");
        let msg = engine.failed.as_deref().expect("engine poisoned");
        assert!(msg.contains("exploded"), "lost the backend error: {msg}");
        // reachable through the trait too, so the CLI can refuse to turn
        // a poisoned run into an UNSAT verdict
        assert_eq!(engine.failure(), Some(msg));
        // poisoned engines stay poisoned (like TensorEngine)
        let mut s2 = State::new(&p);
        assert!(!engine.enforce_sac(&p, &mut s2, &mut c).is_consistent());
        // ...until reset
        engine.reset(&p);
        assert!(engine.failed.is_none());
    }

    #[test]
    fn problem_fingerprint_distinguishes_same_shaped_problems() {
        // same name, var count, domain sizes, and constraint scopes —
        // only the relation bits differ.  SacXla must NOT reuse a
        // session (and its device-resident constraint tensor) across
        // these.
        let mut eq_chain = Problem::new("chain", 4, 3);
        let mut neq_chain = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        let ne = Relation::from_fn(3, 3, |a, b| a != b);
        for v in 0..3 {
            eq_chain.add_constraint(v, v + 1, eq.clone());
            neq_chain.add_constraint(v, v + 1, ne.clone());
        }
        assert_ne!(problem_fingerprint(&eq_chain), problem_fingerprint(&neq_chain));
        assert_eq!(problem_fingerprint(&eq_chain), problem_fingerprint(&eq_chain));
    }

    #[test]
    fn sac_xla_without_artifacts_poisons_not_panics() {
        // offline (no artifact dir): the lazy session start must fail
        // cleanly — poisoned engine, wipeout outcome, clear message.
        let mut engine = SacXla::with_artifact_dir(
            4,
            std::path::PathBuf::from("/nonexistent-artifact-dir"),
        );
        assert_eq!(engine.name(), "sac-xla");
        let p = crate::gen::pigeonhole(3, 2);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce(&p, &mut s, &[], &mut c);
        assert!(!out.is_consistent());
        let msg = engine.failed.as_deref().expect("offline sac-xla must poison");
        assert!(msg.contains("coordinator session"), "unhelpful failure: {msg}");
        engine.reset(&p);
        assert!(engine.failed.is_none(), "reset must clear the poison for a retry");
    }

    // ---- mixed CPU/tensor scheduling ----------------------------------

    #[test]
    fn mixed_cpu_only_reaches_the_sac1_fixpoint_across_worker_counts() {
        // the forced-CPU leg of the satellite property test: sac-mixed
        // with no tensor session must reach the same (unique) SAC
        // closure as sequential SAC-1 at 1/2/4 workers.  (Forced
        // tensor-only and auto splits run in coordinator/service.rs
        // against the CPU-reference executor and, artifact-gated, in
        // tests/coordinator.rs against the real one.)
        forall("sac-mixed-cpu-vs-sac1", 0x51AC, 10, |rng| {
            let spec = RandomSpec::new(
                4 + rng.gen_range(6),
                2 + rng.gen_range(4),
                0.6 + 0.4 * rng.next_f64(),
                0.55 * rng.next_f64(),
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s_ref = State::new(&p);
            let mut c_ref = Counters::default();
            let o_ref =
                Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_ref, &mut c_ref);
            for workers in [1usize, 2, 4] {
                let backend = MixedProbeBackend::cpu_only(workers);
                let stats = backend.stats();
                let mut engine = SacParallel::with_backend(Box::new(backend));
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = engine.enforce_sac(&p, &mut s, &mut c);
                if o.is_consistent() != o_ref.is_consistent() {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_ref:?} on {spec:?}"));
                }
                if o_ref.is_consistent() && s.snapshot() != s_ref.snapshot() {
                    return Err(format!("{workers}w: fixpoint mismatch on {spec:?}"));
                }
                if stats.tensor_probes() != 0 {
                    return Err(format!("{workers}w: offline backend routed to a tensor half"));
                }
                if engine.probes > 0 && stats.cpu_probes() != engine.probes {
                    return Err(format!(
                        "{workers}w: stats {} != probes {}",
                        stats.cpu_probes(),
                        engine.probes
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mixed_engine_name_and_forced_cpu_split() {
        let p = crate::gen::pigeonhole(3, 2);
        let backend = MixedProbeBackend::cpu_only(2).with_split(MixedSplit::CpuOnly);
        let mut engine = SacParallel::with_backend(Box::new(backend));
        assert_eq!(engine.name(), "sac-mixed");
        let mut s = State::new(&p);
        let mut c = Counters::default();
        assert!(!engine.enforce_sac(&p, &mut s, &mut c).is_consistent());
        assert!(engine.failed.is_none());
    }

    #[test]
    fn auto_split_is_inverse_latency_proportional() {
        use MixedProbeBackend as M;
        // equal latency: half and half
        assert_eq!(M::auto_split(10.0, 10.0, 8), 4);
        // CPU 3x slower per probe: the tensor half takes ~3/4
        assert_eq!(M::auto_split(30.0, 10.0, 8), 6);
        // tensor dominated by dispatch overhead: nearly everything CPU
        assert_eq!(M::auto_split(1.0, 99.0, 8), 0);
        // clamps and degenerate cases
        assert_eq!(M::auto_split(10.0, 0.0, 8), 8);
        assert_eq!(M::auto_split(10.0, 10.0, 0), 0);
        assert_eq!(M::auto_split(0.0, 0.0, 8), 4);
        assert_eq!(M::auto_split(f64::NAN, 10.0, 8), 4);
    }

    #[test]
    fn ewma_tracks_observations() {
        let mut e = Ewma::new();
        assert_eq!(e.get(), None);
        e.observe(100.0);
        assert_eq!(e.get(), Some(100.0));
        e.observe(0.0);
        let v = e.get().unwrap();
        assert!(v < 100.0 && v > 0.0, "EWMA must move toward new observations: {v}");
    }

    #[test]
    fn sac_mixed_engine_runs_cpu_only_offline_without_poisoning() {
        // unlike sac-xla, the mixed engine must DEGRADE offline: same
        // closure as SAC-1, no failure reported
        let mut engine = SacMixed::with_artifact_dir(
            2,
            std::path::PathBuf::from("/nonexistent-artifact-dir"),
        );
        assert_eq!(engine.name(), "sac-mixed");
        let p = crate::gen::pigeonhole(3, 2);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce(&p, &mut s, &[], &mut c);
        assert!(!out.is_consistent(), "SAC must still refute pigeonhole(3,2)");
        assert!(engine.failed.is_none(), "offline sac-mixed must not poison: {:?}", engine.failed);
        assert_eq!(engine.failure(), None);
        let stats = engine.stats().expect("a round ran");
        assert!(stats.cpu_probes() > 0);
        assert_eq!(stats.tensor_probes(), 0);
        // a consistent instance too, cross-checked against SAC-1
        let p2 = random_csp(&RandomSpec::new(7, 4, 0.7, 0.35, 13));
        engine.reset(&p2);
        let mut s_mixed = State::new(&p2);
        let o_mixed = engine.enforce(&p2, &mut s_mixed, &[], &mut c);
        let mut s_ref = State::new(&p2);
        let o_ref = Sac1::new(RtacNative::incremental()).enforce_sac(&p2, &mut s_ref, &mut c);
        assert_eq!(o_mixed.is_consistent(), o_ref.is_consistent());
        if o_ref.is_consistent() {
            assert_eq!(s_mixed.snapshot(), s_ref.snapshot());
        }
    }

    #[test]
    fn sac_mixed_engine_reuse_across_problems() {
        let mut engine = SacMixed::with_artifact_dir(
            2,
            std::path::PathBuf::from("/nonexistent-artifact-dir"),
        );
        for p in [
            crate::gen::pigeonhole(3, 2),
            random_csp(&RandomSpec::new(7, 5, 0.8, 0.4, 23)),
            crate::gen::pigeonhole(4, 3),
        ] {
            let mut s_mixed = State::new(&p);
            let mut s_seq = State::new(&p);
            let mut c = Counters::default();
            let o_mixed = engine.enforce(&p, &mut s_mixed, &[], &mut c);
            let o_seq = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_seq, &mut c);
            assert_eq!(o_mixed.is_consistent(), o_seq.is_consistent(), "{}", p.name());
            if o_mixed.is_consistent() {
                assert_eq!(s_mixed.snapshot(), s_seq.snapshot(), "{}", p.name());
            }
            engine.reset(&p);
        }
    }

    #[test]
    fn probe_counts_match_between_sequential_and_batched() {
        // both engines probe the same (var, value) pairs per pass when
        // no removals interleave; on an already-SAC instance the counts
        // are exactly equal (one full pass each).
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut c = Counters::default();
        let mut seq = Sac1::new(RtacNative::incremental());
        let mut s1 = State::new(&p);
        assert!(seq.enforce_sac(&p, &mut s1, &mut c).is_consistent());
        let mut par = SacParallel::new(3);
        let mut s2 = State::new(&p);
        assert!(par.enforce_sac(&p, &mut s2, &mut c).is_consistent());
        assert_eq!(seq.probes, par.probes);
        assert!(par.probes > 0);
    }
}
