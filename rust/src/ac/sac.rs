//! Singleton arc consistency (SAC) — a stronger consistency built *on
//! top of* any [`Propagator`]: value (x, a) is SAC iff the subproblem
//! with x := a is arc consistent.  This is the natural "next level" the
//! paper's recurrent formulation extends to: each singleton probe is an
//! **independent enforcement** — massively parallel in the tensor
//! setting, and a natural batch for the coordinator
//! ([`crate::coordinator::Handle::submit_batch`] is the tensor-route
//! twin of the CPU batching below).
//!
//! Three enforcers:
//!
//! * [`Sac1`] — sequential SAC-1 (Debruyne & Bessière) wrapping any
//!   inner AC engine.  Probes run on a scratch level of the trail;
//!   confirmed removals propagate through the inner engine until a
//!   fixpoint over all (var, value) pairs.
//! * [`SacParallel`] — batched SAC-1 behind the **probe-backend seam**
//!   ([`ProbeBackend`]): the SAC-1 merge loop (launch K probes against
//!   the current domains, remove every failed probe's value, AC
//!   re-propagate, repeat until a full pass removes nothing) is
//!   backend-independent; only *where* the probe fixpoints run differs.
//!   Sound for any backend because probe failure is **monotone**: a
//!   probe that is AC-inconsistent against the batch's launch domains
//!   stays inconsistent under the smaller domains later removals
//!   produce, so every failed probe of a batch can be removed; stale
//!   *successes* are caught by the outer fixpoint loop re-probing until
//!   a full pass removes nothing.  The SAC closure is unique, so every
//!   backend reaches bit-the-same final domains as [`Sac1`].
//!   - [`CpuProbeBackend`] (`sac-par[N]`) — K probes concurrently on
//!     the persistent [`WorkerPool`], each on a private scratch plane
//!     pair checked out of a [`PlaneSlab`] (one memcpy each), the
//!     recurrent fixpoint run directly on the planes (no trail — probe
//!     domains are discarded).  Property-tested at 1/2/4 workers.
//!   - [`XlaProbeBackend`] (`sac-xla[N]`) — K probes staged straight
//!     from the [`DomainPlane`] arena (`runtime::encode_vars_into`,
//!     one base encoding per round + a single-row edit per probe) and
//!     submitted through [`crate::coordinator::Handle::submit_batch`]
//!     onto the compiled `fixb*` executables: the coordinator's dynamic
//!     batcher fuses the round into as few executions as the compiled
//!     batch sizes allow.  [`SacXla`] wraps this backend together with
//!     a lazily-started coordinator session into a self-contained
//!     engine for `make_engine("sac-xla[N]")`.

use crate::ac::rtac::{derive_affected, RtacNative};
use crate::ac::{Counters, Outcome, Propagator};
use crate::coordinator::Handle;
use crate::core::{DomainPlane, PlaneSlab, Problem, State, Val, VarId};
use crate::exec::WorkerPool;
use crate::runtime::encode_vars_into;

/// SAC-1 enforcer wrapping an inner AC engine.
pub struct Sac1<E: Propagator> {
    inner: E,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
    /// Reusable value-collection buffer — hoisted out of the probe loop
    /// so the hot path stops allocating one `Vec` per (pass, variable).
    vals_buf: Vec<usize>,
}

impl<E: Propagator> Sac1<E> {
    pub fn new(inner: E) -> Sac1<E> {
        Sac1 { inner, probes: 0, vals_buf: Vec::new() }
    }

    /// Enforce SAC.  Returns the outcome; `counters` accumulates the
    /// inner engine's work across all probes.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        // start from the AC closure
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        loop {
            let mut removed_any = false;
            for x in 0..problem.n_vars() {
                self.vals_buf.clear();
                self.vals_buf.extend(state.dom(x).iter_ones());
                if self.vals_buf.len() <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                for &a in &self.vals_buf {
                    if !state.contains(x, a) {
                        continue; // removed by an earlier probe's fallout
                    }
                    self.probes += 1;
                    state.push_level();
                    state.assign(x, a);
                    let probe = self.inner.enforce(problem, state, &[x], counters);
                    state.pop_level();
                    if !probe.is_consistent() {
                        state.remove(x, a);
                        removed_any = true;
                        if state.wiped(x) {
                            return Outcome::Wipeout(x);
                        }
                        // re-establish AC after a confirmed removal
                        let out = self.inner.enforce(problem, state, &[x], counters);
                        if !out.is_consistent() {
                            return out;
                        }
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl<E: Propagator> Propagator for Sac1<E> {
    fn name(&self) -> &'static str {
        "sac1"
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.probes = 0;
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

/// Reusable per-probe fixpoint bookkeeping (changed lists + Prop.-2
/// flags), pooled by [`SacParallel`] alongside the scratch planes so a
/// steady-state probe performs no heap allocation at all.  The
/// "`affected_list` names exactly the true flags" invariant carries
/// across probes: [`derive_affected`] resets precisely those entries at
/// each sweep start.
#[derive(Default)]
struct ProbeScratch {
    changed: Vec<VarId>,
    next_changed: Vec<VarId>,
    affected: Vec<bool>,
    affected_list: Vec<VarId>,
}

/// Run the recurrent AC fixpoint directly on a plane pair — the probe
/// body of batched SAC.  `plane` holds the live domains (with the probe
/// assignment already applied); `snap` is the per-sweep Jacobi snapshot
/// buffer.  Prop.-2 incremental candidate sets, seeded from `seed`.
/// No trail: probe domains are scratch and discarded.  Returns true iff
/// the fixpoint is consistent (no domain wiped out).
///
/// The revise loop below must stay semantically in sync with its two
/// siblings — `RtacNative::sweep` (removal sink: trailed
/// `State::remove`) and `RtacParallel::revise_chunk` (removal sink:
/// chunk-relative word masking); this one clears bits on the scratch
/// plane.  Only the sink differs; the support predicate and counter
/// accounting are the bit-identity contract.
fn plane_fixpoint(
    problem: &Problem,
    plane: &mut DomainPlane,
    snap: &mut DomainPlane,
    seed: VarId,
    scratch: &mut ProbeScratch,
    counters: &mut Counters,
) -> bool {
    let n = problem.n_vars();
    if scratch.affected.len() != n {
        scratch.affected.clear();
        scratch.affected.resize(n, false);
        scratch.affected_list.clear();
    }
    scratch.changed.clear();
    scratch.changed.push(seed);
    loop {
        counters.recurrences += 1;
        snap.copy_words_from(plane);
        derive_affected(
            problem,
            &scratch.changed,
            &mut scratch.affected,
            &mut scratch.affected_list,
        );
        scratch.next_changed.clear();
        for x in 0..n {
            if !scratch.affected[x] {
                continue;
            }
            let mut x_changed = false;
            'vals: for a in snap.bits(x).iter_ones() {
                for &arc in problem.arcs_of(x) {
                    counters.support_checks += 1;
                    let other = problem.arc_other(arc);
                    if !problem.arc_support_row(arc, a).intersects(snap.bits(other)) {
                        plane.clear(x, a);
                        counters.removals += 1;
                        x_changed = true;
                        continue 'vals;
                    }
                }
            }
            if x_changed {
                scratch.next_changed.push(x);
                if plane.is_wiped(x) {
                    return false;
                }
            }
        }
        if scratch.next_changed.is_empty() {
            return true;
        }
        std::mem::swap(&mut scratch.changed, &mut scratch.next_changed);
    }
}

/// The probe-execution seam of batched SAC (the probe-backend decision
/// recorded in ROADMAP.md).  A backend runs one *round* of singleton
/// probes — each asking "is the subproblem with x := a arc consistent?"
/// — against the launch domains in `state` and reports, per probe,
/// whether the probe's AC fixpoint stayed consistent.  The surrounding
/// SAC-1 merge loop in [`SacParallel`] (monotone failed-probe removal +
/// AC re-propagation until a clean pass) is backend-independent.
pub trait ProbeBackend {
    /// Probes submitted per round — the K of the batch loop.
    fn batch(&self) -> usize;

    /// Engine name the wrapping [`Propagator`] reports.
    fn engine_name(&self) -> &'static str;

    /// Run one round of probes against the domains in `state`.  The
    /// caller has already filtered `probes` to live, non-singleton
    /// (var, value) pairs.  Returns one verdict per probe, in order:
    /// `true` iff the probe fixpoint is consistent.  `Err` poisons the
    /// wrapping engine (tensor route: coordinator/session failure — the
    /// CPU backend is infallible).
    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>>;

    /// Per-problem reset hook.
    fn reset(&mut self, _problem: &Problem) {}
}

/// CPU probe backend (`sac-par[N]`): K probes concurrently on the
/// persistent [`WorkerPool`], each on a private scratch plane pair from
/// the [`PlaneSlab`], running [`plane_fixpoint`] (no trail).
pub struct CpuProbeBackend {
    /// Requested probe workers; 0 = auto (available parallelism).
    workers: usize,
    pool: Option<WorkerPool>,
    slab: PlaneSlab,
    /// Pooled per-probe fixpoint bookkeeping (see [`ProbeScratch`]).
    scratch_pool: Vec<ProbeScratch>,
}

impl CpuProbeBackend {
    pub fn new(workers: usize) -> CpuProbeBackend {
        CpuProbeBackend { workers, pool: None, slab: PlaneSlab::new(), scratch_pool: Vec::new() }
    }
}

impl ProbeBackend for CpuProbeBackend {
    fn batch(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }

    fn engine_name(&self) -> &'static str {
        "sac-par"
    }

    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>> {
        let k = self.batch();
        let need_pool = match &self.pool {
            Some(p) => p.size() != k,
            None => true,
        };
        if need_pool {
            self.pool = Some(WorkerPool::new(k));
        }
        // Each probe checks out a plane pair and owns it for the
        // probe's lifetime: the live plane is a memcpy of the current
        // domains, the snapshot buffer is uninitialised scratch (the
        // fixpoint overwrites it before reading).
        let mut jobs: Vec<(VarId, Val, DomainPlane, DomainPlane, ProbeScratch)> =
            Vec::with_capacity(probes.len());
        for &(x, a) in probes {
            let cur = self.slab.checkout(state.plane());
            let snap = self.slab.checkout_scratch(state.plane());
            let scratch = self.scratch_pool.pop().unwrap_or_default();
            jobs.push((x, a, cur, snap, scratch));
        }
        let tasks: Vec<_> = jobs
            .into_iter()
            .map(|(x, a, mut cur, mut snap, mut scratch)| {
                move || {
                    let mut c = Counters::default();
                    cur.assign(x, a);
                    let consistent =
                        plane_fixpoint(problem, &mut cur, &mut snap, x, &mut scratch, &mut c);
                    (consistent, cur, snap, scratch, c)
                }
            })
            .collect();
        let results = self.pool.as_mut().expect("pool sized above").run_collect(tasks);
        // Merge in launch order: counters stay deterministic and the
        // scratch buffers go back to their pools before any state-level
        // propagation runs.
        let mut verdicts = Vec::with_capacity(probes.len());
        for (consistent, cur, snap, scratch, c) in results {
            counters.add(&c);
            self.slab.checkin(cur);
            self.slab.checkin(snap);
            self.scratch_pool.push(scratch);
            verdicts.push(consistent);
        }
        Ok(verdicts)
    }

    // pool and slab survive reset: the persistent runtime is the point
    // (the slab drops stale-layout planes lazily on checkout)
}

/// Default probe round size of the tensor route — the largest batch the
/// AOT pipeline compiles (`python/compile/aot.py` BATCHES).
pub const DEFAULT_TENSOR_PROBE_BATCH: usize = 8;

/// Tensor probe backend (`sac-xla[N]`): probes are staged straight from
/// the domain-plane arena and routed through the coordinator onto the
/// compiled `fixb*` executables.  One [`encode_vars_into`] pass per
/// round stages the launch domains; each probe plane is then the staged
/// base with a single row edited to the singleton `{a}` — no per-probe
/// re-gather.  A fused round goes through
/// [`Handle::submit_batch`]/`enforce_batch_blocking`, putting all K
/// planes on the executor queue contiguously so the dynamic batcher
/// coalesces them; the `per_probe` variant submits them one blocking
/// request at a time (the occupancy baseline `rtac serve --sac-probe`
/// measures against).
pub struct XlaProbeBackend {
    handle: Handle,
    /// Probes per round; 0 = auto ([`DEFAULT_TENSOR_PROBE_BATCH`]).
    batch: usize,
    /// Round staging buffer: the launch domains, encoded once per round.
    staging: Vec<f32>,
    /// Fused (`submit_batch`) vs per-probe (`enforce_blocking`) routing.
    fused: bool,
    /// Fingerprint of the problem this backend first probed.  The
    /// session's constraint tensor is device-resident and per-problem,
    /// so probing a *different* problem through the same handle would
    /// silently evaluate against the wrong constraints — detected here
    /// and surfaced as a poisoning error instead.
    bound: Option<u64>,
}

impl XlaProbeBackend {
    pub fn new(handle: Handle, batch: usize) -> XlaProbeBackend {
        XlaProbeBackend { handle, batch, staging: Vec::new(), fused: true, bound: None }
    }

    /// The per-probe submission baseline: same backend, but every probe
    /// gambles against the executor's `max_wait` deadline on its own.
    pub fn per_probe(handle: Handle, batch: usize) -> XlaProbeBackend {
        XlaProbeBackend { handle, batch, staging: Vec::new(), fused: false, bound: None }
    }
}

impl ProbeBackend for XlaProbeBackend {
    fn batch(&self) -> usize {
        if self.batch > 0 {
            self.batch
        } else {
            DEFAULT_TENSOR_PROBE_BATCH
        }
    }

    fn engine_name(&self) -> &'static str {
        "sac-xla"
    }

    fn run_probes(
        &mut self,
        problem: &Problem,
        state: &State,
        probes: &[(VarId, Val)],
        counters: &mut Counters,
    ) -> anyhow::Result<Vec<bool>> {
        // the handle's session owns a device-resident constraint tensor
        // for ONE problem; refuse to probe a different one (the
        // fingerprint walk is microseconds next to an XLA round-trip)
        let fp = problem_fingerprint(problem);
        match self.bound {
            None => self.bound = Some(fp),
            Some(bound) if bound != fp => anyhow::bail!(
                "tensor probe backend is bound to another problem's session (the \
                 constraint tensor is device-resident) — build a new \
                 SacParallel::tensor against a fresh session, or use SacXla which \
                 restarts sessions on problem switches"
            ),
            Some(_) => {}
        }
        let bucket = self.handle.bucket;
        encode_vars_into(state.plane(), bucket, &mut self.staging)?;
        let planes: Vec<Vec<f32>> = probes
            .iter()
            .map(|&(x, a)| {
                let mut plane = self.staging.clone();
                let row = &mut plane[x * bucket.d..(x + 1) * bucket.d];
                row.fill(0.0);
                row[a] = 1.0;
                plane
            })
            .collect();
        let responses = if self.fused {
            self.handle.enforce_batch_blocking(planes)?
        } else {
            planes
                .into_iter()
                .map(|p| self.handle.enforce_blocking(p))
                .collect::<anyhow::Result<Vec<_>>>()?
        };
        Ok(responses
            .into_iter()
            .map(|r| {
                // joint sweep count of the fused execution that served
                // this probe — the tensor-side #Recurrence
                counters.recurrences += r.iters.max(0) as u64;
                !r.wiped()
            })
            .collect())
    }
}

/// Batched SAC-1 over a [`ProbeBackend`] — `sac-par[N]` on the CPU
/// pool, `sac-xla[N]` through the coordinator.
pub struct SacParallel {
    /// State-level AC for the root closure and post-removal
    /// re-propagation (the probes themselves run backend-side).
    inner: RtacNative,
    backend: Box<dyn ProbeBackend>,
    /// Probes performed (for the ablation bench).
    pub probes: u64,
    /// Candidate (var, value) pairs of the current pass.
    pairs: Vec<(VarId, Val)>,
    /// Set on a backend failure (tensor route only): the engine is then
    /// poisoned and reports wipeouts, like `TensorEngine`.
    pub failed: Option<String>,
}

impl SacParallel {
    /// CPU-pool probes (`sac-par[N]`); `workers` 0 = auto.
    pub fn new(workers: usize) -> SacParallel {
        SacParallel::with_backend(Box::new(CpuProbeBackend::new(workers)))
    }

    /// Coordinator-routed probes (`sac-xla[N]`) against an existing
    /// session; `batch` 0 = auto.
    pub fn tensor(handle: Handle, batch: usize) -> SacParallel {
        SacParallel::with_backend(Box::new(XlaProbeBackend::new(handle, batch)))
    }

    /// Any probe backend — the seam the tests and `rtac serve` use.
    pub fn with_backend(backend: Box<dyn ProbeBackend>) -> SacParallel {
        SacParallel {
            inner: RtacNative::incremental(),
            backend,
            probes: 0,
            pairs: Vec::new(),
            failed: None,
        }
    }

    /// Enforce SAC with batched probes.  Returns the outcome; `counters`
    /// accumulates the work of every probe plus the state-level AC runs.
    pub fn enforce_sac(
        &mut self,
        problem: &Problem,
        state: &mut State,
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        let out = self.inner.enforce(problem, state, &[], counters);
        if !out.is_consistent() {
            return out;
        }
        let k = self.backend.batch().max(1);
        loop {
            let mut removed_any = false;
            // This pass's candidates: every live value of every
            // non-singleton variable (SAC-1's probe set).
            self.pairs.clear();
            for x in 0..problem.n_vars() {
                if state.dom_size(x) <= 1 {
                    continue; // a singleton that survived AC is SAC
                }
                self.pairs.extend(state.dom(x).iter_ones().map(|a| (x, a)));
            }
            let mut start = 0usize;
            while start < self.pairs.len() {
                let end = (start + k).min(self.pairs.len());
                // Launch up to k probes against the CURRENT domains,
                // skipping values already removed by an earlier round's
                // fallout, and variables that fallout reduced to a
                // singleton (a singleton that survived AC is SAC — the
                // probe outcome is known).
                let round: Vec<(VarId, Val)> = self.pairs[start..end]
                    .iter()
                    .copied()
                    .filter(|&(x, a)| state.contains(x, a) && state.dom_size(x) > 1)
                    .collect();
                start = end;
                if round.is_empty() {
                    continue;
                }
                self.probes += round.len() as u64;
                let verdicts = match self.backend.run_probes(problem, state, &round, counters) {
                    Ok(v) => v,
                    Err(e) => {
                        self.failed = Some(format!("{e:#}"));
                        return Outcome::Wipeout(0);
                    }
                };
                debug_assert_eq!(verdicts.len(), round.len());
                // Probe failure is monotone (see module docs): every
                // failed probe's value goes, each followed by AC
                // re-propagation — exactly SAC-1's confirmed-removal
                // step, just k at a time.
                for ((x, a), consistent) in round.into_iter().zip(verdicts) {
                    if consistent {
                        continue;
                    }
                    if !state.contains(x, a) {
                        continue; // an earlier removal's fallout got it
                    }
                    state.remove(x, a);
                    removed_any = true;
                    if state.wiped(x) {
                        return Outcome::Wipeout(x);
                    }
                    let out = self.inner.enforce(problem, state, &[x], counters);
                    if !out.is_consistent() {
                        return out;
                    }
                }
            }
            if !removed_any {
                return Outcome::Consistent;
            }
        }
    }
}

impl Propagator for SacParallel {
    fn name(&self) -> &'static str {
        self.backend.engine_name()
    }

    fn reset(&mut self, problem: &Problem) {
        self.inner.reset(problem);
        self.backend.reset(problem);
        self.probes = 0;
        self.failed = None;
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.enforce_sac(problem, state, counters)
    }
}

/// `sac-xla[N]` as a self-contained engine: lazily starts — and owns —
/// a coordinator session for the problem it enforces on, then runs
/// [`SacParallel`] with the [`XlaProbeBackend`].  Sessions are
/// per-problem (the constraint tensor is device-resident), so the
/// session restarts when the problem changes (`reset`, or a different
/// problem fingerprint at `enforce`).  Artifact-gated: without compiled
/// artifacts the first enforcement poisons the engine (`failed`) and
/// reports wipeout, like `TensorEngine` on a coordinator failure.
pub struct SacXla {
    /// Probes per round; 0 = auto.
    batch: usize,
    artifact_dir: std::path::PathBuf,
    session: Option<(crate::coordinator::Coordinator, SacParallel)>,
    /// Fingerprint of the problem the live session serves.
    session_key: Option<u64>,
    pub failed: Option<String>,
}

/// Content fingerprint of a problem: variable count, domain sizes, and
/// every constraint's scope + relation bits.  Guards [`SacXla`]'s
/// session reuse — the constraint tensor is device-resident, so reusing
/// a session for a same-*shaped* but different problem would silently
/// probe against the wrong constraints.  O(e·d²), but SacXla only
/// serves bucket-sized problems, where that is microseconds.
fn problem_fingerprint(problem: &Problem) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x0000_0100_0000_01b3) // FNV-1a step
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, problem.n_vars() as u64);
    for v in 0..problem.n_vars() {
        h = mix(h, problem.dom_size(v) as u64);
    }
    for c in problem.constraints() {
        h = mix(h, ((c.x as u64) << 32) | c.y as u64);
        for a in 0..c.rel.dx() {
            for b in c.rel.row_fwd(a).iter_ones() {
                h = mix(h, ((a as u64) << 32) | b as u64);
            }
        }
    }
    h
}

impl SacXla {
    /// Engine against `runtime::default_artifact_dir()` (what
    /// `make_engine("sac-xla[N]")` constructs).
    pub fn new(batch: usize) -> SacXla {
        SacXla::with_artifact_dir(batch, crate::runtime::default_artifact_dir())
    }

    pub fn with_artifact_dir(batch: usize, artifact_dir: std::path::PathBuf) -> SacXla {
        SacXla { batch, artifact_dir, session: None, session_key: None, failed: None }
    }

    fn ensure_session(&mut self, problem: &Problem) -> anyhow::Result<()> {
        let key = problem_fingerprint(problem);
        if self.session.is_some() && self.session_key == Some(key) {
            return Ok(());
        }
        self.session = None;
        self.session_key = None;
        let config = crate::coordinator::CoordinatorConfig {
            artifact_dir: self.artifact_dir.clone(),
            // adaptive batching: probe rounds arrive as contiguous
            // bursts, so the executor sizes its window from what it
            // actually sees instead of a fixed policy
            policy: crate::coordinator::BatchPolicy { adaptive: true, ..Default::default() },
        };
        let coordinator = crate::coordinator::Coordinator::start(problem, config)?;
        let engine = SacParallel::tensor(coordinator.handle(), self.batch);
        self.session = Some((coordinator, engine));
        self.session_key = Some(key);
        Ok(())
    }
}

impl Propagator for SacXla {
    fn name(&self) -> &'static str {
        "sac-xla"
    }

    fn reset(&mut self, _problem: &Problem) {
        // per-problem session: tear it down; the next enforcement
        // starts a fresh one (and re-uploads the constraint tensor)
        self.session = None;
        self.session_key = None;
        self.failed = None;
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        if let Err(e) = self.ensure_session(problem) {
            let msg = format!("starting coordinator session: {e:#}");
            eprintln!("sac-xla: {msg}");
            self.failed = Some(msg);
            return Outcome::Wipeout(0);
        }
        let (_, engine) = self.session.as_mut().expect("session ensured above");
        let out = engine.enforce_sac(problem, state, counters);
        if let Some(e) = engine.failed.clone() {
            eprintln!("sac-xla: {e}");
            self.failed = Some(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3bit::Ac3Bit;
    use crate::ac::rtac::RtacNative;
    use crate::core::Relation;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn sac_strictly_stronger_than_ac_on_known_gadget() {
        // x0,x1,x2 pairwise != over d=2: AC-consistent (every value has
        // a support on each edge) but no solution — SAC detects it.
        let p = crate::gen::pigeonhole(3, 2);
        let mut s_ac = State::new(&p);
        let mut c = Counters::default();
        assert!(Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c).is_consistent());
        assert_eq!(s_ac.total_size(), 6); // AC removes nothing

        let mut s_sac = State::new(&p);
        let out = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
        assert!(!out.is_consistent(), "SAC must refute pigeonhole(3,2)");

        let mut s_par = State::new(&p);
        let out_par = SacParallel::new(2).enforce_sac(&p, &mut s_par, &mut c);
        assert!(!out_par.is_consistent(), "batched SAC must refute pigeonhole(3,2)");
    }

    #[test]
    fn sac_equals_ac_when_already_sac() {
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = Sac1::new(RtacNative::dense()).enforce_sac(&p, &mut s, &mut c);
        assert!(out.is_consistent());
        assert_eq!(s.total_size(), 12); // equality chain: everything SAC

        let mut s_par = State::new(&p);
        let out_par = SacParallel::new(3).enforce_sac(&p, &mut s_par, &mut c);
        assert!(out_par.is_consistent());
        assert_eq!(s_par.total_size(), 12);
    }

    #[test]
    fn sac_closure_engine_independent() {
        for seed in [11u64, 29, 47] {
            let p = random_csp(&RandomSpec::new(8, 4, 0.7, 0.45, seed));
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s1, &mut c);
            let o2 = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s2, &mut c);
            assert_eq!(o1.is_consistent(), o2.is_consistent(), "seed {seed}");
            if o1.is_consistent() {
                assert_eq!(s1.snapshot(), s2.snapshot(), "seed {seed}");
            }
        }
    }

    #[test]
    fn sac_subset_of_ac_closure() {
        for seed in [5u64, 17] {
            let p = random_csp(&RandomSpec::new(9, 4, 0.8, 0.5, seed));
            let mut s_ac = State::new(&p);
            let mut s_sac = State::new(&p);
            let mut c = Counters::default();
            let o_ac = Ac3Bit::new().enforce(&p, &mut s_ac, &[], &mut c);
            let o_sac = Sac1::new(Ac3Bit::new()).enforce_sac(&p, &mut s_sac, &mut c);
            if !o_ac.is_consistent() || !o_sac.is_consistent() {
                continue;
            }
            for v in 0..p.n_vars() {
                for a in s_sac.dom(v).iter_ones() {
                    assert!(s_ac.contains(v, a), "SAC kept a value AC removed");
                }
            }
        }
    }

    #[test]
    fn batched_sac_same_fixpoint_as_sequential_across_worker_counts() {
        // Satellite contract: sac-par at 1/2/4 workers reaches the SAME
        // fixpoint (final domains + outcome) as sequential SAC-1 on
        // random dense instances — the SAC closure is unique, so probe
        // batching must not change it.
        forall("sac-par-vs-sac1", 0x5AC2, 12, |rng| {
            let spec = RandomSpec::new(
                4 + rng.gen_range(6),
                2 + rng.gen_range(4),
                0.6 + 0.4 * rng.next_f64(),
                0.55 * rng.next_f64(),
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s_ref = State::new(&p);
            let mut c_ref = Counters::default();
            let o_ref =
                Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_ref, &mut c_ref);
            for workers in [1usize, 2, 4] {
                let mut s = State::new(&p);
                let mut c = Counters::default();
                let o = SacParallel::new(workers).enforce_sac(&p, &mut s, &mut c);
                if o.is_consistent() != o_ref.is_consistent() {
                    return Err(format!("{workers}w: outcome {o:?} vs {o_ref:?} on {spec:?}"));
                }
                if o_ref.is_consistent() && s.snapshot() != s_ref.snapshot() {
                    return Err(format!("{workers}w: fixpoint mismatch on {spec:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batched_sac_engine_reuse_across_problems() {
        // one engine (one pool + slab) across layout changes: the slab
        // must drop stale planes and the fixpoints must stay right.
        let mut engine = SacParallel::new(2);
        for p in [
            crate::gen::pigeonhole(3, 2),
            random_csp(&RandomSpec::new(7, 5, 0.8, 0.4, 23)),
            crate::gen::pigeonhole(4, 3),
        ] {
            let mut s_par = State::new(&p);
            let mut s_seq = State::new(&p);
            let mut c = Counters::default();
            let o_par = engine.enforce_sac(&p, &mut s_par, &mut c);
            let o_seq = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_seq, &mut c);
            assert_eq!(o_par.is_consistent(), o_seq.is_consistent(), "{}", p.name());
            if o_par.is_consistent() {
                assert_eq!(s_par.snapshot(), s_seq.snapshot(), "{}", p.name());
            }
            engine.reset(&p);
        }
    }

    /// Seam double: answers every probe "consistent" and records what it
    /// was asked, so the merge loop's filtering contract is observable.
    struct RecordingBackend {
        rounds: std::rc::Rc<std::cell::RefCell<Vec<Vec<(VarId, Val)>>>>,
        k: usize,
        fail_after: Option<usize>,
    }

    impl ProbeBackend for RecordingBackend {
        fn batch(&self) -> usize {
            self.k
        }
        fn engine_name(&self) -> &'static str {
            "sac-test"
        }
        fn run_probes(
            &mut self,
            _problem: &Problem,
            state: &State,
            probes: &[(VarId, Val)],
            _counters: &mut Counters,
        ) -> anyhow::Result<Vec<bool>> {
            let mut rounds = self.rounds.borrow_mut();
            if let Some(limit) = self.fail_after {
                if rounds.len() >= limit {
                    anyhow::bail!("backend exploded");
                }
            }
            for &(x, a) in probes {
                assert!(state.contains(x, a), "backend got a dead probe ({x}, {a})");
                assert!(state.dom_size(x) > 1, "backend got a singleton probe ({x}, {a})");
            }
            rounds.push(probes.to_vec());
            Ok(vec![true; probes.len()])
        }
    }

    #[test]
    fn merge_loop_hands_backends_filtered_rounds_of_at_most_k() {
        // equality chain: root AC keeps every domain full, so the probe
        // set is deterministic (12 pairs -> rounds of <= 3)
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let rounds = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let backend = RecordingBackend { rounds: rounds.clone(), k: 3, fail_after: None };
        let mut engine = SacParallel::with_backend(Box::new(backend));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce_sac(&p, &mut s, &mut c);
        assert!(out.is_consistent(), "all-consistent verdicts cannot wipe anything");
        assert_eq!(engine.name(), "sac-test");
        let rounds = rounds.borrow();
        assert!(!rounds.is_empty());
        assert!(rounds.iter().all(|r| !r.is_empty() && r.len() <= 3), "round sizes: {rounds:?}");
        let probed: u64 = rounds.iter().map(|r| r.len() as u64).sum();
        assert_eq!(probed, engine.probes);
    }

    #[test]
    fn backend_failure_poisons_the_engine() {
        // pigeonhole(3,2) is AC-consistent with full domains: the merge
        // loop reliably reaches a second probe round (6 pairs, k = 2)
        let p = crate::gen::pigeonhole(3, 2);
        let rounds = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let backend = RecordingBackend { rounds, k: 2, fail_after: Some(1) };
        let mut engine = SacParallel::with_backend(Box::new(backend));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce_sac(&p, &mut s, &mut c);
        assert!(!out.is_consistent(), "a failed backend must not report consistent");
        let msg = engine.failed.as_deref().expect("engine poisoned");
        assert!(msg.contains("exploded"), "lost the backend error: {msg}");
        // reachable through the trait too, so the CLI can refuse to turn
        // a poisoned run into an UNSAT verdict
        assert_eq!(engine.failure(), Some(msg));
        // poisoned engines stay poisoned (like TensorEngine)
        let mut s2 = State::new(&p);
        assert!(!engine.enforce_sac(&p, &mut s2, &mut c).is_consistent());
        // ...until reset
        engine.reset(&p);
        assert!(engine.failed.is_none());
    }

    #[test]
    fn problem_fingerprint_distinguishes_same_shaped_problems() {
        // same name, var count, domain sizes, and constraint scopes —
        // only the relation bits differ.  SacXla must NOT reuse a
        // session (and its device-resident constraint tensor) across
        // these.
        let mut eq_chain = Problem::new("chain", 4, 3);
        let mut neq_chain = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        let ne = Relation::from_fn(3, 3, |a, b| a != b);
        for v in 0..3 {
            eq_chain.add_constraint(v, v + 1, eq.clone());
            neq_chain.add_constraint(v, v + 1, ne.clone());
        }
        assert_ne!(problem_fingerprint(&eq_chain), problem_fingerprint(&neq_chain));
        assert_eq!(problem_fingerprint(&eq_chain), problem_fingerprint(&eq_chain));
    }

    #[test]
    fn sac_xla_without_artifacts_poisons_not_panics() {
        // offline (no artifact dir): the lazy session start must fail
        // cleanly — poisoned engine, wipeout outcome, clear message.
        let mut engine = SacXla::with_artifact_dir(
            4,
            std::path::PathBuf::from("/nonexistent-artifact-dir"),
        );
        assert_eq!(engine.name(), "sac-xla");
        let p = crate::gen::pigeonhole(3, 2);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = engine.enforce(&p, &mut s, &[], &mut c);
        assert!(!out.is_consistent());
        let msg = engine.failed.as_deref().expect("offline sac-xla must poison");
        assert!(msg.contains("coordinator session"), "unhelpful failure: {msg}");
        engine.reset(&p);
        assert!(engine.failed.is_none(), "reset must clear the poison for a retry");
    }

    #[test]
    fn probe_counts_match_between_sequential_and_batched() {
        // both engines probe the same (var, value) pairs per pass when
        // no removals interleave; on an already-SAC instance the counts
        // are exactly equal (one full pass each).
        let mut p = Problem::new("chain", 4, 3);
        let eq = Relation::from_fn(3, 3, |a, b| a == b);
        for v in 0..3 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        let mut c = Counters::default();
        let mut seq = Sac1::new(RtacNative::incremental());
        let mut s1 = State::new(&p);
        assert!(seq.enforce_sac(&p, &mut s1, &mut c).is_consistent());
        let mut par = SacParallel::new(3);
        let mut s2 = State::new(&p);
        assert!(par.enforce_sac(&p, &mut s2, &mut c).is_consistent());
        assert_eq!(seq.probes, par.probes);
        assert!(par.probes > 0);
    }
}
