//! AC-3 (Mackworth 1977) — the paper's baseline comparator.
//!
//! A queue of directed arcs; each pop *revises* one variable against one
//! constraint by scanning, value by value, for a support in the witness
//! variable's current domain.  The scan is deliberately scalar (`allows`
//! probes) — the bit-parallel variant lives in [`super::ac3bit`] so the
//! ablation bench can separate algorithmic from representational gains.
//!
//! Queue ordering is pluggable ([`QueueOrder`]): FIFO (classic), LIFO,
//! and smallest-domain-first (a revision-ordering heuristic in the
//! spirit of Boussemart et al. [5]).

use std::collections::VecDeque;

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{Arc, Problem, State, VarId};

/// Revision (queue pop) ordering policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueOrder {
    /// First-in first-out (the textbook AC-3).
    Fifo,
    /// Last-in first-out (depth-first propagation).
    Lifo,
    /// Pop the arc whose *revised* variable has the smallest domain.
    MinDom,
}

/// The AC-3 engine.
pub struct Ac3 {
    order: QueueOrder,
    queue: VecDeque<Arc>,
    in_queue: Vec<bool>, // indexed by arc id = cons*2 + is_x
    vals_buf: Vec<usize>,
}

#[inline]
fn arc_id(a: Arc) -> usize {
    a.cons * 2 + a.is_x as usize
}

impl Ac3 {
    pub fn new(order: QueueOrder) -> Ac3 {
        Ac3 { order, queue: VecDeque::new(), in_queue: Vec::new(), vals_buf: Vec::new() }
    }

    fn push(&mut self, a: Arc) {
        let id = arc_id(a);
        if !self.in_queue[id] {
            self.in_queue[id] = true;
            self.queue.push_back(a);
        }
    }

    fn pop(&mut self, problem: &Problem, state: &State) -> Option<Arc> {
        let a = match self.order {
            QueueOrder::Fifo => self.queue.pop_front()?,
            QueueOrder::Lifo => self.queue.pop_back()?,
            QueueOrder::MinDom => {
                // linear scan for the smallest revised-variable domain
                let (best, _) = self
                    .queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &a)| state.dom_size(problem.arc_var(a)))?;
                self.queue.remove(best)?
            }
        };
        self.in_queue[arc_id(a)] = false;
        Some(a)
    }

    /// Scalar support scan: does (var=a) have a support on this arc?
    fn has_support(
        problem: &Problem,
        state: &State,
        arc: Arc,
        a: usize,
        counters: &mut Counters,
    ) -> bool {
        let other = problem.arc_other(arc);
        let row = problem.arc_support_row(arc, a);
        for b in state.dom(other).iter_ones() {
            counters.support_checks += 1;
            if row.get(b) {
                return true;
            }
        }
        false
    }

    /// Remove unsupported values of the arc's revised variable.
    /// Returns (changed, wiped).
    fn revise(
        &mut self,
        problem: &Problem,
        state: &mut State,
        arc: Arc,
        counters: &mut Counters,
    ) -> (bool, bool) {
        counters.revisions += 1;
        let var = problem.arc_var(arc);
        self.vals_buf.clear();
        self.vals_buf.extend(state.dom(var).iter_ones());
        let mut changed = false;
        // take the buffer to avoid aliasing self in the loop
        let vals = std::mem::take(&mut self.vals_buf);
        for &a in &vals {
            if !Self::has_support(problem, state, arc, a, counters) {
                state.remove(var, a);
                counters.removals += 1;
                changed = true;
            }
        }
        self.vals_buf = vals;
        (changed, changed && state.wiped(var))
    }

    fn seed(&mut self, problem: &Problem, touched: &[VarId]) {
        self.queue.clear();
        self.in_queue.clear();
        self.in_queue.resize(problem.n_constraints() * 2, false);
        if touched.is_empty() {
            for a in problem.all_arcs() {
                self.push(a);
            }
        } else {
            // domains of `touched` changed: revise their neighbours
            for &v in touched {
                for &a in problem.arcs_of(v) {
                    // the arc revising the *other* endpoint, witnessed by v
                    let rev = Arc { cons: a.cons, is_x: !a.is_x };
                    self.push(rev);
                }
            }
        }
    }
}

impl Propagator for Ac3 {
    fn name(&self) -> &'static str {
        match self.order {
            QueueOrder::Fifo => "ac3",
            QueueOrder::Lifo => "ac3-lifo",
            QueueOrder::MinDom => "ac3-dom",
        }
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.seed(problem, touched);
        while let Some(arc) = self.pop(problem, state) {
            let (changed, wiped) = self.revise(problem, state, arc, counters);
            if wiped {
                return Outcome::Wipeout(problem.arc_var(arc));
            }
            if changed {
                let var = problem.arc_var(arc);
                let witness = problem.arc_other(arc);
                for &a in problem.arcs_of(var) {
                    let neighbour_arc = Arc { cons: a.cons, is_x: !a.is_x };
                    let nv = problem.arc_var(neighbour_arc);
                    if nv != witness {
                        self.push(neighbour_arc);
                    }
                }
            }
        }
        Outcome::Consistent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Relation;

    fn chain_eq(n: usize, d: usize) -> Problem {
        let mut p = Problem::new("chain", n, d);
        let eq = Relation::from_fn(d, d, |a, b| a == b);
        for v in 0..n - 1 {
            p.add_constraint(v, v + 1, eq.clone());
        }
        p
    }

    #[test]
    fn full_domains_on_equality_chain_stay_full() {
        let p = chain_eq(5, 3);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s, &[], &mut c);
        assert_eq!(out, Outcome::Consistent);
        assert_eq!(s.total_size(), 15);
        assert!(c.revisions >= 8); // all arcs revised at least once
    }

    #[test]
    fn assignment_propagates_down_chain() {
        let p = chain_eq(6, 4);
        let mut s = State::new(&p);
        s.assign(0, 2);
        let mut c = Counters::default();
        let out = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s, &[0], &mut c);
        assert_eq!(out, Outcome::Consistent);
        for v in 0..6 {
            assert_eq!(s.value(v), Some(2), "var {v}");
        }
        assert_eq!(c.removals as usize, 5 * 3);
    }

    #[test]
    fn wipeout_detected() {
        let mut p = Problem::new("unsat", 2, 2);
        p.add_constraint(0, 1, Relation::forbid_all(2, 2));
        let mut s = State::new(&p);
        let mut c = Counters::default();
        let out = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s, &[], &mut c);
        assert!(matches!(out, Outcome::Wipeout(_)));
    }

    #[test]
    fn touched_seeding_equivalent_to_full_on_prior_ac_state() {
        // enforce fully, assign, then touched-seeded enforcement must
        // agree with full re-enforcement.
        let p = crate::gen::queens(6);
        let mut engine = Ac3::new(QueueOrder::Fifo);
        let mut c = Counters::default();

        let mut s1 = State::new(&p);
        assert!(engine.enforce(&p, &mut s1, &[], &mut c).is_consistent());
        s1.assign(0, 1);
        let o1 = engine.enforce(&p, &mut s1, &[0], &mut c);

        let mut s2 = State::new(&p);
        s2.assign(0, 1);
        let o2 = engine.enforce(&p, &mut s2, &[], &mut c);

        assert_eq!(o1.is_consistent(), o2.is_consistent());
        assert_eq!(s1.snapshot(), s2.snapshot());
    }

    #[test]
    fn all_orders_reach_same_closure() {
        let p = crate::gen::random::random_csp(&crate::gen::random::RandomSpec::new(
            12, 6, 0.6, 0.45, 1234,
        ));
        let mut results = Vec::new();
        for order in [QueueOrder::Fifo, QueueOrder::Lifo, QueueOrder::MinDom] {
            let mut s = State::new(&p);
            let mut c = Counters::default();
            let out = Ac3::new(order).enforce(&p, &mut s, &[], &mut c);
            results.push((out.is_consistent(), s.snapshot()));
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn already_consistent_makes_no_removals() {
        let p = chain_eq(4, 3);
        let mut s = State::new(&p);
        let mut c = Counters::default();
        Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s, &[], &mut c);
        let mut c2 = Counters::default();
        let out = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s, &[], &mut c2);
        assert!(out.is_consistent());
        assert_eq!(c2.removals, 0);
    }
}
