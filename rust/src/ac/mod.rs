//! Arc consistency engines.
//!
//! Five interchangeable implementations behind the [`Propagator`] trait:
//!
//! * [`ac3::Ac3`] — the paper's baseline: queue of directed arcs,
//!   value-by-value support scan (pluggable queue ordering).
//! * [`ac2001::Ac2001`] — AC-3 + *last support* residues (ref [4]):
//!   optimal O(ed²) worst case.
//! * [`ac3bit::Ac3Bit`] — AC-3 with bitwise support tests (ref [8]):
//!   one `AND`+`any` per value instead of a value loop.
//! * [`rtac::RtacNative`] — the paper's contribution in native form:
//!   synchronous Jacobi-style sweeps of Eq. 1 (exactly what the tensor
//!   path computes), dense or Prop.-2 incremental.  Counts
//!   `#Recurrence`; the queue engines count `#Revision`.
//! * [`rtac_par::RtacParallel`] — the same dense recurrence with each
//!   sweep partitioned across threads over the flat domain-plane arena
//!   (`rtac-par` auto-sizes, `rtac-parN` pins N workers).  Bit-identical
//!   to `rtac` in closure, outcome and `#Recurrence`.
//!
//! All engines compute the same unique closure (Prop. 1) — asserted
//! pairwise by integration tests on random instances.

pub mod ac2001;
pub mod ac3;
pub mod ac3bit;
pub mod rtac;
pub mod rtac_par;
pub mod sac;

use crate::core::{Problem, State, VarId};

/// Result of an enforcement run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// All domains non-empty and arc consistent.
    Consistent,
    /// Some domain was wiped out: the current assignment is dead.
    Wipeout(VarId),
}

impl Outcome {
    pub fn is_consistent(&self) -> bool {
        matches!(self, Outcome::Consistent)
    }
}

/// Work counters in the paper's terms (Table 1).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// AC-3-family: revise() calls (queue pops).  Paper's `#Revision`.
    pub revisions: u64,
    /// RTAC-family: full sweeps executed.  Paper's `#Recurrence`.
    pub recurrences: u64,
    /// Values removed by the run.
    pub removals: u64,
    /// Individual support checks (finer-grained than revisions; used by
    /// the ablation benches).
    pub support_checks: u64,
}

impl Counters {
    pub fn add(&mut self, other: &Counters) {
        self.revisions += other.revisions;
        self.recurrences += other.recurrences;
        self.removals += other.removals;
        self.support_checks += other.support_checks;
    }
}

/// An arc-consistency enforcement engine.
pub trait Propagator {
    /// Human-readable engine name (bench labels).
    fn name(&self) -> &'static str;

    /// Enforce AC on `state`, given that the domains of `touched`
    /// variables just changed (empty slice = enforce from scratch on the
    /// whole network, e.g. at the search root).
    ///
    /// Removals go through `state.remove` so the search trail can undo
    /// them.  Returns the outcome and updates `counters`.
    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome;

    /// Reset any per-problem caches (e.g. AC-2001 residues) — called when
    /// the engine is reused for a different problem instance.
    fn reset(&mut self, _problem: &Problem) {}
}

/// Engine selection by name (CLI / bench wiring).
pub fn make_engine(name: &str) -> Result<Box<dyn Propagator>, String> {
    match name {
        "ac3" => Ok(Box::new(ac3::Ac3::new(ac3::QueueOrder::Fifo))),
        "ac3-lifo" => Ok(Box::new(ac3::Ac3::new(ac3::QueueOrder::Lifo))),
        "ac3-dom" => Ok(Box::new(ac3::Ac3::new(ac3::QueueOrder::MinDom))),
        "ac2001" => Ok(Box::new(ac2001::Ac2001::new())),
        "ac3bit" => Ok(Box::new(ac3bit::Ac3Bit::new())),
        "rtac" => Ok(Box::new(rtac::RtacNative::dense())),
        "rtac-inc" => Ok(Box::new(rtac::RtacNative::incremental())),
        // SAC is a *stronger* consistency: not interchangeable with the
        // AC engines in closure-equality tests, but plugs into the same
        // solver for stronger-but-costlier propagation.
        "sac" => Ok(Box::new(sac::Sac1::new(ac3bit::Ac3Bit::new()))),
        "sac-rtac" => Ok(Box::new(sac::Sac1::new(rtac::RtacNative::incremental()))),
        // "rtac-par" = auto worker count; "rtac-parN" pins N workers.
        other if other.starts_with("rtac-par") => {
            let suffix = &other["rtac-par".len()..];
            let workers = if suffix.is_empty() {
                0
            } else {
                suffix
                    .parse::<usize>()
                    .ok()
                    .filter(|&w| w >= 1)
                    .ok_or_else(|| format!("bad worker count in engine name {other:?}"))?
            };
            Ok(Box::new(rtac_par::RtacParallel::new(workers)))
        }
        other => Err(format!(
            "unknown engine {other:?} (try ac3 | ac3-lifo | ac3-dom | ac2001 | ac3bit | rtac | rtac-inc | rtac-par[N] | sac | sac-rtac)"
        )),
    }
}

/// All engine names (for cross-engine agreement tests and benches).
/// `rtac-par` auto-sizes its workers (inline below ~16 vars/worker), so
/// the small agreement-test instances stay cheap; pinned-worker
/// bit-identity lives in `rtac_par`'s property suite.
pub const ALL_ENGINES: &[&str] =
    &["ac3", "ac3-lifo", "ac3-dom", "ac2001", "ac3bit", "rtac", "rtac-inc", "rtac-par"];
