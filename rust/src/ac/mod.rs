//! Arc consistency engines.
//!
//! Seven interchangeable AC implementations behind the [`Propagator`]
//! trait (five queue/sweep engines plus the pooled parallel pair), with
//! the SAC family layered on top:
//!
//! * [`ac3::Ac3`] — the paper's baseline: queue of directed arcs,
//!   value-by-value support scan (pluggable queue ordering).
//! * [`ac2001::Ac2001`] — AC-3 + *last support* residues (ref [4]):
//!   optimal O(ed²) worst case.
//! * [`ac3bit::Ac3Bit`] — AC-3 with bitwise support tests (ref [8]):
//!   one `AND`+`any` per value instead of a value loop.
//! * [`rtac::RtacNative`] — the paper's contribution in native form:
//!   synchronous Jacobi-style sweeps of Eq. 1 (exactly what the tensor
//!   path computes), dense (`rtac`) or Prop.-2 incremental
//!   (`rtac-inc`).  Counts `#Recurrence`; the queue engines count
//!   `#Revision`.
//! * [`rtac_par::RtacParallel`] — the same recurrence with each sweep
//!   partitioned across a **persistent worker pool**
//!   ([`crate::exec::WorkerPool`]) over the flat domain-plane arena:
//!   `rtac-par[N]` dense, `rtac-par-inc[N]` with the Prop.-2
//!   incremental candidate set (per-chunk changed lists merged at the
//!   sweep barrier like counters).  `rtac-par-scoped[N]` keeps the old
//!   per-sweep `std::thread::scope` spawning purely as the bench
//!   baseline the pool amortises away.  All bit-identical to `rtac`
//!   in closure, outcome and `#Recurrence`.
//! * [`sac::Sac1`] / [`sac::SacParallel`] / [`sac::SacXla`] /
//!   [`sac::SacMixed`] — singleton arc consistency, a *stronger*
//!   consistency: `sac` / `sac-rtac` probe sequentially; the batched
//!   engines run K probes per round behind the [`sac::ProbeBackend`]
//!   seam — `sac-par[N]` on the worker pool (scratch plane pairs from
//!   a [`crate::core::PlaneSlab`]), `sac-xla[N]` routed through the
//!   coordinator onto the compiled `fixb*` tensor executables in delta
//!   form (artifact-gated: it lazily starts a session and poisons
//!   itself when none can start), and `sac-mixed[N]` splitting each
//!   round between the CPU pool and the tensor route by a latency cost
//!   model ([`sac::MixedProbeBackend`]; runs CPU-only offline instead
//!   of poisoning).  Not interchangeable with the AC engines in
//!   closure-equality tests, but all SAC engines reach the same unique
//!   SAC closure and plug into the same solver for
//!   stronger-but-costlier propagation.
//!
//! Engine names take an optional worker-count suffix (`rtac-par4`,
//! `sac-par2`, `sac-xla8` — for `sac-xla` the count is the probe batch
//! per round; for `sac-mixed` it is the CPU probe workers); the bare
//! name auto-sizes.  A `0` suffix is rejected at parse time — a
//! zero-worker engine could never make progress.
//!
//! All AC engines compute the same unique closure (Prop. 1) — asserted
//! pairwise by integration tests on random instances.

pub mod ac2001;
pub mod ac3;
pub mod ac3bit;
pub mod rtac;
pub mod rtac_par;
pub mod sac;

use crate::core::{Problem, State, VarId};

/// Result of an enforcement run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// All domains non-empty and arc consistent.
    Consistent,
    /// Some domain was wiped out: the current assignment is dead.
    Wipeout(VarId),
}

impl Outcome {
    pub fn is_consistent(&self) -> bool {
        matches!(self, Outcome::Consistent)
    }
}

/// Work counters in the paper's terms (Table 1).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// AC-3-family: revise() calls (queue pops).  Paper's `#Revision`.
    pub revisions: u64,
    /// RTAC-family: full sweeps executed.  Paper's `#Recurrence`.
    pub recurrences: u64,
    /// Values removed by the run.
    pub removals: u64,
    /// Individual support checks (finer-grained than revisions; used by
    /// the ablation benches).
    pub support_checks: u64,
}

impl Counters {
    pub fn add(&mut self, other: &Counters) {
        self.revisions += other.revisions;
        self.recurrences += other.recurrences;
        self.removals += other.removals;
        self.support_checks += other.support_checks;
    }
}

/// An arc-consistency enforcement engine.
pub trait Propagator {
    /// Human-readable engine name (bench labels).
    fn name(&self) -> &'static str;

    /// Enforce AC on `state`, given that the domains of `touched`
    /// variables just changed (empty slice = enforce from scratch on the
    /// whole network, e.g. at the search root).
    ///
    /// Removals go through `state.remove` so the search trail can undo
    /// them.  Returns the outcome and updates `counters`.
    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome;

    /// Reset any per-problem caches (e.g. AC-2001 residues) — called when
    /// the engine is reused for a different problem instance.
    fn reset(&mut self, _problem: &Problem) {}

    /// Infrastructure failure that poisoned the engine, if any.  The
    /// tensor-routed engines ([`sac::SacXla`], [`sac::SacParallel`] on a
    /// coordinator failure, `coordinator::TensorEngine`) report synthetic
    /// wipeouts once poisoned so search terminates; callers that turn
    /// outcomes into verdicts (the CLI) must check this afterwards —
    /// a poisoned run is an *error*, not an UNSAT.
    fn failure(&self) -> Option<&str> {
        None
    }
}

/// Parse the worker-count suffix of an engine name like `rtac-par4`
/// (`prefix` = `"rtac-par"`).  Empty suffix = 0 = auto-size.  An
/// explicit `0` is rejected here, at parse time: a zero-worker engine
/// could never run a sweep or a probe, so constructing one would only
/// defer the failure to the first enforcement.  Public because every
/// CLI surface that accepts an engine-shaped name (`--engine`,
/// `rtac serve --worker-engine`) must parse the same grammar.
pub fn parse_worker_suffix(name: &str, prefix: &str) -> Result<usize, String> {
    let suffix = &name[prefix.len()..];
    if suffix.is_empty() {
        return Ok(0); // auto
    }
    match suffix.parse::<usize>() {
        Ok(0) => Err(format!(
            "engine {name:?}: 0 workers is not runnable — use {prefix:?} for an \
             auto-sized pool or {prefix}N with N >= 1"
        )),
        Ok(w) => Ok(w),
        Err(_) => Err(format!("bad worker count in engine name {name:?}")),
    }
}

/// Engine selection by name (CLI / bench wiring).
pub fn make_engine(name: &str) -> Result<Box<dyn Propagator>, String> {
    match name {
        "ac3" => Ok(Box::new(ac3::Ac3::new(ac3::QueueOrder::Fifo))),
        "ac3-lifo" => Ok(Box::new(ac3::Ac3::new(ac3::QueueOrder::Lifo))),
        "ac3-dom" => Ok(Box::new(ac3::Ac3::new(ac3::QueueOrder::MinDom))),
        "ac2001" => Ok(Box::new(ac2001::Ac2001::new())),
        "ac3bit" => Ok(Box::new(ac3bit::Ac3Bit::new())),
        "rtac" => Ok(Box::new(rtac::RtacNative::dense())),
        "rtac-inc" => Ok(Box::new(rtac::RtacNative::incremental())),
        // SAC is a *stronger* consistency: not interchangeable with the
        // AC engines in closure-equality tests, but plugs into the same
        // solver for stronger-but-costlier propagation.
        "sac" => Ok(Box::new(sac::Sac1::new(ac3bit::Ac3Bit::new()))),
        "sac-rtac" => Ok(Box::new(sac::Sac1::new(rtac::RtacNative::incremental()))),
        // Pool-backed engines: bare name = auto worker count, an `N`
        // suffix pins N workers.  Longest prefix first — `rtac-par4`
        // must not shadow `rtac-par-inc4`.
        other if other.starts_with("rtac-par-inc") => {
            let workers = parse_worker_suffix(other, "rtac-par-inc")?;
            Ok(Box::new(rtac_par::RtacParallel::incremental(workers)))
        }
        other if other.starts_with("rtac-par-scoped") => {
            let workers = parse_worker_suffix(other, "rtac-par-scoped")?;
            Ok(Box::new(rtac_par::RtacParallel::scoped_spawn(workers)))
        }
        other if other.starts_with("rtac-par") => {
            let workers = parse_worker_suffix(other, "rtac-par")?;
            Ok(Box::new(rtac_par::RtacParallel::new(workers)))
        }
        other if other.starts_with("sac-par") => {
            let workers = parse_worker_suffix(other, "sac-par")?;
            Ok(Box::new(sac::SacParallel::new(workers)))
        }
        // Tensor-routed batched SAC: probes go through a lazily-started
        // coordinator session onto the `fixb*` artifacts.  N is the
        // probe batch per round (0 suffix rejected like the others).
        other if other.starts_with("sac-xla") => {
            let batch = parse_worker_suffix(other, "sac-xla")?;
            Ok(Box::new(sac::SacXla::new(batch)))
        }
        // Mixed CPU/tensor batched SAC: each round split between the
        // pool and a lazily-started coordinator session by the cost
        // model; CPU-only offline.  N is the CPU probe workers.
        other if other.starts_with("sac-mixed") => {
            let workers = parse_worker_suffix(other, "sac-mixed")?;
            Ok(Box::new(sac::SacMixed::new(workers)))
        }
        other => Err(format!(
            "unknown engine {other:?} (try ac3 | ac3-lifo | ac3-dom | ac2001 | ac3bit | rtac | \
             rtac-inc | rtac-par[N] | rtac-par-inc[N] | rtac-par-scoped[N] | sac | sac-rtac | \
             sac-par[N] | sac-xla[N] | sac-mixed[N])"
        )),
    }
}

/// All AC engine names (for cross-engine agreement tests and benches;
/// SAC engines are excluded — they compute a stronger closure).
/// The pool engines auto-size their workers (inline below ~16
/// vars/worker), so the small agreement-test instances stay cheap;
/// pinned-worker bit-identity lives in `rtac_par`'s property suite.
pub const ALL_ENGINES: &[&str] = &[
    "ac3",
    "ac3-lifo",
    "ac3-dom",
    "ac2001",
    "ac3bit",
    "rtac",
    "rtac-inc",
    "rtac-par",
    "rtac-par-inc",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_worker_engine_names_rejected_at_parse_time() {
        for name in
            ["rtac-par0", "rtac-par-inc0", "rtac-par-scoped0", "sac-par0", "sac-xla0", "sac-mixed0"]
        {
            let err = make_engine(name).err().unwrap_or_else(|| {
                panic!("{name} must be rejected at parse time")
            });
            assert!(err.contains("0 workers"), "{name}: unhelpful error {err:?}");
        }
    }

    #[test]
    fn pool_engine_names_parse_with_and_without_counts() {
        for name in
            ["rtac-par", "rtac-par3", "rtac-par-inc", "rtac-par-inc2", "rtac-par-scoped2",
             "sac-par", "sac-par4", "sac-xla", "sac-xla8", "sac-mixed", "sac-mixed4"]
        {
            assert!(make_engine(name).is_ok(), "{name} must parse");
        }
        assert!(make_engine("rtac-parx").is_err());
        assert!(make_engine("sac-par-1").is_err());
        assert!(make_engine("sac-xlaq").is_err());
        assert!(make_engine("sac-mixedy").is_err());
    }

    #[test]
    fn engine_names_self_report() {
        for (name, reported) in [
            ("rtac-par2", "rtac-par"),
            ("rtac-par-inc2", "rtac-par-inc"),
            ("rtac-par-scoped2", "rtac-par-scoped"),
            ("sac-par2", "sac-par"),
            ("sac-xla4", "sac-xla"),
            ("sac-mixed2", "sac-mixed"),
        ] {
            assert_eq!(make_engine(name).unwrap().name(), reported);
        }
    }

    #[test]
    fn unknown_engine_error_lists_the_full_family() {
        let err = make_engine("nope").unwrap_err();
        for name in ["rtac-par-scoped[N]", "sac-par[N]", "sac-xla[N]", "sac-mixed[N]"] {
            assert!(err.contains(name), "error string misses {name}: {err}");
        }
    }
}
