//! AC-2001/3.1 (Bessière, Régin, Yap & Zhang 2005, [4]) — the optimal
//! coarse-grained sequential algorithm.
//!
//! AC-3's support scan restarts from scratch on every revision; AC-2001
//! memoises, per directed arc and value, the *last* support found
//! (`last[arc][a]`).  A revision first re-checks the residue in O(1) and
//! only on failure resumes the scan *after* it — each (arc, value) pair
//! scans every witness value at most once over a full enforcement,
//! giving the optimal O(e·d²) bound.
//!
//! The `last` table is search-state dependent: on backtrack a recorded
//! support may reappear, which is *safe* (it is still a support if it is
//! in the domain — supports never need to move backwards within one
//! enforcement; across enforcements the residue is just a hint, cf.
//! AC-3^rm residues [7]).

use std::collections::VecDeque;

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{Arc, Problem, State, VarId};

/// The AC-2001 engine.
pub struct Ac2001 {
    queue: VecDeque<Arc>,
    in_queue: Vec<bool>,
    /// last[arc_id] indexed by value -> last known support (usize::MAX = none yet).
    last: Vec<Vec<usize>>,
    vals_buf: Vec<usize>,
}

#[inline]
fn arc_id(a: Arc) -> usize {
    a.cons * 2 + a.is_x as usize
}

impl Ac2001 {
    pub fn new() -> Ac2001 {
        Ac2001 { queue: VecDeque::new(), in_queue: Vec::new(), last: Vec::new(), vals_buf: Vec::new() }
    }

    fn ensure_tables(&mut self, problem: &Problem) {
        let want = problem.n_constraints() * 2;
        if self.last.len() != want {
            self.last = (0..want)
                .map(|id| {
                    let arc = Arc { cons: id / 2, is_x: id % 2 == 1 };
                    // note: arc_id(x-arc)=cons*2+1
                    let var = problem.arc_var(arc);
                    vec![usize::MAX; problem.dom_size(var)]
                })
                .collect();
        }
    }

    fn push(&mut self, a: Arc) {
        let id = arc_id(a);
        if !self.in_queue[id] {
            self.in_queue[id] = true;
            self.queue.push_back(a);
        }
    }

    /// Find a support for (var=a) at-or-after the residue, updating it.
    fn has_support(
        &mut self,
        problem: &Problem,
        state: &State,
        arc: Arc,
        a: usize,
        counters: &mut Counters,
    ) -> bool {
        let id = arc_id(arc);
        let other = problem.arc_other(arc);
        let dom_other = state.dom(other);
        let residue = self.last[id][a];
        if residue != usize::MAX && dom_other.get(residue) {
            // residue still valid: O(1) accept (no fresh support check)
            return true;
        }
        let row = problem.arc_support_row(arc, a);
        // resume the scan strictly after the stale residue; wrap is NOT
        // needed within one enforcement (domains only shrink), but across
        // enforcements (search) residues can be stale-low, so we fall
        // back to a full scan from 0 when the tail fails.
        let start = if residue == usize::MAX { 0 } else { residue + 1 };
        for b in dom_other.iter_ones() {
            if b < start {
                continue;
            }
            counters.support_checks += 1;
            if row.get(b) {
                self.last[id][a] = b;
                return true;
            }
        }
        if start > 0 {
            for b in dom_other.iter_ones() {
                if b >= start {
                    break;
                }
                counters.support_checks += 1;
                if row.get(b) {
                    self.last[id][a] = b;
                    return true;
                }
            }
        }
        false
    }

    fn revise(
        &mut self,
        problem: &Problem,
        state: &mut State,
        arc: Arc,
        counters: &mut Counters,
    ) -> (bool, bool) {
        counters.revisions += 1;
        let var = problem.arc_var(arc);
        self.vals_buf.clear();
        self.vals_buf.extend(state.dom(var).iter_ones());
        let vals = std::mem::take(&mut self.vals_buf);
        let mut changed = false;
        for &a in &vals {
            if !self.has_support(problem, state, arc, a, counters) {
                state.remove(var, a);
                counters.removals += 1;
                changed = true;
            }
        }
        self.vals_buf = vals;
        (changed, changed && state.wiped(var))
    }
}

impl Default for Ac2001 {
    fn default() -> Self {
        Self::new()
    }
}

impl Propagator for Ac2001 {
    fn name(&self) -> &'static str {
        "ac2001"
    }

    fn reset(&mut self, _problem: &Problem) {
        self.last.clear();
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        touched: &[VarId],
        counters: &mut Counters,
    ) -> Outcome {
        self.ensure_tables(problem);
        self.queue.clear();
        self.in_queue.clear();
        self.in_queue.resize(problem.n_constraints() * 2, false);
        if touched.is_empty() {
            for a in problem.all_arcs() {
                self.push(a);
            }
        } else {
            for &v in touched {
                for &a in problem.arcs_of(v) {
                    self.push(Arc { cons: a.cons, is_x: !a.is_x });
                }
            }
        }
        while let Some(arc) = self.queue.pop_front() {
            self.in_queue[arc_id(arc)] = false;
            let (changed, wiped) = self.revise(problem, state, arc, counters);
            if wiped {
                return Outcome::Wipeout(problem.arc_var(arc));
            }
            if changed {
                let var = problem.arc_var(arc);
                let witness = problem.arc_other(arc);
                for &a in problem.arcs_of(var) {
                    let neighbour_arc = Arc { cons: a.cons, is_x: !a.is_x };
                    if problem.arc_var(neighbour_arc) != witness {
                        self.push(neighbour_arc);
                    }
                }
            }
        }
        Outcome::Consistent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::ac3::{Ac3, QueueOrder};
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::util::quickcheck::forall;

    #[test]
    fn arc_id_var_mapping_is_consistent() {
        // ensure_tables sizes last[] by arc_var; verify the id encoding.
        let mut p = Problem::new("t", 2, 3);
        p.add_constraint(0, 1, crate::core::Relation::allow_all(3, 3));
        let ax = Arc { cons: 0, is_x: true };
        let ay = Arc { cons: 0, is_x: false };
        assert_eq!(arc_id(ax), 1);
        assert_eq!(arc_id(ay), 0);
        let mut e = Ac2001::new();
        e.ensure_tables(&p);
        assert_eq!(e.last[arc_id(ax)].len(), p.dom_size(0));
        assert_eq!(e.last[arc_id(ay)].len(), p.dom_size(1));
    }

    #[test]
    fn matches_ac3_closure_on_random_instances() {
        forall("ac2001-vs-ac3", 0x2001, 20, |rng| {
            let spec = RandomSpec::new(
                3 + rng.gen_range(10),
                1 + rng.gen_range(7),
                rng.next_f64(),
                rng.next_f64() * 0.9,
                rng.next_u64(),
            );
            let p = random_csp(&spec);
            let mut s1 = State::new(&p);
            let mut s2 = State::new(&p);
            let mut c = Counters::default();
            let o1 = Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s1, &[], &mut c);
            let o2 = Ac2001::new().enforce(&p, &mut s2, &[], &mut c);
            if o1.is_consistent() != o2.is_consistent() {
                return Err(format!("outcome mismatch on {spec:?}"));
            }
            if o1.is_consistent() && s1.snapshot() != s2.snapshot() {
                return Err(format!("closure mismatch on {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn residues_cut_support_checks() {
        let p = random_csp(&RandomSpec::new(18, 10, 0.7, 0.4, 42));
        let mut c3 = Counters::default();
        let mut c01 = Counters::default();
        let mut s1 = State::new(&p);
        let mut s2 = State::new(&p);
        Ac3::new(QueueOrder::Fifo).enforce(&p, &mut s1, &[], &mut c3);
        Ac2001::new().enforce(&p, &mut s2, &[], &mut c01);
        assert!(
            c01.support_checks <= c3.support_checks,
            "ac2001 {} vs ac3 {}",
            c01.support_checks,
            c3.support_checks
        );
    }

    #[test]
    fn reused_engine_with_stale_residues_is_still_correct() {
        // Enforce, backtrack-like domain restore, enforce again: the
        // residue table now points at values that may be out of domain
        // order; the closure must still match a fresh engine's.
        let p = crate::gen::queens(7);
        let mut engine = Ac2001::new();
        let mut c = Counters::default();

        let mut s = State::new(&p);
        assert!(engine.enforce(&p, &mut s, &[], &mut c).is_consistent());
        s.push_level();
        s.assign(0, 3);
        let _ = engine.enforce(&p, &mut s, &[0], &mut c);
        s.pop_level();
        s.push_level();
        s.assign(0, 1);
        let o_reused = engine.enforce(&p, &mut s, &[0], &mut c);

        let mut fresh = State::new(&p);
        fresh.assign(0, 1);
        let o_fresh = Ac2001::new().enforce(&p, &mut fresh, &[], &mut c);
        assert_eq!(o_reused.is_consistent(), o_fresh.is_consistent());
        assert_eq!(s.snapshot(), fresh.snapshot());
    }
}
