//! ASCII table rendering for bench reports (paper-style rows on stdout).

/// Column-aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a header underline; numeric-looking cells right-align.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for c in 0..ncol {
            width[c] = self.headers[c].len();
            for r in &self.rows {
                width[c] = width[c].max(r[c].len());
            }
        }
        let numeric: Vec<bool> = (0..ncol)
            .map(|c| {
                !self.rows.is_empty()
                    && self.rows.iter().all(|r| {
                        r[c].trim_end_matches(|ch: char| "x%ms".contains(ch))
                            .parse::<f64>()
                            .is_ok()
                    })
            })
            .collect();
        let mut out = String::new();
        let fmt_cell = |text: &str, c: usize, is_num: bool| {
            if is_num {
                format!("{:>width$}", text, width = width[c])
            } else {
                format!("{:<width$}", text, width = width[c])
            }
        };
        let hdr: Vec<String> = (0..ncol).map(|c| fmt_cell(&self.headers[c], c, numeric[c])).collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            let cells: Vec<String> = (0..ncol).map(|c| fmt_cell(&r[c], c, numeric[c])).collect();
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }
}

/// Format a float with a sensible number of digits for a report cell.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.1}", x)
    } else {
        format!("{:.3}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["alpha".into(), "1.5".into()]);
        t.row(vec!["b".into(), "120".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        // numeric column right-aligned
        assert!(lines[2].ends_with("1.5"));
        assert!(lines[3].ends_with("120"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(3.4567), "3.457");
        assert_eq!(fnum(42.34), "42.3");
        assert_eq!(fnum(12345.6), "12346");
    }
}
