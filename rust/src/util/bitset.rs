//! Dynamic bitset over `u64` words — the workhorse of the native AC
//! engines (domains and relation rows are bit rows; support checks are
//! word-wise AND + any-nonzero).
//!
//! Two types share one representation:
//! * [`BitSet`] — an owning, growable-capacity bitset.
//! * [`Bits`] — a borrowed, `Copy` view over a word slice.  This is the
//!   currency of the flat-arena layout: domain rows live in one
//!   contiguous [`crate::core::DomainPlane`] buffer and relation rows in
//!   one packed buffer per direction, so accessors hand out `Bits` views
//!   instead of `&BitSet`.
//!
//! The hot operations (`intersects`, `intersect_count`, `and_assign`) are
//! branch-light loops over the word slice so LLVM auto-vectorises them.

/// A fixed-capacity bitset backed by a `Vec<u64>`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    len: usize,
    words: Vec<u64>,
}

/// Words needed to hold `len` bits.
#[inline]
pub fn words_for(len: usize) -> usize {
    (len + 63) / 64
}

/// Mask selecting the valid bits of the final word.
#[inline]
pub fn tail_mask(len: usize) -> u64 {
    let r = len % 64;
    if r == 0 {
        !0
    } else {
        (1u64 << r) - 1
    }
}

/// A borrowed view of `len` bits over a `u64` word slice (tail bits
/// beyond `len` are guaranteed clear by every producer in this crate).
#[derive(Clone, Copy)]
pub struct Bits<'a> {
    len: usize,
    words: &'a [u64],
}

impl<'a> Bits<'a> {
    /// View `len` bits over `words` (must be exactly `words_for(len)`).
    #[inline]
    pub fn new(len: usize, words: &'a [u64]) -> Bits<'a> {
        debug_assert_eq!(words.len(), words_for(len));
        Bits { len, words }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn words(&self) -> &'a [u64] {
        self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    #[inline]
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True iff no bit is set.
    #[inline]
    pub fn none(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// True iff `self & other` has any set bit — the support test.
    #[inline]
    pub fn intersects(self, other: Bits<'_>) -> bool {
        debug_assert_eq!(self.len, other.len);
        self.words.iter().zip(other.words).any(|(&a, &b)| a & b != 0)
    }

    /// Index of the lowest set bit, if any.
    #[inline]
    pub fn first(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(wi * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }

    /// Iterate indices of set bits in ascending order.
    #[inline]
    pub fn iter_ones(&self) -> OnesIter<'a> {
        OnesIter::over(self.words)
    }

    /// Copy the set bits into a Vec (convenience for tests / tracing).
    pub fn to_vec(&self) -> Vec<usize> {
        self.iter_ones().collect()
    }
}

impl std::fmt::Debug for Bits<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bits{{len:{}, ones:{:?}}}", self.len, self.to_vec())
    }
}

impl BitSet {
    /// All-zeros bitset of capacity `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitSet { len, words: vec![0; words_for(len)] }
    }

    /// All-ones bitset of capacity `len` bits.
    pub fn ones(len: usize) -> Self {
        let mut s = BitSet { len, words: vec![!0u64; words_for(len)] };
        if let Some(last) = s.words.last_mut() {
            *last &= tail_mask(len);
        }
        s
    }

    /// Build from an iterator of set bit positions.
    pub fn from_indices(len: usize, idx: impl IntoIterator<Item = usize>) -> Self {
        let mut s = Self::zeros(len);
        for i in idx {
            s.set(i);
        }
        s
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty_capacity(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    #[inline]
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True iff no bit is set.
    #[inline]
    pub fn none(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// True iff `self & other` has any set bit — the support test.
    #[inline]
    pub fn intersects(&self, other: &BitSet) -> bool {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .any(|(&a, &b)| a & b != 0)
    }

    /// popcount(self & other) — the support *count* (paper's `Sup_xy`).
    #[inline]
    pub fn intersect_count(&self, other: &BitSet) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(&a, &b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// self &= other; returns true if self changed.
    pub fn and_assign(&mut self, other: &BitSet) -> bool {
        debug_assert_eq!(self.len, other.len);
        let mut changed = false;
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            let na = *a & b;
            changed |= na != *a;
            *a = na;
        }
        changed
    }

    /// self |= other.
    pub fn or_assign(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// self &= !other (set difference); returns true if self changed.
    pub fn and_not_assign(&mut self, other: &BitSet) -> bool {
        debug_assert_eq!(self.len, other.len);
        let mut changed = false;
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            let na = *a & !b;
            changed |= na != *a;
            *a = na;
        }
        changed
    }

    /// Set every bit to zero, keeping capacity.
    pub fn clear_all(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// Index of the lowest set bit, if any.
    #[inline]
    pub fn first(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(wi * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }

    /// Borrowed [`Bits`] view of this set.
    #[inline]
    pub fn bits(&self) -> Bits<'_> {
        Bits { len: self.len, words: &self.words }
    }

    /// Iterate indices of set bits in ascending order.
    pub fn iter_ones(&self) -> OnesIter<'_> {
        OnesIter::over(&self.words)
    }

    /// Copy the set bits into a Vec (convenience for tests / tracing).
    pub fn to_vec(&self) -> Vec<usize> {
        self.iter_ones().collect()
    }
}

impl std::fmt::Debug for BitSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitSet{{len:{}, ones:{:?}}}", self.len, self.to_vec())
    }
}

/// Iterator over set-bit indices of a word slice.
pub struct OnesIter<'a> {
    words: &'a [u64],
    wi: usize,
    cur: u64,
}

impl<'a> OnesIter<'a> {
    #[inline]
    fn over(words: &'a [u64]) -> OnesIter<'a> {
        OnesIter { words, wi: 0, cur: words.first().copied().unwrap_or(0) }
    }
}

impl<'a> Iterator for OnesIter<'a> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        loop {
            if self.cur != 0 {
                let b = self.cur.trailing_zeros() as usize;
                self.cur &= self.cur - 1;
                return Some(self.wi * 64 + b);
            }
            self.wi += 1;
            if self.wi >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.wi];
        }
    }
}

/// Iterate set-bit indices of `words` restricted to `start..end`, in
/// ascending order.  Used by parallel sweep workers to walk an affected-
/// variable bitset within their chunk's variable range without scanning
/// the words outside it.
pub fn ones_in_range(words: &[u64], start: usize, end: usize) -> RangeOnesIter<'_> {
    let end = end.min(words.len() * 64);
    if start >= end {
        return RangeOnesIter { words: &[], wi: 0, cur: 0, end: 0 };
    }
    let wi = start / 64;
    let cur = words[wi] & (!0u64 << (start % 64));
    RangeOnesIter { words, wi, cur, end }
}

/// Iterator behind [`ones_in_range`].
pub struct RangeOnesIter<'a> {
    words: &'a [u64],
    wi: usize,
    cur: u64,
    end: usize,
}

impl Iterator for RangeOnesIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        loop {
            if self.cur != 0 {
                let i = self.wi * 64 + self.cur.trailing_zeros() as usize;
                if i >= self.end {
                    return None;
                }
                self.cur &= self.cur - 1;
                return Some(i);
            }
            self.wi += 1;
            if self.wi * 64 >= self.end || self.wi >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.wi];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_ones() {
        let z = BitSet::zeros(70);
        assert_eq!(z.count(), 0);
        assert!(z.none());
        let o = BitSet::ones(70);
        assert_eq!(o.count(), 70);
        assert!(!o.get(69) == false);
        // tail bits beyond len must be clear
        assert_eq!(o.words()[1] >> 6, 0);
    }

    #[test]
    fn set_get_clear() {
        let mut s = BitSet::zeros(130);
        for i in [0, 1, 63, 64, 127, 129] {
            s.set(i);
            assert!(s.get(i));
        }
        assert_eq!(s.count(), 6);
        s.clear(64);
        assert!(!s.get(64));
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn intersects_and_count() {
        let a = BitSet::from_indices(100, [1, 50, 99]);
        let b = BitSet::from_indices(100, [2, 50, 99]);
        assert!(a.intersects(&b));
        assert_eq!(a.intersect_count(&b), 2);
        let c = BitSet::from_indices(100, [3, 4]);
        assert!(!a.intersects(&c));
        assert_eq!(a.intersect_count(&c), 0);
    }

    #[test]
    fn and_assign_reports_change() {
        let mut a = BitSet::from_indices(64, [1, 2, 3]);
        let b = BitSet::from_indices(64, [2, 3, 4]);
        assert!(a.and_assign(&b));
        assert_eq!(a.to_vec(), vec![2, 3]);
        let b2 = BitSet::ones(64);
        assert!(!a.and_assign(&b2));
    }

    #[test]
    fn and_not_assign() {
        let mut a = BitSet::from_indices(64, [1, 2, 3]);
        let b = BitSet::from_indices(64, [2]);
        assert!(a.and_not_assign(&b));
        assert_eq!(a.to_vec(), vec![1, 3]);
        assert!(!a.and_not_assign(&b));
    }

    #[test]
    fn iter_ones_crosses_words() {
        let idx = vec![0, 63, 64, 65, 128, 199];
        let s = BitSet::from_indices(200, idx.clone());
        assert_eq!(s.to_vec(), idx);
        assert_eq!(s.first(), Some(0));
        assert_eq!(BitSet::zeros(10).first(), None);
    }

    #[test]
    fn or_assign() {
        let mut a = BitSet::from_indices(80, [1]);
        let b = BitSet::from_indices(80, [70]);
        a.or_assign(&b);
        assert_eq!(a.to_vec(), vec![1, 70]);
    }

    #[test]
    fn bits_view_mirrors_owner() {
        let s = BitSet::from_indices(130, [0, 64, 129]);
        let v = s.bits();
        assert_eq!(v.len(), 130);
        assert_eq!(v.count(), 3);
        assert!(v.get(64) && !v.get(65));
        assert_eq!(v.first(), Some(0));
        assert_eq!(v.to_vec(), s.to_vec());
        assert!(!v.none());
        let empty = BitSet::zeros(130);
        assert!(empty.bits().none());
        assert!(v.intersects(s.bits()));
        assert!(!v.intersects(empty.bits()));
    }

    #[test]
    fn ones_in_range_respects_bounds() {
        let s = BitSet::from_indices(200, vec![0, 5, 63, 64, 65, 128, 199]);
        let all: Vec<usize> = ones_in_range(s.words(), 0, 200).collect();
        assert_eq!(all, s.to_vec());
        let mid: Vec<usize> = ones_in_range(s.words(), 5, 65).collect();
        assert_eq!(mid, vec![5, 63, 64]);
        let word_edge: Vec<usize> = ones_in_range(s.words(), 64, 128).collect();
        assert_eq!(word_edge, vec![64, 65]);
        assert_eq!(ones_in_range(s.words(), 199, 200).collect::<Vec<_>>(), vec![199]);
        assert!(ones_in_range(s.words(), 66, 66).next().is_none());
        assert!(ones_in_range(s.words(), 300, 400).next().is_none());
        // end clamps to the slice's bit capacity
        assert_eq!(ones_in_range(s.words(), 190, 1000).collect::<Vec<_>>(), vec![199]);
    }

    #[test]
    fn bits_over_raw_words() {
        let words = [0b1010u64, 0b1];
        let v = Bits::new(65, &words);
        assert_eq!(v.to_vec(), vec![1, 3, 64]);
        assert_eq!(words_for(65), 2);
        assert_eq!(tail_mask(65), 1);
        assert_eq!(tail_mask(64), !0);
    }
}
