//! Foundation utilities: bitsets, deterministic RNG, JSON, CLI parsing,
//! tables, stats, timing, and a mini property-testing harness.  All of
//! this exists because the offline vendored crate set has no rand / serde
//! / clap / criterion / proptest — see DESIGN.md §3.

pub mod bitset;
pub mod cli;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod table;
pub mod timer;
