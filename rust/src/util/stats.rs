//! Sample statistics for the bench harness and coordinator metrics:
//! mean/stddev/min/max and order statistics (p50/p90/p99).

/// Summary statistics of a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from a sample; returns None on an empty slice.
    pub fn from(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Nearest-rank percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Streaming mean/variance (Welford) for long-running metrics where
/// keeping every sample would be wasteful.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Online {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::from(&[]).is_none());
    }

    #[test]
    fn percentiles_monotone() {
        let mut v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile_sorted(&v, 0.5);
        let p90 = percentile_sorted(&v, 0.9);
        let p99 = percentile_sorted(&v, 0.99);
        assert!(p50 <= p90 && p90 <= p99);
        assert!((p50 - 499.0).abs() <= 1.0);
        assert!((p99 - 989.0).abs() <= 1.5);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 11) as f64).collect();
        let mut o = Online::new();
        xs.iter().for_each(|&x| o.push(x));
        let s = Summary::from(&xs).unwrap();
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
    }

    #[test]
    fn online_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 0.5).collect();
        let (a, b) = xs.split_at(20);
        let mut oa = Online::new();
        a.iter().for_each(|&x| oa.push(x));
        let mut ob = Online::new();
        b.iter().for_each(|&x| ob.push(x));
        oa.merge(&ob);
        let mut all = Online::new();
        xs.iter().for_each(|&x| all.push(x));
        assert!((oa.mean() - all.mean()).abs() < 1e-9);
        assert!((oa.std() - all.std()).abs() < 1e-9);
        assert_eq!(oa.count(), all.count());
    }
}
