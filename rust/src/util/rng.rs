//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! The vendored crate set has no `rand`; everything in this repo that
//! needs randomness (instance generators, property tests, workloads)
//! goes through this so runs are reproducible from a single `u64` seed.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from one u64 via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection for unbiased sampling.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: first k entries become the sample
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate_roughly_p() {
        let mut r = Rng::new(5);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.25)).count();
        assert!((2000..3000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(3);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
        assert!(d.iter().all(|&i| i < 20));
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(11);
        let mut f = a.fork();
        assert_ne!(a.next_u64(), f.next_u64());
    }
}
