//! Miniature property-testing harness (proptest is not in the vendored
//! crate set).  Deterministic: every case derives from a base seed, and a
//! failure report prints the seed of the failing case so it can be
//! replayed with `forall_seeded`.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` on `cases` generated inputs; panics with the failing seed
/// and message on the first counterexample.
pub fn forall<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Miri interprets every case ~1000x slower than native; a handful of
    // cases still exercises the property without stalling the CI job.
    let cases = if cfg!(miri) { cases.min(4) } else { cases };
    let mut seeder = Rng::new(base_seed);
    for case in 0..cases {
        let case_seed = seeder.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (replay seed: {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (for debugging a reported failure).
pub fn forall_seeded<F>(name: &str, case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} failed (seed {case_seed:#x}): {msg}");
    }
}

/// Assertion helper producing property-style Result errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall("sum-commutes", 1, 32, |rng| {
            let a = rng.gen_range(1000) as i64;
            let b = rng.gen_range(1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_failing_seed() {
        forall("always-false", 2, 4, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<u64> = Vec::new();
        forall("collect", 42, 8, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        forall("collect", 42, 8, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
