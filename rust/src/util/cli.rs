//! Tiny CLI argument parser (the vendored crate set has no clap).
//!
//! Grammar: `binary SUBCOMMAND [positional...] [--key value | --flag]`.
//! Unknown keys are collected and reported by `finish()` so typos fail
//! loudly instead of silently using defaults.
//!
//! Ambiguity rule: `--name tok` treats `tok` as the option's value
//! whenever `tok` does not itself start with `--` (there is no flag
//! registry).  Boolean flags must therefore appear *after* positionals,
//! or use the unambiguous `--flag` / `--key=value` forms.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.options.get(key).cloned()
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get_str(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get_str(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get_str(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get_str(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got {v:?}")),
        }
    }

    /// Comma-separated list, e.g. `--densities 0.1,0.5,1.0`.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.get_str(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| format!("--{key}: bad number {p:?}")))
                .collect(),
        }
    }

    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get_str(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| format!("--{key}: bad integer {p:?}")))
                .collect(),
        }
    }

    /// Call after reading every expected option: errors on unknown keys.
    pub fn finish(&self) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown option(s): {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("solve input.csp --vars 100 --density 0.5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("solve"));
        assert_eq!(a.get_usize("vars", 0).unwrap(), 100);
        assert_eq!(a.get_f64("density", 0.0).unwrap(), 0.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["input.csp"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = parse("gen --n=10 --d=0.25");
        assert_eq!(a.get_usize("n", 0).unwrap(), 10);
        assert_eq!(a.get_f64("d", 0.0).unwrap(), 0.25);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get_usize("iters", 7).unwrap(), 7);
        assert_eq!(a.get_or("engine", "rtac"), "rtac");
    }

    #[test]
    fn lists() {
        let a = parse("bench --densities 0.1,0.5,1.0 --sizes 10,20");
        assert_eq!(a.get_f64_list("densities", &[]).unwrap(), vec![0.1, 0.5, 1.0]);
        assert_eq!(a.get_usize_list("sizes", &[]).unwrap(), vec![10, 20]);
    }

    #[test]
    fn unknown_options_detected() {
        let a = parse("run --typo 3");
        let _ = a.get_usize("iters", 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("run --n abc");
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --quiet --fast");
        assert!(a.has_flag("quiet"));
        assert!(a.has_flag("fast"));
    }
}
