//! Minimal JSON reader/writer (the vendored crate set has no serde).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serialises bench reports.  Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]` access that flows through Option.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report building.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).ok_or("bad \\u codepoint")?);
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {:?}", other.map(|c| c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_whitespace_tolerant() {
        let v = parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"n":16,"d":8,"file":"fix_n16_d8.hlo.txt"}],"format":1}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":1,"block_x":8,"entries":[
            {"name":"step_n8_d4","file":"step_n8_d4.hlo.txt","hlo_bytes":6536,
             "kind":"step","n":8,"d":4,"batch":1,
             "inputs":[{"name":"cons","shape":[8,8,4,4],"dtype":"f32"}],
             "outputs":["vars"]}]}"#;
        let v = parse(src).unwrap();
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(e.get("kind").unwrap().as_str(), Some("step"));
    }
}
