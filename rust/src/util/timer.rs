//! Timing helpers for the bench harness and coordinator metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed())
}

/// Human-readable duration (ns/µs/ms/s auto-scaled).
pub fn human(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a);
        assert!(sw.elapsed_ms() >= 0.0);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, d) = time_it(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0);
    }

    #[test]
    fn human_scales() {
        assert_eq!(human(Duration::from_nanos(500)), "500ns");
        assert!(human(Duration::from_micros(1500)).ends_with("ms"));
        assert!(human(Duration::from_millis(2500)).ends_with('s'));
        assert!(human(Duration::from_micros(12)).contains("µs"));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleep: meaningless under the interpreter
    fn restart_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(1));
        let first = sw.restart();
        assert!(first >= Duration::from_millis(1));
        assert!(sw.elapsed() < first);
    }
}
