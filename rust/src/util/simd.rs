//! Runtime-dispatched SIMD word kernels for the CPU hot path.
//!
//! Every native AC engine spends its time in three word-level operations
//! over the flat domain-plane arena and the packed relation rows:
//!
//! 1. **multi-row support intersection** ([`supported_mask`]) — given a
//!    mask of up-to-64 candidate values of one variable, decide for each
//!    candidate whether its relation row intersects the witness domain.
//!    The AVX2 path tests 4 consecutive single-word rows per iteration,
//!    the AVX-512 path 8; wide rows fall back to a vectorised any-
//!    intersect per row.  The arc loop's early exit is preserved by the
//!    caller (it stops as soon as the mask empties).
//! 2. **masked row clearing / merging** ([`zero_words`], [`or_words`]) —
//!    bulk clears of domain rows (`assign`) and OR-merges of changed /
//!    affected bitsets at sweep barriers.
//! 3. **fused changed/wipeout detection** ([`row_delta`]) — one pass
//!    computing `cur XOR next != 0` (row changed) and `next == 0` (row
//!    wiped), replacing separate change bookkeeping and `all-zero`
//!    rescans.
//!
//! Dispatch is decided once per process by [`active_isa`]
//! (`is_x86_feature_detected!`), overridable with the `RTAC_FORCE_SCALAR`
//! environment variable or [`set_forced_scalar`] — the scalar kernels in
//! [`scalar`] are the reference oracle the SIMD paths are property-tested
//! against (including lane-boundary widths 63/64/65/127/128).  The
//! AVX-512 path additionally needs a compiler new enough to have the
//! stabilized AVX-512 intrinsics (rustc ≥ 1.89, probed by `build.rs` as
//! the `rtac_avx512` cfg); otherwise [`Isa::Avx512`] silently degrades to
//! scalar and is never selected by detection.
//!
//! Engines hoist [`active_isa`] to one call per enforcement and thread
//! the [`Isa`] value through the kernels, so a toggle of the force flag
//! takes effect at the next `enforce` — which is what the
//! scalar-vs-dispatched bit-identity tests and the `simd_vs_scalar`
//! bench cells rely on.
//!
//! # Safety contract
//!
//! The kernel entry points are safe functions, but an [`Isa`] value must
//! come from [`active_isa`] (or be [`Isa::Scalar`]): hand-constructing
//! `Isa::Avx2`/`Isa::Avx512` and passing it on a machine without those
//! features would execute illegal instructions.
//!
//! ```
//! use rtac::util::simd::{self, Isa};
//!
//! // Four relation rows (one word each); the witness domain is {3}.
//! let rows = [0b1010u64, 0b0001, 0b1111, 0b0000];
//! let dom = [0b1000u64];
//! // Of the candidates {0,1,2,3}, only rows 0 and 2 contain value 3.
//! assert_eq!(simd::supported_mask(Isa::Scalar, 0b1111, &rows, 1, &dom), 0b0101);
//!
//! // Fused changed/wipeout detection over a 2-word row.
//! let d = simd::row_delta(Isa::Scalar, &[0b11, 0b1], &[0b01, 0b1]);
//! assert!(d.changed && !d.wiped);
//! let d = simd::row_delta(Isa::Scalar, &[0b11, 0b0], &[0b00, 0b0]);
//! assert!(d.changed && d.wiped);
//!
//! // The dispatched ISA gives bit-identical answers to the oracle.
//! let isa = simd::active_isa();
//! assert_eq!(
//!     simd::supported_mask(isa, 0b1111, &rows, 1, &dom),
//!     simd::scalar::supported_mask(0b1111, &rows, 1, &dom),
//! );
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Once, OnceLock};

/// Instruction set a kernel call dispatches to.
///
/// Obtain via [`active_isa`] — see the module-level safety contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable word loops — the reference oracle.
    Scalar,
    /// 256-bit paths (4 words / 4 single-word rows per iteration).
    Avx2,
    /// 512-bit paths (8 words / 8 single-word rows per iteration).
    /// Selected only when compiled with rustc ≥ 1.89 (`rtac_avx512`).
    Avx512,
}

/// Short lowercase name for bench cells and logs.
pub fn isa_name(isa: Isa) -> &'static str {
    match isa {
        Isa::Scalar => "scalar",
        Isa::Avx2 => "avx2",
        Isa::Avx512 => "avx512",
    }
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static FORCE_INIT: Once = Once::new();

fn force_init_from_env() {
    FORCE_INIT.call_once(|| {
        let on = std::env::var_os("RTAC_FORCE_SCALAR")
            .is_some_and(|v| !v.is_empty() && v != "0");
        if on {
            FORCE_SCALAR.store(true, Ordering::Relaxed);
        }
    });
}

/// Is the scalar override currently in effect (env or programmatic)?
pub fn forced_scalar() -> bool {
    force_init_from_env();
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Programmatically force (or release) the scalar kernels, overriding
/// the `RTAC_FORCE_SCALAR` environment variable.  Takes effect at the
/// next [`active_isa`] call — engines re-read it per enforcement.
pub fn set_forced_scalar(on: bool) {
    force_init_from_env();
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

fn detected_isa() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        // Miri interprets MIR and cannot execute vendor intrinsics; the
        // scalar oracle is the only meaningful path under it.
        if cfg!(miri) {
            return Isa::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            #[cfg(rtac_avx512)]
            {
                if is_x86_feature_detected!("avx512f") {
                    return Isa::Avx512;
                }
            }
            if is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        Isa::Scalar
    })
}

/// The ISA kernel calls should dispatch to right now: the widest one the
/// CPU supports, unless the scalar override is in effect.
pub fn active_isa() -> Isa {
    if forced_scalar() {
        Isa::Scalar
    } else {
        detected_isa()
    }
}

/// Report the dispatched ISA once per process (first engine construction
/// wins), so bench logs record which kernels produced the numbers.
pub fn announce_isa_once() {
    static ANNOUNCED: Once = Once::new();
    ANNOUNCED.call_once(|| {
        eprintln!("rtac: word kernels dispatching to {}", isa_name(active_isa()));
    });
}

/// Result of the fused changed/wipeout pass over one domain row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowDelta {
    /// `cur XOR next` had a set bit — the row changed.
    pub changed: bool,
    /// `next` is all-zero — the variable wiped out.
    pub wiped: bool,
}

/// Scalar reference kernels — the oracle every SIMD path must match
/// bit-for-bit (property-tested below and in `tests/engines.rs`).
pub mod scalar {
    use super::RowDelta;

    /// See [`super::supported_mask`].
    pub fn supported_mask(mask: u64, rows: &[u64], row_words: usize, dom: &[u64]) -> u64 {
        let mut out = 0u64;
        let mut m = mask;
        while m != 0 {
            let i = m.trailing_zeros() as usize;
            m &= m - 1;
            let row = &rows[i * row_words..(i + 1) * row_words];
            if row.iter().zip(dom).any(|(&r, &d)| r & d != 0) {
                out |= 1u64 << i;
            }
        }
        out
    }

    /// See [`super::zero_words`].
    pub fn zero_words(dst: &mut [u64]) {
        for w in dst.iter_mut() {
            *w = 0;
        }
    }

    /// See [`super::or_words`].
    pub fn or_words(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d |= s;
        }
    }

    /// See [`super::row_delta`].
    pub fn row_delta(cur: &[u64], next: &[u64]) -> RowDelta {
        debug_assert_eq!(cur.len(), next.len());
        let mut diff = 0u64;
        let mut alive = 0u64;
        for (&c, &n) in cur.iter().zip(next) {
            diff |= c ^ n;
            alive |= n;
        }
        RowDelta { changed: diff != 0, wiped: alive == 0 }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::RowDelta;
    use std::arch::x86_64::*;

    // SAFETY: caller must guarantee AVX2 is available (the dispatch!
    // macro only routes here after `active_isa` detection).
    #[target_feature(enable = "avx2")]
    pub unsafe fn supported_mask(mask: u64, rows: &[u64], row_words: usize, dom: &[u64]) -> u64 {
        // SAFETY: AVX2 availability is this fn's own precondition; every
        // 4-word `loadu` is kept in bounds by `i + 4 <= rows.len()`.
        unsafe {
            if row_words == 1 {
                // 4 single-word rows per iteration against a splat of the
                // witness domain word; skip groups with no candidate bits.
                let splat = _mm256_set1_epi64x(dom[0] as i64);
                let zero = _mm256_setzero_si256();
                let n = rows.len();
                let mut out = 0u64;
                let mut i = 0;
                while i + 4 <= n {
                    let nib = (mask >> i) & 0xF;
                    if nib != 0 {
                        let v = _mm256_loadu_si256(rows.as_ptr().add(i) as *const __m256i);
                        let eq = _mm256_cmpeq_epi64(_mm256_and_si256(v, splat), zero);
                        let zero_lanes = _mm256_movemask_pd(_mm256_castsi256_pd(eq)) as u64;
                        out |= (!zero_lanes & nib) << i;
                    }
                    i += 4;
                }
                while i < n {
                    if (mask >> i) & 1 != 0 && rows[i] & dom[0] != 0 {
                        out |= 1u64 << i;
                    }
                    i += 1;
                }
                out
            } else {
                let mut out = 0u64;
                let mut m = mask;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if intersects(&rows[i * row_words..(i + 1) * row_words], dom) {
                        out |= 1u64 << i;
                    }
                }
                out
            }
        }
    }

    // SAFETY: caller must guarantee AVX2 (reached only from the AVX2
    // kernels above, which carry the same precondition).
    #[target_feature(enable = "avx2")]
    unsafe fn intersects(row: &[u64], dom: &[u64]) -> bool {
        // SAFETY: AVX2 is the fn's precondition; `i + 4 <= row.len()`
        // bounds both loads (callers pass `dom` at least as long).
        unsafe {
            let n = row.len();
            let mut i = 0;
            while i + 4 <= n {
                let a = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
                let b = _mm256_loadu_si256(dom.as_ptr().add(i) as *const __m256i);
                if _mm256_testz_si256(a, b) == 0 {
                    return true;
                }
                i += 4;
            }
            while i < n {
                if row[i] & dom[i] != 0 {
                    return true;
                }
                i += 1;
            }
            false
        }
    }

    // SAFETY: caller must guarantee AVX2 (dispatch!-routed).
    #[target_feature(enable = "avx2")]
    pub unsafe fn zero_words(dst: &mut [u64]) {
        // SAFETY: AVX2 is the fn's precondition; stores stay inside
        // `dst` because `i + 4 <= n` (vector) and `i < n` (tail).
        unsafe {
            let z = _mm256_setzero_si256();
            let n = dst.len();
            let p = dst.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= n {
                _mm256_storeu_si256(p.add(i) as *mut __m256i, z);
                i += 4;
            }
            while i < n {
                *p.add(i) = 0;
                i += 1;
            }
        }
    }

    // SAFETY: caller must guarantee AVX2 (dispatch!-routed).
    #[target_feature(enable = "avx2")]
    pub unsafe fn or_words(dst: &mut [u64], src: &[u64]) {
        // SAFETY: AVX2 is the fn's precondition; `n` is the shorter of
        // the two lengths, so every load/store is in bounds for both.
        unsafe {
            let n = dst.len().min(src.len());
            let p = dst.as_mut_ptr();
            let q = src.as_ptr();
            let mut i = 0;
            while i + 4 <= n {
                let a = _mm256_loadu_si256(p.add(i) as *const __m256i);
                let b = _mm256_loadu_si256(q.add(i) as *const __m256i);
                _mm256_storeu_si256(p.add(i) as *mut __m256i, _mm256_or_si256(a, b));
                i += 4;
            }
            while i < n {
                *p.add(i) |= *q.add(i);
                i += 1;
            }
        }
    }

    // SAFETY: caller must guarantee AVX2 (dispatch!-routed).
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_delta(cur: &[u64], next: &[u64]) -> RowDelta {
        // SAFETY: AVX2 is the fn's precondition; `i + 4 <= cur.len()`
        // bounds both loads (the safe wrapper asserts equal lengths).
        unsafe {
            let n = cur.len();
            let mut diff_acc = _mm256_setzero_si256();
            let mut alive_acc = _mm256_setzero_si256();
            let mut diff = 0u64;
            let mut alive = 0u64;
            let mut i = 0;
            while i + 4 <= n {
                let c = _mm256_loadu_si256(cur.as_ptr().add(i) as *const __m256i);
                let x = _mm256_loadu_si256(next.as_ptr().add(i) as *const __m256i);
                diff_acc = _mm256_or_si256(diff_acc, _mm256_xor_si256(c, x));
                alive_acc = _mm256_or_si256(alive_acc, x);
                i += 4;
            }
            while i < n {
                diff |= cur[i] ^ next[i];
                alive |= next[i];
                i += 1;
            }
            let changed = diff != 0 || _mm256_testz_si256(diff_acc, diff_acc) == 0;
            let wiped = alive == 0 && _mm256_testz_si256(alive_acc, alive_acc) == 1;
            RowDelta { changed, wiped }
        }
    }
}

#[cfg(all(target_arch = "x86_64", rtac_avx512))]
mod avx512 {
    use super::RowDelta;
    use std::arch::x86_64::*;

    // SAFETY: caller must guarantee AVX-512F (the dispatch! macro only
    // routes here after `active_isa` detection).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn supported_mask(mask: u64, rows: &[u64], row_words: usize, dom: &[u64]) -> u64 {
        // SAFETY: AVX-512F is this fn's own precondition; every 8-word
        // `loadu` is kept in bounds by `i + 8 <= rows.len()`.
        unsafe {
            if row_words == 1 {
                // 8 single-word rows per iteration; `_mm512_test_epi64_mask`
                // yields the nonzero-lane mask directly.
                let splat = _mm512_set1_epi64(dom[0] as i64);
                let n = rows.len();
                let mut out = 0u64;
                let mut i = 0;
                while i + 8 <= n {
                    let byte = (mask >> i) & 0xFF;
                    if byte != 0 {
                        let v = _mm512_loadu_epi64(rows.as_ptr().add(i) as *const i64);
                        let nz = _mm512_test_epi64_mask(v, splat) as u64;
                        out |= (nz & byte) << i;
                    }
                    i += 8;
                }
                while i < n {
                    if (mask >> i) & 1 != 0 && rows[i] & dom[0] != 0 {
                        out |= 1u64 << i;
                    }
                    i += 1;
                }
                out
            } else {
                let mut out = 0u64;
                let mut m = mask;
                while m != 0 {
                    let i = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if intersects(&rows[i * row_words..(i + 1) * row_words], dom) {
                        out |= 1u64 << i;
                    }
                }
                out
            }
        }
    }

    // SAFETY: caller must guarantee AVX-512F (reached only from the
    // AVX-512 kernels above, which carry the same precondition).
    #[target_feature(enable = "avx512f")]
    unsafe fn intersects(row: &[u64], dom: &[u64]) -> bool {
        // SAFETY: AVX-512F is the fn's precondition; `i + 8 <= row.len()`
        // bounds both loads (callers pass `dom` at least as long).
        unsafe {
            let n = row.len();
            let mut i = 0;
            while i + 8 <= n {
                let a = _mm512_loadu_epi64(row.as_ptr().add(i) as *const i64);
                let b = _mm512_loadu_epi64(dom.as_ptr().add(i) as *const i64);
                if _mm512_test_epi64_mask(a, b) != 0 {
                    return true;
                }
                i += 8;
            }
            while i < n {
                if row[i] & dom[i] != 0 {
                    return true;
                }
                i += 1;
            }
            false
        }
    }

    // SAFETY: caller must guarantee AVX-512F (dispatch!-routed).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn zero_words(dst: &mut [u64]) {
        // SAFETY: AVX-512F is the fn's precondition; stores stay inside
        // `dst` because `i + 8 <= n` (vector) and `i < n` (tail).
        unsafe {
            let z = _mm512_setzero_si512();
            let n = dst.len();
            let p = dst.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= n {
                _mm512_storeu_epi64(p.add(i) as *mut i64, z);
                i += 8;
            }
            while i < n {
                *p.add(i) = 0;
                i += 1;
            }
        }
    }

    // SAFETY: caller must guarantee AVX-512F (dispatch!-routed).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn or_words(dst: &mut [u64], src: &[u64]) {
        // SAFETY: AVX-512F is the fn's precondition; `n` is the shorter
        // of the two lengths, so every load/store is in bounds for both.
        unsafe {
            let n = dst.len().min(src.len());
            let p = dst.as_mut_ptr();
            let q = src.as_ptr();
            let mut i = 0;
            while i + 8 <= n {
                let a = _mm512_loadu_epi64(p.add(i) as *const i64);
                let b = _mm512_loadu_epi64(q.add(i) as *const i64);
                _mm512_storeu_epi64(p.add(i) as *mut i64, _mm512_or_si512(a, b));
                i += 8;
            }
            while i < n {
                *p.add(i) |= *q.add(i);
                i += 1;
            }
        }
    }

    // SAFETY: caller must guarantee AVX-512F (dispatch!-routed).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn row_delta(cur: &[u64], next: &[u64]) -> RowDelta {
        // SAFETY: AVX-512F is the fn's precondition; `i + 8 <= cur.len()`
        // bounds both loads (the safe wrapper asserts equal lengths).
        unsafe {
            let n = cur.len();
            let mut diff_acc = _mm512_setzero_si512();
            let mut alive_acc = _mm512_setzero_si512();
            let mut diff = 0u64;
            let mut alive = 0u64;
            let mut i = 0;
            while i + 8 <= n {
                let c = _mm512_loadu_epi64(cur.as_ptr().add(i) as *const i64);
                let x = _mm512_loadu_epi64(next.as_ptr().add(i) as *const i64);
                diff_acc = _mm512_or_si512(diff_acc, _mm512_xor_si512(c, x));
                alive_acc = _mm512_or_si512(alive_acc, x);
                i += 8;
            }
            while i < n {
                diff |= cur[i] ^ next[i];
                alive |= next[i];
                i += 1;
            }
            let changed = diff != 0 || _mm512_test_epi64_mask(diff_acc, diff_acc) != 0;
            let wiped = alive == 0 && _mm512_test_epi64_mask(alive_acc, alive_acc) == 0;
            RowDelta { changed, wiped }
        }
    }
}

/// Dispatch a kernel call on an [`Isa`] value: compiled-out ISAs (non-
/// x86_64 targets, or AVX-512 on an old compiler) degrade to scalar.
macro_rules! dispatch {
    ($isa:expr, $scalar:expr, $avx2:expr, $avx512:expr) => {
        match $isa {
            Isa::Scalar => $scalar,
            // SAFETY: an `Isa::Avx2` value only exists when `active_isa`
            // detected AVX2 on this CPU (module-level safety contract).
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { $avx2 },
            // SAFETY: an `Isa::Avx512` value only exists when `active_isa`
            // detected AVX-512F on this CPU (module-level safety contract).
            #[cfg(all(target_arch = "x86_64", rtac_avx512))]
            Isa::Avx512 => unsafe { $avx512 },
            #[cfg(all(target_arch = "x86_64", not(rtac_avx512)))]
            Isa::Avx512 => $scalar,
            #[cfg(not(target_arch = "x86_64"))]
            _ => $scalar,
        }
    };
}

/// Multi-row support intersection: for each set bit `i` of `mask`,
/// decide whether row `i` of `rows` (`row_words` words per row, up to 64
/// rows) intersects `dom`, and return the mask of rows that do.
///
/// This is one arc's worth of support tests for one 64-value window of
/// the revised variable's domain: `mask` holds the values still alive,
/// `rows` their relation rows (consecutive values ⇒ consecutive rows in
/// the packed buffer), `dom` the witness variable's current domain row.
pub fn supported_mask(isa: Isa, mask: u64, rows: &[u64], row_words: usize, dom: &[u64]) -> u64 {
    debug_assert!(row_words > 0 && rows.len() % row_words == 0);
    debug_assert!(rows.len() / row_words <= 64);
    debug_assert!({
        let k = rows.len() / row_words;
        k >= 64 || mask >> k == 0
    });
    debug_assert!(dom.len() >= row_words);
    if mask == 0 {
        return 0;
    }
    dispatch!(
        isa,
        scalar::supported_mask(mask, rows, row_words, dom),
        avx2::supported_mask(mask, rows, row_words, dom),
        avx512::supported_mask(mask, rows, row_words, dom)
    )
}

/// Clear every word of `dst` (bulk row clearing, e.g. `assign`).
pub fn zero_words(isa: Isa, dst: &mut [u64]) {
    dispatch!(isa, scalar::zero_words(dst), avx2::zero_words(dst), avx512::zero_words(dst))
}

/// `dst |= src`, word-wise (bitset merges at sweep barriers).
pub fn or_words(isa: Isa, dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len());
    dispatch!(
        isa,
        scalar::or_words(dst, src),
        avx2::or_words(dst, src),
        avx512::or_words(dst, src)
    )
}

/// Fused changed/wipeout detection over one domain row: one pass yields
/// both `cur != next` and `next == 0`.
pub fn row_delta(isa: Isa, cur: &[u64], next: &[u64]) -> RowDelta {
    debug_assert_eq!(cur.len(), next.len());
    dispatch!(
        isa,
        scalar::row_delta(cur, next),
        avx2::row_delta(cur, next),
        avx512::row_delta(cur, next)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitset::{tail_mask, words_for};
    use crate::util::quickcheck::forall;
    use crate::util::rng::Rng;

    /// Widths that straddle word-lane boundaries, per the bit-identity
    /// contract, plus a few odd ones.
    const WIDTHS: &[usize] = &[1, 7, 63, 64, 65, 127, 128, 200];

    fn random_words(rng: &mut Rng, len_bits: usize) -> Vec<u64> {
        let mut v: Vec<u64> = (0..words_for(len_bits)).map(|_| rng.next_u64()).collect();
        if let Some(last) = v.last_mut() {
            *last &= tail_mask(len_bits);
        }
        v
    }

    #[test]
    fn isa_name_covers_all_variants() {
        assert_eq!(isa_name(Isa::Scalar), "scalar");
        assert_eq!(isa_name(Isa::Avx2), "avx2");
        assert_eq!(isa_name(Isa::Avx512), "avx512");
    }

    #[test]
    fn forced_scalar_toggles_active_isa() {
        let prior = forced_scalar();
        set_forced_scalar(true);
        assert_eq!(active_isa(), Isa::Scalar);
        set_forced_scalar(prior);
        assert_eq!(forced_scalar(), prior);
    }

    #[test]
    fn supported_mask_matches_scalar_on_single_word_rows() {
        let isa = detected_isa();
        forall("simd-supported-1w", 0x51D1, 64, |rng: &mut Rng| {
            let n_rows = 1 + rng.gen_range(64);
            let rows: Vec<u64> = (0..n_rows).map(|_| rng.next_u64()).collect();
            let dom = [rng.next_u64()];
            let mask = rng.next_u64() & tail_mask(n_rows);
            let got = supported_mask(isa, mask, &rows, 1, &dom);
            let want = scalar::supported_mask(mask, &rows, 1, &dom);
            if got != want {
                return Err(format!("{n_rows} rows: {got:#x} != {want:#x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn supported_mask_matches_scalar_on_wide_rows() {
        let isa = detected_isa();
        forall("simd-supported-wide", 0x51D2, 48, |rng: &mut Rng| {
            let width = WIDTHS[rng.gen_range(WIDTHS.len())];
            let rw = words_for(width);
            let n_rows = 1 + rng.gen_range(32);
            let mut rows = Vec::with_capacity(n_rows * rw);
            for _ in 0..n_rows {
                rows.extend(random_words(rng, width));
            }
            // sparse domain so both outcomes occur
            let mut dom = random_words(rng, width);
            for w in dom.iter_mut() {
                *w &= rng.next_u64() & rng.next_u64();
            }
            let mask = rng.next_u64() & tail_mask(n_rows);
            let got = supported_mask(isa, mask, &rows, rw, &dom);
            let want = scalar::supported_mask(mask, &rows, rw, &dom);
            if got != want {
                return Err(format!("width {width}, {n_rows} rows: {got:#x} != {want:#x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn zero_and_or_match_scalar_at_lane_boundaries() {
        let isa = detected_isa();
        for &width in WIDTHS {
            forall(&format!("simd-zero-or-{width}"), 0x51D3 + width as u64, 8, |rng| {
                let src = random_words(rng, width);
                let base = random_words(rng, width);

                let mut a = base.clone();
                let mut b = base.clone();
                or_words(isa, &mut a, &src);
                scalar::or_words(&mut b, &src);
                if a != b {
                    return Err(format!("or_words diverged at width {width}"));
                }

                zero_words(isa, &mut a);
                scalar::zero_words(&mut b);
                if a != b || a.iter().any(|&w| w != 0) {
                    return Err(format!("zero_words diverged at width {width}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn row_delta_matches_scalar_including_wipeouts() {
        let isa = detected_isa();
        forall("simd-row-delta", 0x51D4, 64, |rng: &mut Rng| {
            let width = WIDTHS[rng.gen_range(WIDTHS.len())];
            let cur = random_words(rng, width);
            let mut next = cur.clone();
            match rng.gen_range(4) {
                0 => {}                                     // unchanged
                1 => scalar::zero_words(&mut next),         // wiped (if cur nonzero)
                _ => {
                    for w in next.iter_mut() {
                        *w &= rng.next_u64();               // random removals
                    }
                }
            }
            let got = row_delta(isa, &cur, &next);
            let want = scalar::row_delta(&cur, &next);
            if got != want {
                return Err(format!("width {width}: {got:?} != {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn row_delta_edge_semantics() {
        for isa in [Isa::Scalar, detected_isa()] {
            let d = row_delta(isa, &[5, 0], &[5, 0]);
            assert!(!d.changed && !d.wiped, "{isa:?}: unchanged nonzero row");
            let d = row_delta(isa, &[0, 0], &[0, 0]);
            assert!(!d.changed && d.wiped, "{isa:?}: already-empty row");
            let d = row_delta(isa, &[1, 2], &[1, 0]);
            assert!(d.changed && !d.wiped, "{isa:?}: partial removal");
        }
    }
}
