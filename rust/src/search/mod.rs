//! MAC backtracking search (the paper's Algorithm 2), generic over the
//! AC engine, plus ordering heuristics and a parallel portfolio driver
//! that feeds the coordinator's batched tensor path.

pub mod heuristics;
pub mod parallel;
pub mod solver;

pub use heuristics::{ValOrder, VarHeuristic};
pub use solver::{SolveResult, SolveStats, Solver, SolverConfig};
