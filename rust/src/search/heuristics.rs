//! Variable / value ordering heuristics for the MAC solver
//! (paper Algorithm 2 line 8: `idx = heuristics()`).

use crate::core::{Problem, State, Val, VarId};
use crate::util::rng::Rng;

/// Variable selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VarHeuristic {
    /// First unassigned variable in index order.
    Lex,
    /// Smallest current domain (fail-first).
    MinDom,
    /// dom size / static degree.
    DomDeg,
    /// dom size / weighted degree; weights bump on wipeout (wdeg-lite —
    /// weights attach to the wiped variable rather than the culprit
    /// constraint, which our engine-agnostic Propagator API doesn't
    /// expose; see DESIGN.md).
    DomWdeg,
}

impl VarHeuristic {
    pub fn parse(s: &str) -> Result<VarHeuristic, String> {
        match s {
            "lex" => Ok(VarHeuristic::Lex),
            "mindom" => Ok(VarHeuristic::MinDom),
            "domdeg" => Ok(VarHeuristic::DomDeg),
            "domwdeg" => Ok(VarHeuristic::DomWdeg),
            other => Err(format!("unknown var heuristic {other:?}")),
        }
    }
}

/// Value ordering policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValOrder {
    /// Ascending value order.
    Lex,
    /// Deterministic shuffle from the solver seed (diversification for
    /// the random-CSP benches, mirroring the paper's random pick).
    Random,
}

impl ValOrder {
    pub fn parse(s: &str) -> Result<ValOrder, String> {
        match s {
            "lex" => Ok(ValOrder::Lex),
            "random" => Ok(ValOrder::Random),
            other => Err(format!("unknown value order {other:?}")),
        }
    }
}

/// Mutable heuristic state (wdeg weights).
pub struct HeuristicState {
    pub weights: Vec<u64>,
}

impl HeuristicState {
    pub fn new(problem: &Problem) -> HeuristicState {
        HeuristicState { weights: vec![1; problem.n_vars()] }
    }

    /// Bump the weight of a variable implicated in a wipeout.
    pub fn bump(&mut self, v: VarId) {
        self.weights[v] = self.weights[v].saturating_add(1);
    }
}

/// Pick the next variable to assign, or None if all are singletons.
pub fn select_var(
    h: VarHeuristic,
    problem: &Problem,
    state: &State,
    hs: &HeuristicState,
) -> Option<VarId> {
    let unassigned = (0..problem.n_vars()).filter(|&v| !state.is_singleton(v));
    match h {
        VarHeuristic::Lex => unassigned.min(),
        VarHeuristic::MinDom => unassigned.min_by_key(|&v| (state.dom_size(v), v)),
        VarHeuristic::DomDeg => unassigned.min_by_key(|&v| {
            let deg = problem.arcs_of(v).len().max(1);
            // compare dom/deg as rationals: dom_a/deg_a < dom_b/deg_b
            // avoided via cross-multiplication by mapping to a key tuple
            (state.dom_size(v) * 1_000_000 / deg, v)
        }),
        VarHeuristic::DomWdeg => unassigned.min_by_key(|&v| {
            let deg = problem.arcs_of(v).len() as u64;
            let w = (hs.weights[v] * deg.max(1)).max(1);
            ((state.dom_size(v) as u64 * 1_000_000 / w), v as u64)
        }),
    }
}

/// Order the live values of `v` for branching.
pub fn order_values(order: ValOrder, state: &State, v: VarId, rng: &mut Rng) -> Vec<Val> {
    let mut vals: Vec<Val> = state.dom(v).iter_ones().collect();
    if order == ValOrder::Random {
        rng.shuffle(&mut vals);
    }
    vals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Relation;

    fn star_problem() -> Problem {
        // var 0 is connected to everyone; others only to 0.
        let mut p = Problem::new("star", 4, 4);
        let r = Relation::from_fn(4, 4, |a, b| a != b);
        for v in 1..4 {
            p.add_constraint(0, v, r.clone());
        }
        p
    }

    #[test]
    fn lex_picks_lowest_unassigned() {
        let p = star_problem();
        let mut s = State::new(&p);
        let hs = HeuristicState::new(&p);
        assert_eq!(select_var(VarHeuristic::Lex, &p, &s, &hs), Some(0));
        s.assign(0, 0);
        assert_eq!(select_var(VarHeuristic::Lex, &p, &s, &hs), Some(1));
    }

    #[test]
    fn mindom_prefers_small_domains() {
        let p = star_problem();
        let mut s = State::new(&p);
        let hs = HeuristicState::new(&p);
        s.remove(2, 0);
        s.remove(2, 1);
        assert_eq!(select_var(VarHeuristic::MinDom, &p, &s, &hs), Some(2));
    }

    #[test]
    fn domdeg_prefers_high_degree_on_ties() {
        let p = star_problem();
        let s = State::new(&p);
        let hs = HeuristicState::new(&p);
        // all domains equal; var 0 has degree 3 vs 1 → smallest ratio
        assert_eq!(select_var(VarHeuristic::DomDeg, &p, &s, &hs), Some(0));
    }

    #[test]
    fn domwdeg_follows_bumps() {
        let p = star_problem();
        let s = State::new(&p);
        let mut hs = HeuristicState::new(&p);
        // without bumps, degree dominates → var 0
        assert_eq!(select_var(VarHeuristic::DomWdeg, &p, &s, &hs), Some(0));
        for _ in 0..10 {
            hs.bump(2);
        }
        assert_eq!(select_var(VarHeuristic::DomWdeg, &p, &s, &hs), Some(2));
    }

    #[test]
    fn all_assigned_returns_none() {
        let p = star_problem();
        let mut s = State::new(&p);
        for v in 0..4 {
            s.assign(v, v % 4);
        }
        let hs = HeuristicState::new(&p);
        assert_eq!(select_var(VarHeuristic::MinDom, &p, &s, &hs), None);
    }

    #[test]
    fn value_order_random_is_permutation() {
        let p = star_problem();
        let s = State::new(&p);
        let mut rng = Rng::new(5);
        let vals = order_values(ValOrder::Random, &s, 1, &mut rng);
        let mut sorted = vals.clone();
        sorted.sort();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        assert_eq!(order_values(ValOrder::Lex, &s, 1, &mut rng), vec![0, 1, 2, 3]);
    }
}
