//! MAC (maintaining arc consistency) backtracking search — the paper's
//! Algorithm 2 (`dfs` + `assign` + `tensorAC`), generic over the AC
//! engine so AC-3 and RTAC plug into the *same* search for Fig. 3's
//! apples-to-apples per-assignment timing.
//!
//! The engine is borrowed, not owned, and `reset` is called exactly
//! once per solve: the pool-backed engines (`rtac-par[-inc]`,
//! `sac-par`) keep their persistent worker threads across the reset
//! and across every per-node `enforce`, so a search amortises one
//! thread-pool spawn over its whole tree (see `exec/pool.rs`).

use std::time::{Duration, Instant};

use crate::ac::{Counters, Outcome, Propagator};
use crate::core::{Problem, State, Val, VarId};
use crate::search::heuristics::{
    order_values, select_var, HeuristicState, ValOrder, VarHeuristic,
};
use crate::util::rng::Rng;

/// Search configuration.
#[derive(Clone, Debug)]
pub struct SolverConfig {
    pub var_heuristic: VarHeuristic,
    pub val_order: ValOrder,
    /// Stop after this many assignments (paper benches: 50K). 0 = no cap.
    pub max_assignments: u64,
    /// Wall-clock cap. None = unbounded.
    pub time_limit: Option<Duration>,
    /// Seed for value-order shuffling.
    pub seed: u64,
    /// Record the duration of every AC call (Fig. 3 data).
    pub record_ac_times: bool,
    /// Cooperative cancellation (parallel portfolio: first finisher
    /// raises the flag, the rest unwind as `SolveResult::Limit`).
    pub stop: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            var_heuristic: VarHeuristic::MinDom,
            val_order: ValOrder::Lex,
            max_assignments: 0,
            time_limit: None,
            seed: 0,
            record_ac_times: false,
            stop: None,
        }
    }
}

/// Why the search stopped.
#[derive(Clone, Debug, PartialEq)]
pub enum SolveResult {
    /// A solution (one value per variable).
    Sat(Vec<Val>),
    /// Exhausted the space.
    Unsat,
    /// Hit max_assignments / time_limit first.
    Limit,
}

impl SolveResult {
    pub fn is_sat(&self) -> bool {
        matches!(self, SolveResult::Sat(_))
    }
}

/// Aggregated statistics of one solve run.
#[derive(Clone, Debug, Default)]
pub struct SolveStats {
    /// Assignments tried (paper's unit for Fig. 3 / Table 1 averaging).
    pub assignments: u64,
    pub backtracks: u64,
    /// Work counters summed over every AC call.
    pub ac: Counters,
    /// Number of AC calls (root + one per assignment).
    pub ac_calls: u64,
    /// Per-AC-call wall time in ms (only if record_ac_times).
    pub ac_times_ms: Vec<f64>,
    pub total_time: Duration,
}

impl SolveStats {
    /// Mean AC time per assignment in ms (Fig. 3's y-axis).
    pub fn mean_ac_ms(&self) -> f64 {
        if self.ac_times_ms.is_empty() {
            0.0
        } else {
            self.ac_times_ms.iter().sum::<f64>() / self.ac_times_ms.len() as f64
        }
    }

    /// Mean revisions per AC call (Table 1 `#Revision` column).
    pub fn revisions_per_call(&self) -> f64 {
        if self.ac_calls == 0 {
            0.0
        } else {
            self.ac.revisions as f64 / self.ac_calls as f64
        }
    }

    /// Mean recurrences per AC call (Table 1 `#Recurrence` column).
    pub fn recurrences_per_call(&self) -> f64 {
        if self.ac_calls == 0 {
            0.0
        } else {
            self.ac.recurrences as f64 / self.ac_calls as f64
        }
    }
}

/// The MAC solver.  Borrows the engine so callers can inspect/reuse it.
pub struct Solver<'e> {
    pub config: SolverConfig,
    engine: &'e mut dyn Propagator,
}

struct Search<'p, 'e> {
    problem: &'p Problem,
    config: SolverConfig,
    engine: &'e mut dyn Propagator,
    hs: HeuristicState,
    rng: Rng,
    stats: SolveStats,
    started: Instant,
    limit_hit: bool,
}

impl<'e> Solver<'e> {
    pub fn new(engine: &'e mut dyn Propagator, config: SolverConfig) -> Solver<'e> {
        Solver { config, engine }
    }

    /// Solve from full initial domains.
    pub fn solve(&mut self, problem: &Problem) -> (SolveResult, SolveStats) {
        self.solve_with_assignments(problem, &[])
    }

    /// Solve with unary givens applied first (e.g. sudoku clues).
    pub fn solve_with_assignments(
        &mut self,
        problem: &Problem,
        givens: &[(VarId, Val)],
    ) -> (SolveResult, SolveStats) {
        let started = Instant::now();
        self.engine.reset(problem);
        let mut search = Search {
            problem,
            config: self.config.clone(),
            engine: &mut *self.engine,
            hs: HeuristicState::new(problem),
            rng: Rng::new(self.config.seed),
            stats: SolveStats::default(),
            started,
            limit_hit: false,
        };
        let mut state = State::new(problem);
        for &(v, a) in givens {
            if !state.contains(v, a) {
                let mut stats = search.stats;
                stats.total_time = started.elapsed();
                return (SolveResult::Unsat, stats);
            }
            state.assign(v, a);
        }
        // Root enforcement over the whole network (Algorithm 2 line 3).
        let root = search.run_ac(&mut state, &[]);
        let result = if !root.is_consistent() {
            SolveResult::Unsat
        } else {
            match search.dfs(&mut state) {
                Some(solution) => SolveResult::Sat(solution),
                None if search.limit_hit => SolveResult::Limit,
                None => SolveResult::Unsat,
            }
        };
        let mut stats = search.stats;
        stats.total_time = started.elapsed();
        if let SolveResult::Sat(sol) = &result {
            debug_assert!(problem.satisfies(sol), "solver returned a non-solution");
        }
        (result, stats)
    }
}

impl<'p, 'e> Search<'p, 'e> {
    fn run_ac(&mut self, state: &mut State, touched: &[VarId]) -> Outcome {
        let t = Instant::now();
        let out = self.engine.enforce(self.problem, state, touched, &mut self.stats.ac);
        self.stats.ac_calls += 1;
        if self.config.record_ac_times {
            self.stats.ac_times_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        out
    }

    fn budget_exhausted(&mut self) -> bool {
        if self.config.max_assignments > 0 && self.stats.assignments >= self.config.max_assignments
        {
            self.limit_hit = true;
            return true;
        }
        if let Some(stop) = &self.config.stop {
            if stop.load(std::sync::atomic::Ordering::Relaxed) {
                self.limit_hit = true;
                return true;
            }
        }
        if let Some(limit) = self.config.time_limit {
            // check the clock only every few nodes to keep it cheap
            if self.stats.assignments % 64 == 0 && self.started.elapsed() > limit {
                self.limit_hit = true;
                return true;
            }
        }
        false
    }

    /// Depth-first MAC.  Returns a solution extension if one exists
    /// below this node.
    fn dfs(&mut self, state: &mut State) -> Option<Vec<Val>> {
        let var = match select_var(self.config.var_heuristic, self.problem, state, &self.hs) {
            None => {
                // every variable is a singleton: a solution
                return Some(
                    (0..self.problem.n_vars()).map(|v| state.value(v).unwrap()).collect(),
                );
            }
            Some(v) => v,
        };
        let vals = order_values(self.config.val_order, state, var, &mut self.rng);
        for a in vals {
            if self.budget_exhausted() {
                return None;
            }
            state.push_level();
            state.assign(var, a);
            self.stats.assignments += 1;
            let out = self.run_ac(state, &[var]);
            if out.is_consistent() {
                if let Some(sol) = self.dfs(state) {
                    return Some(sol);
                }
                if self.limit_hit {
                    state.pop_level();
                    return None;
                }
            } else if let Outcome::Wipeout(w) = out {
                self.hs.bump(w);
            }
            state.pop_level();
            self.stats.backtracks += 1;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::make_engine;
    use crate::gen::{pigeonhole, queens};
    use crate::gen::coloring::c5;
    use crate::gen::random::{random_csp, RandomSpec};

    fn solve_with(engine_name: &str, p: &Problem) -> (SolveResult, SolveStats) {
        let mut engine = make_engine(engine_name).unwrap();
        let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
        solver.solve(p)
    }

    #[test]
    fn queens_sat_sizes() {
        for n in [1, 4, 5, 6, 8] {
            let p = queens(n);
            let (r, _) = solve_with("ac3", &p);
            match r {
                SolveResult::Sat(sol) => assert!(p.satisfies(&sol), "n={n}"),
                other => panic!("queens({n}) -> {other:?}"),
            }
        }
    }

    #[test]
    fn queens_unsat_sizes() {
        for n in [2, 3] {
            let (r, _) = solve_with("ac3bit", &queens(n));
            assert_eq!(r, SolveResult::Unsat, "n={n}");
        }
    }

    #[test]
    fn pigeonhole_unsat_with_every_engine() {
        let p = pigeonhole(5, 4);
        for name in crate::ac::ALL_ENGINES {
            let (r, _) = solve_with(name, &p);
            assert_eq!(r, SolveResult::Unsat, "engine {name}");
        }
    }

    #[test]
    fn c5_colorable_with_3_not_2() {
        let (r3, _) = solve_with("rtac", &c5(3));
        assert!(r3.is_sat());
        let (r2, _) = solve_with("rtac", &c5(2));
        assert_eq!(r2, SolveResult::Unsat);
    }

    #[test]
    fn engines_agree_on_random_instances() {
        for seed in 0..6 {
            let p = random_csp(&RandomSpec::new(10, 5, 0.5, 0.45, seed));
            let verdicts: Vec<bool> = crate::ac::ALL_ENGINES
                .iter()
                .map(|e| solve_with(e, &p).0.is_sat())
                .collect();
            assert!(
                verdicts.iter().all(|&v| v == verdicts[0]),
                "seed {seed}: {verdicts:?} across {:?}",
                crate::ac::ALL_ENGINES
            );
        }
    }

    #[test]
    fn sat_solutions_verified_per_engine() {
        let p = random_csp(&RandomSpec::new(9, 6, 0.4, 0.3, 11));
        for name in crate::ac::ALL_ENGINES {
            let (r, _) = solve_with(name, &p);
            if let SolveResult::Sat(sol) = r {
                assert!(p.satisfies(&sol), "engine {name}");
            }
        }
    }

    #[test]
    fn pooled_engines_reused_across_search_nodes() {
        // One persistent pool serving every enforce of a search: the
        // verdicts (and for SAT, the solutions) must match the
        // sequential engines, across many nodes and a mid-test problem
        // switch per engine instance.
        for name in ["rtac-par2", "rtac-par-inc2", "sac-par2"] {
            let p = queens(6);
            let mut engine = make_engine(name).unwrap();
            let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
            let (r, stats) = solver.solve(&p);
            match r {
                SolveResult::Sat(sol) => assert!(p.satisfies(&sol), "{name}"),
                other => panic!("{name}: queens(6) -> {other:?}"),
            }
            assert!(stats.ac_calls > 1, "{name}: pool must serve many nodes");
            // same engine (same pool), different problem
            let p2 = pigeonhole(5, 4);
            let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
            let (r2, _) = solver.solve(&p2);
            assert_eq!(r2, SolveResult::Unsat, "{name}");
        }
    }

    #[test]
    fn assignment_limit_respected() {
        let p = pigeonhole(9, 8); // big UNSAT tree
        let mut engine = make_engine("ac3bit").unwrap();
        let cfg = SolverConfig { max_assignments: 50, ..Default::default() };
        let mut solver = Solver::new(engine.as_mut(), cfg);
        let (r, stats) = solver.solve(&p);
        assert_eq!(r, SolveResult::Limit);
        assert!(stats.assignments <= 51);
    }

    #[test]
    fn givens_respected() {
        let p = queens(6);
        let mut engine = make_engine("ac3").unwrap();
        let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
        let (r, _) = solver.solve_with_assignments(&p, &[(0, 1)]);
        if let SolveResult::Sat(sol) = r {
            assert_eq!(sol[0], 1);
            assert!(p.satisfies(&sol));
        } else {
            panic!("queens(6) with given col0=1 should be SAT");
        }
    }

    #[test]
    fn contradictory_given_is_unsat() {
        let p = queens(5);
        let mut engine = make_engine("ac3").unwrap();
        let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
        // two givens attacking each other
        let (r, _) = solver.solve_with_assignments(&p, &[(0, 0), (1, 0)]);
        assert_eq!(r, SolveResult::Unsat);
    }

    #[test]
    fn stats_populated() {
        let p = queens(6);
        let mut engine = make_engine("rtac").unwrap();
        let cfg = SolverConfig { record_ac_times: true, ..Default::default() };
        let mut solver = Solver::new(engine.as_mut(), cfg);
        let (_, stats) = solver.solve(&p);
        assert!(stats.assignments > 0);
        assert!(stats.ac_calls as usize == stats.ac_times_ms.len());
        assert!(stats.ac.recurrences > 0);
        assert!(stats.recurrences_per_call() >= 1.0);
        assert!(stats.mean_ac_ms() >= 0.0);
    }

    #[test]
    fn heuristics_all_solve_queens8() {
        for h in ["lex", "mindom", "domdeg", "domwdeg"] {
            let p = queens(8);
            let mut engine = make_engine("ac3bit").unwrap();
            let cfg = SolverConfig {
                var_heuristic: VarHeuristic::parse(h).unwrap(),
                ..Default::default()
            };
            let mut solver = Solver::new(engine.as_mut(), cfg);
            let (r, _) = solver.solve(&p);
            assert!(r.is_sat(), "heuristic {h}");
        }
    }

    #[test]
    fn sudoku_solves() {
        let (p, givens) = crate::gen::sudoku_from_givens(&format!(
            "53..7....6..195....98....6.8...6...34..8.3..17...2...6.6....28....419..5....8..79{}",
            ""
        ))
        .unwrap();
        let mut engine = make_engine("ac3bit").unwrap();
        let mut solver = Solver::new(engine.as_mut(), SolverConfig::default());
        let (r, _) = solver.solve_with_assignments(&p, &givens);
        match r {
            SolveResult::Sat(sol) => {
                assert!(p.satisfies(&sol));
                // givens preserved
                for (c, v) in givens {
                    assert_eq!(sol[c], v);
                }
            }
            other => panic!("sudoku -> {other:?}"),
        }
    }
}
