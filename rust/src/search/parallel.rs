//! Parallel portfolio MAC search over a shared coordinator session.
//!
//! The first branching variable's values are partitioned across K worker
//! threads ([`split_values`]); each worker runs the standard MAC solver
//! on its sub-space with a propagator chosen by [`WorkerEngine`]:
//!
//! * [`WorkerEngine::Tensor`] (the default, [`solve_parallel`]) — a
//!   [`TensorEngine`] per worker, so every AC call flows through the
//!   coordinator and coalesces with the other workers' calls into
//!   batched XLA executions.  Each worker attaches its own session
//!   client and ships **base-once-then-row-diffs**: consecutive MAC
//!   nodes differ in few rows, and the per-client base slots
//!   (`coordinator::service`) keep concurrent workers' deltas from
//!   invalidating each other.  When `k` exceeds the session's
//!   `base_slots` cap (so the slots would thrash), the workers ship
//!   full planes instead — decided once in [`solve_parallel_with`].
//! * [`WorkerEngine::TensorFull`] — the same engine shipping full
//!   planes every call; the upload-volume baseline the search-delta
//!   bench cell compares against.
//! * [`WorkerEngine::MixedSac`] — a
//!   [`crate::ac::sac::MixedProbeBackend`]-backed SAC engine per
//!   worker: stronger (singleton) propagation whose probe rounds are
//!   split between each worker's CPU pool and the shared session by
//!   the mixed cost model, the tensor share shipped in delta form on
//!   the worker's own session client.
//!
//! First SAT answer wins (cooperative stop flag); if every worker
//! exhausts its slice, the instance is UNSAT.
//!
//! **Degradation** (§Supervision & recovery): when a worker's tensor
//! engine fails — the session timed out, went moribund after its
//! restart budget, or died outright — the worker swaps in a CPU
//! propagator ([`RtacNative`]) ONCE and re-runs the value whose attempt
//! was poisoned (its wipeouts were synthetic, so that attempt's verdict
//! is discarded, never merged).  Only a second failure poisons the
//! worker, and a poisoned worker without a SAT answer fails the whole
//! run — a verdict is never fabricated from unexplored subtrees.
//!
//! This is the system story of the paper's GPU pitch: one resident
//! constraint tensor, many in-flight domain planes — and, per client,
//! mostly *rows* of planes on the wire.
//!
//! ```
//! use rtac::search::parallel::{split_values, WorkerEngine};
//!
//! // 5 values of the split variable, raced by 2 workers
//! assert_eq!(split_values(5, 2), vec![vec![0, 2, 4], vec![1, 3]]);
//! // engine selection is data, so `rtac serve --worker-engine` and the
//! // bench cells pick per-worker propagators without new entry points
//! assert_ne!(WorkerEngine::Tensor, WorkerEngine::TensorFull);
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use crate::ac::rtac::RtacNative;
use crate::ac::sac::{MixedProbeBackend, SacParallel};
use crate::ac::Propagator;
use crate::coordinator::{Coordinator, Handle, TensorEngine};
use crate::core::{Problem, Val, VarId};
use crate::search::solver::{SolveResult, SolveStats, Solver, SolverConfig};

/// Which propagator each portfolio worker runs on the shared session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerEngine {
    /// AC through the session ([`TensorEngine`]), shipping per-node
    /// row diffs against a per-worker base slot (the default).
    Tensor,
    /// AC through the session shipping full planes — the upload-volume
    /// baseline.
    TensorFull,
    /// Batched SAC with mixed CPU/tensor probe scheduling
    /// (`sac-mixed`): `cpu_workers` pool threads per search worker
    /// (0 = auto), `probe_batch` tensor probes per round (0 = auto).
    MixedSac { cpu_workers: usize, probe_batch: usize },
}

/// Result of a parallel run.
#[derive(Debug)]
pub struct ParallelOutcome {
    pub result: SolveResult,
    /// Per-worker stats, indexed by worker id.
    pub worker_stats: Vec<SolveStats>,
    /// Which worker found the solution (if SAT).
    pub winner: Option<usize>,
}

/// Partition `d` values of the split variable round-robin across `k`
/// workers (worker `w` takes values `w, w + k, w + 2k, …`).  Slices may
/// be empty when `k > d`; concatenated and sorted they cover exactly
/// `0..d`.
///
/// ```
/// use rtac::search::parallel::split_values;
/// assert_eq!(split_values(4, 4), vec![vec![0], vec![1], vec![2], vec![3]]);
/// assert_eq!(split_values(2, 3), vec![vec![0], vec![1], vec![]]);
/// ```
pub fn split_values(d: usize, k: usize) -> Vec<Vec<Val>> {
    let mut slices: Vec<Vec<Val>> = vec![Vec::new(); k];
    for a in 0..d {
        slices[a % k].push(a);
    }
    slices
}

/// Fold one attempt's stats into a worker's running totals.
fn merge_stats(into: &mut SolveStats, s: SolveStats) {
    into.assignments += s.assignments;
    into.backtracks += s.backtracks;
    into.ac_calls += s.ac_calls;
    into.ac.add(&s.ac);
    into.ac_times_ms.extend(s.ac_times_ms);
}

/// Split variable `split_var`'s values round-robin across `k` workers
/// and race them on the shared `coordinator` session with
/// [`WorkerEngine::Tensor`] propagators.
pub fn solve_parallel(
    problem: &Problem,
    coordinator: &Coordinator,
    base_config: &SolverConfig,
    split_var: VarId,
    k: usize,
) -> Result<ParallelOutcome> {
    solve_parallel_with(
        problem,
        &coordinator.handle(),
        base_config,
        split_var,
        k,
        WorkerEngine::Tensor,
    )
}

/// [`solve_parallel`] with an explicit per-worker propagator choice,
/// over any session [`Handle`] — a live [`Coordinator`]'s, or a
/// protocol-compatible stand-in (the offline e2e tests drive this with
/// a CPU-reference executor).
pub fn solve_parallel_with(
    problem: &Problem,
    handle: &Handle,
    base_config: &SolverConfig,
    split_var: VarId,
    k: usize,
    engine_kind: WorkerEngine,
) -> Result<ParallelOutcome> {
    assert!(k >= 1);
    // Resolve the mixed engine's auto pool size HERE, where k is known:
    // each search worker gets its own probe pool, so auto-sizing each
    // pool to the full machine would oversubscribe it k-fold (k search
    // threads x k·cores probe threads) and skew the cost model's CPU
    // EWMA with thrashing.  Share the cores across workers instead.
    let engine_kind = match engine_kind {
        WorkerEngine::MixedSac { cpu_workers: 0, probe_batch } => {
            let cores =
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
            WorkerEngine::MixedSac { cpu_workers: (cores / k).max(1), probe_batch }
        }
        other => other,
    };
    // Delta-shipping engines attach one session client each, and a
    // client without a resident base slot thrashes the executor's LRU
    // map (every node: stale drop + full re-upload — strictly worse
    // than full planes, and able to exhaust the retry bound).  When the
    // session's cap cannot hold one slot per worker, ship full planes
    // instead — decided HERE, the shared layer, so every caller is
    // protected, not just `rtac serve` (which additionally auto-sizes
    // its default `--base-slots` up to `--workers`).
    let use_delta = k <= handle.base_slots;
    if !use_delta && !matches!(engine_kind, WorkerEngine::TensorFull) {
        eprintln!(
            "solve_parallel: {k} delta-shipping workers exceed the session's {} base \
             slot(s); shipping full planes instead (raise BatchPolicy::base_slots to \
             keep per-node deltas)",
            handle.base_slots
        );
    }
    let slices = split_values(problem.dom_size(split_var), k);

    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<(usize, SolveResult, SolveStats, Option<String>)>();

    // lint:allow(thread-placement): portfolio search workers live for the
    // whole solve, not per-sweep — a WorkerPool would add a second barrier
    // layer for no reuse (each worker runs one long solve, then exits).
    std::thread::scope(|scope| {
        for (wid, slice) in slices.into_iter().enumerate() {
            let handle = handle.clone();
            let stop = stop.clone();
            let tx = tx.clone();
            let mut config = base_config.clone();
            config.stop = Some(stop.clone());
            config.seed = base_config.seed.wrapping_add(wid as u64);
            let problem = &*problem;
            scope.spawn(move || {
                // one engine per worker: the solver resets it per value,
                // and the pool-backed engines keep their threads across
                // resets (the persistent-runtime amortisation).  Each
                // delta-shipping engine attaches its own session client,
                // so per-client base slots keep the workers' delta
                // chains independent.
                let mut engine: Box<dyn Propagator> = match engine_kind {
                    WorkerEngine::Tensor if use_delta => {
                        Box::new(TensorEngine::new(handle.clone()))
                    }
                    WorkerEngine::Tensor | WorkerEngine::TensorFull => {
                        Box::new(TensorEngine::full_plane(handle.clone()))
                    }
                    WorkerEngine::MixedSac { cpu_workers, probe_batch } => {
                        let backend = if use_delta {
                            MixedProbeBackend::with_tensor_delta(
                                cpu_workers,
                                handle.clone(),
                                probe_batch,
                            )
                        } else {
                            MixedProbeBackend::with_tensor(
                                cpu_workers,
                                handle.clone(),
                                probe_batch,
                            )
                        };
                        Box::new(SacParallel::with_backend(Box::new(backend)))
                    }
                };
                let mut merged_stats = SolveStats::default();
                let mut outcome = SolveResult::Unsat;
                let mut failure: Option<String> = None;
                let mut degraded = false;
                for a in slice {
                    if stop.load(Ordering::Relaxed) {
                        outcome = SolveResult::Limit;
                        break;
                    }
                    let mut solver = Solver::new(engine.as_mut(), config.clone());
                    let (mut r, s) = solver.solve_with_assignments(problem, &[(split_var, a)]);
                    merge_stats(&mut merged_stats, s);
                    if let Some(e) = engine.failure() {
                        // poisoned engine: its wipeouts were synthetic,
                        // so this attempt's verdict is NOT usable.
                        // Degrade ONCE to the CPU propagator and re-run
                        // this value (the tensor session is gone —
                        // timed out, moribund, or dead — but the CPU
                        // answers the same questions); a second failure
                        // poisons the worker for real.
                        if degraded {
                            failure = Some(e.to_string());
                            break;
                        }
                        eprintln!(
                            "solve_parallel: worker {wid} lost its tensor engine ({e}); \
                             degrading to the CPU propagator and re-running value {a}"
                        );
                        degraded = true;
                        engine = Box::new(RtacNative::incremental());
                        let mut solver = Solver::new(engine.as_mut(), config.clone());
                        let (r2, s2) =
                            solver.solve_with_assignments(problem, &[(split_var, a)]);
                        merge_stats(&mut merged_stats, s2);
                        r = r2;
                        if let Some(e) = engine.failure() {
                            failure = Some(e.to_string());
                            break;
                        }
                    }
                    match r {
                        SolveResult::Sat(sol) => {
                            stop.store(true, Ordering::Relaxed);
                            outcome = SolveResult::Sat(sol);
                            break;
                        }
                        SolveResult::Limit => {
                            outcome = SolveResult::Limit;
                            // keep scanning remaining values unless stopped
                        }
                        SolveResult::Unsat => {}
                    }
                }
                let _ = tx.send((wid, outcome, merged_stats, failure));
            });
        }
        drop(tx);

        let mut worker_stats: Vec<SolveStats> = vec![SolveStats::default(); k];
        let mut winner = None;
        let mut best: Option<SolveResult> = None;
        let mut any_limit = false;
        let mut failures: Vec<(usize, String)> = Vec::new();
        for (wid, r, s, failure) in rx.iter() {
            worker_stats[wid] = s;
            if let Some(e) = failure {
                failures.push((wid, e));
            }
            match r {
                SolveResult::Sat(sol) => {
                    if !matches!(best, Some(SolveResult::Sat(_))) {
                        best = Some(SolveResult::Sat(sol));
                        winner = Some(wid);
                    }
                }
                SolveResult::Limit => any_limit = true,
                SolveResult::Unsat => {}
            }
        }
        let result = match best {
            // a found solution is independently verifiable (callers
            // assert `problem.satisfies`), so it stands even if another
            // worker's engine was poisoned
            Some(sat) => sat,
            // without a solution, a poisoned worker (one that failed
            // even after its one-shot CPU degradation) means an
            // unexplored subtree: UNSAT/LIMIT would be a wrong verdict
            // — error out
            None if !failures.is_empty() => {
                let (wid, e) = &failures[0];
                return Err(anyhow!(
                    "{} search worker(s) lost their coordinator session \
                     (first: worker {wid}: {e}) — verdict unavailable",
                    failures.len()
                ));
            }
            None if any_limit => SolveResult::Limit,
            None => SolveResult::Unsat,
        };
        Ok(ParallelOutcome { result, worker_stats, winner })
    })
}
