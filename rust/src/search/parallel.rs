//! Parallel portfolio MAC search over a shared coordinator session.
//!
//! The first branching variable's values are partitioned across K worker
//! threads; each worker runs the standard MAC solver on its sub-space
//! with a [`TensorEngine`], so every AC call flows through the
//! coordinator and coalesces with the other workers' calls into batched
//! XLA executions.  First SAT answer wins (cooperative stop flag); if
//! every worker exhausts its slice, the instance is UNSAT.
//!
//! This is the system story of the paper's GPU pitch: one resident
//! constraint tensor, many in-flight domain planes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use crate::coordinator::{Coordinator, TensorEngine};
use crate::core::{Problem, Val, VarId};
use crate::search::solver::{SolveResult, SolveStats, Solver, SolverConfig};

/// Result of a parallel run.
#[derive(Debug)]
pub struct ParallelOutcome {
    pub result: SolveResult,
    /// Per-worker stats, indexed by worker id.
    pub worker_stats: Vec<SolveStats>,
    /// Which worker found the solution (if SAT).
    pub winner: Option<usize>,
}

/// Split variable `split_var`'s values round-robin across `k` workers
/// and race them on the shared `coordinator` session.
pub fn solve_parallel(
    problem: &Problem,
    coordinator: &Coordinator,
    base_config: &SolverConfig,
    split_var: VarId,
    k: usize,
) -> Result<ParallelOutcome> {
    assert!(k >= 1);
    let d = problem.dom_size(split_var);
    let mut slices: Vec<Vec<Val>> = vec![Vec::new(); k];
    for a in 0..d {
        slices[a % k].push(a);
    }

    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<(usize, SolveResult, SolveStats, Option<String>)>();

    std::thread::scope(|scope| {
        for (wid, slice) in slices.into_iter().enumerate() {
            let handle = coordinator.handle();
            let stop = stop.clone();
            let tx = tx.clone();
            let mut config = base_config.clone();
            config.stop = Some(stop.clone());
            config.seed = base_config.seed.wrapping_add(wid as u64);
            let problem = &*problem;
            scope.spawn(move || {
                let mut merged_stats = SolveStats::default();
                let mut outcome = SolveResult::Unsat;
                let mut failure: Option<String> = None;
                for a in slice {
                    if stop.load(Ordering::Relaxed) {
                        outcome = SolveResult::Limit;
                        break;
                    }
                    let mut engine = TensorEngine::new(handle.clone());
                    let mut solver = Solver::new(&mut engine, config.clone());
                    let (r, s) = solver.solve_with_assignments(problem, &[(split_var, a)]);
                    merged_stats.assignments += s.assignments;
                    merged_stats.backtracks += s.backtracks;
                    merged_stats.ac_calls += s.ac_calls;
                    merged_stats.ac.add(&s.ac);
                    merged_stats.ac_times_ms.extend(s.ac_times_ms);
                    if let Some(e) = engine.failed.take() {
                        // poisoned engine: its wipeouts were synthetic,
                        // so this subtree's Unsat is NOT a verdict
                        failure = Some(e);
                        break;
                    }
                    match r {
                        SolveResult::Sat(sol) => {
                            stop.store(true, Ordering::Relaxed);
                            outcome = SolveResult::Sat(sol);
                            break;
                        }
                        SolveResult::Limit => {
                            outcome = SolveResult::Limit;
                            // keep scanning remaining values unless stopped
                        }
                        SolveResult::Unsat => {}
                    }
                }
                let _ = tx.send((wid, outcome, merged_stats, failure));
            });
        }
        drop(tx);

        let mut worker_stats: Vec<SolveStats> = vec![SolveStats::default(); k];
        let mut winner = None;
        let mut best: Option<SolveResult> = None;
        let mut any_limit = false;
        let mut failures: Vec<(usize, String)> = Vec::new();
        for (wid, r, s, failure) in rx.iter() {
            worker_stats[wid] = s;
            if let Some(e) = failure {
                failures.push((wid, e));
            }
            match r {
                SolveResult::Sat(sol) => {
                    if !matches!(best, Some(SolveResult::Sat(_))) {
                        best = Some(SolveResult::Sat(sol));
                        winner = Some(wid);
                    }
                }
                SolveResult::Limit => any_limit = true,
                SolveResult::Unsat => {}
            }
        }
        let result = match best {
            // a found solution is independently verifiable (callers
            // assert `problem.satisfies`), so it stands even if another
            // worker's engine was poisoned
            Some(sat) => sat,
            // without a solution, a poisoned worker means an unexplored
            // subtree: UNSAT/LIMIT would be a wrong verdict — error out
            None if !failures.is_empty() => {
                let (wid, e) = &failures[0];
                return Err(anyhow!(
                    "{} search worker(s) lost their coordinator session \
                     (first: worker {wid}: {e}) — verdict unavailable",
                    failures.len()
                ));
            }
            None if any_limit => SolveResult::Limit,
            None => SolveResult::Unsat,
        };
        Ok(ParallelOutcome { result, worker_stats, winner })
    })
}
