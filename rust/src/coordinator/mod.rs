//! The L3 coordination system: a per-problem serving session that
//! dynamically batches arc-consistency requests from concurrent clients
//! (parallel search workers, the `serve` CLI loop, benches) into fused
//! XLA executions — router + dynamic batcher + executor, vLLM-style but
//! for constraint propagation.

pub mod engine;
pub mod metrics;
pub mod service;

pub use engine::TensorEngine;
pub use metrics::{Metrics, MetricsSnapshot};
pub use service::{BatchPolicy, Coordinator, CoordinatorConfig, Handle, Response};
