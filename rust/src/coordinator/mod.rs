//! The L3 coordination system: a per-problem serving session that
//! dynamically batches arc-consistency requests from concurrent clients
//! (parallel search workers, the `serve` CLI loop, benches) into fused
//! XLA executions — router + dynamic batcher + executor, vLLM-style but
//! for constraint propagation.
//!
//! Three pieces:
//!
//! * [`service`] — the [`Coordinator`] session itself: the startup
//!   fence, the dynamic batcher (fixed or adaptive [`BatchPolicy`]),
//!   the per-client delta base slots (capped + LRU, see
//!   `BatchPolicy::base_slots`), and the cloneable client [`Handle`]
//!   (client ids via [`Handle::attach`]; full planes via
//!   [`Handle::submit`]/[`Handle::submit_batch`], probe-round deltas
//!   via [`Handle::upload_base`] + [`Handle::submit_batch_delta`],
//!   chained search-node deltas via [`Handle::submit_delta`]).  The
//!   wire protocol is documented end-to-end in `docs/PROTOCOL.md`.
//! * [`metrics`] — shared counters with the conservation invariant
//!   `requests == responses + dropped_requests` (aggregate and per
//!   client) and the upload-volume accounting the delta encoding is
//!   measured by.
//! * [`engine`] — [`TensorEngine`], the [`crate::ac::Propagator`] that
//!   routes a MAC solver's AC calls through a session (shipping
//!   base-once-then-row-diffs by default).
//! * [`retry`] — the shared [`RetryPolicy`] (bounded attempts,
//!   exponential backoff, transient-vs-fatal classification) behind
//!   every client-side recovery loop, and the executor-side supervision
//!   story's client-facing half: a restarted session answers retried
//!   calls, a moribund one fails them fatally.
//! * [`fixcache`] — the content-addressed fixpoint memo layer: a
//!   bounded LRU cache keyed by `(constraint fingerprint, input-plane
//!   fingerprint)` consulted before any enforcement actually runs —
//!   executor-side (a hit skips the fused execution and still counts
//!   as a normal response), in SAC probe rounds, and per fleet shard.
//!   Sound because the AC/SAC closure is unique; poisoned entries are
//!   detected by a fingerprint re-check and evicted, never served.
//!   `rtac serve --fixcache-entries` (0 disables).
//! * [`fleet`] — the scheduler tier above single sessions: a [`Fleet`]
//!   of N supervised shards with fingerprint-keyed session placement
//!   (rendezvous-stable, content-deduplicated), latency-budget
//!   admission control, per-client fairness on the batch path, and
//!   shard failover that re-places and re-hydrates a dead shard's
//!   sessions onto survivors.  `rtac serve --shards N` and
//!   `rtac loadgen` run on it.
//! * `chaos` (crate-internal) — the deterministic fault-injection
//!   harness: seeded `FaultPlan`s driving CPU-reference executors that
//!   speak the exact session wire protocol, including whole-shard
//!   kills for the fleet tier.
//!
//! ```
//! use rtac::coordinator::BatchPolicy;
//!
//! // an adaptive session policy: the executor derives its effective
//! // batching window from observed queue demand, capped by these knobs
//! let policy = BatchPolicy { adaptive: true, ..Default::default() };
//! assert!(policy.max_batch >= 1);
//! ```

pub(crate) mod chaos;
pub mod engine;
pub mod fixcache;
pub mod fleet;
pub mod metrics;
pub mod retry;
pub mod service;

pub use engine::TensorEngine;
pub use fixcache::{CachedFixpoint, FixCache, FixCacheStats};
pub use fleet::{Fleet, FleetClient, FleetPolicy};
pub use metrics::{ClientMetrics, Metrics, MetricsSnapshot};
pub use retry::{Retry, RetryPolicy};
pub use service::{
    BatchPolicy, ClientId, Coordinator, CoordinatorConfig, Handle, Response, StaleTracker,
};
