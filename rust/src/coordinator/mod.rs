//! The L3 coordination system: a per-problem serving session that
//! dynamically batches arc-consistency requests from concurrent clients
//! (parallel search workers, the `serve` CLI loop, benches) into fused
//! XLA executions — router + dynamic batcher + executor, vLLM-style but
//! for constraint propagation.
//!
//! Three pieces:
//!
//! * [`service`] — the [`Coordinator`] session itself: the startup
//!   fence, the dynamic batcher (fixed or adaptive [`BatchPolicy`]),
//!   the delta-probe base cache, and the cloneable client [`Handle`]
//!   (full planes via [`Handle::submit`]/[`Handle::submit_batch`],
//!   delta probes via [`Handle::upload_base`] +
//!   [`Handle::submit_batch_delta`]).
//! * [`metrics`] — shared counters with the session conservation
//!   invariant `requests == responses + dropped_requests` and the
//!   upload-volume accounting the delta encoding is measured by.
//! * [`engine`] — [`TensorEngine`], the [`crate::ac::Propagator`] that
//!   routes a MAC solver's AC calls through a session.
//!
//! ```
//! use rtac::coordinator::BatchPolicy;
//!
//! // an adaptive session policy: the executor derives its effective
//! // batching window from observed queue demand, capped by these knobs
//! let policy = BatchPolicy { adaptive: true, ..Default::default() };
//! assert!(policy.max_batch >= 1);
//! ```

pub mod engine;
pub mod metrics;
pub mod service;

pub use engine::TensorEngine;
pub use metrics::{Metrics, MetricsSnapshot};
pub use service::{BatchPolicy, Coordinator, CoordinatorConfig, Handle, Response};
