//! The session-wide retry policy (§Unified retry policy).
//!
//! Before this module, recovery logic lived in per-call-site ad-hoc
//! forms: a hard-coded *one-shot* stale-delta retry in the SAC probe
//! backend (`ac/sac.rs`), a magic-constant `0..3` re-upload loop in the
//! delta engine (`coordinator/engine.rs`), and nothing at all in the
//! mixed backend's tensor share.  [`RetryPolicy`] replaces all three
//! with one bounded-attempt, exponential-backoff loop plus an explicit
//! transient-vs-fatal classification ([`Retry`]) made *by the call
//! site*, which is the only place that can tell "my base slot was
//! evicted: re-upload and go again" from "the session is dead: stop".
//!
//! ```
//! use rtac::coordinator::{Retry, RetryPolicy};
//!
//! let policy = RetryPolicy::no_backoff(3);
//! let mut calls = 0;
//! let out: anyhow::Result<u32> = policy.run("demo op", |attempt| {
//!     calls += 1;
//!     if attempt < 2 {
//!         Err(Retry::Transient(anyhow::anyhow!("slot evicted")))
//!     } else {
//!         Ok(attempt)
//!     }
//! });
//! assert_eq!(out.unwrap(), 2);
//! assert_eq!(calls, 3, "attempts 0 and 1 were transient failures");
//! ```

use std::time::Duration;

use anyhow::Result;

/// A failed attempt, classified by the call site.
pub enum Retry {
    /// Worth another attempt within the budget: a stale/evicted base
    /// slot, a dropped request on a session that still answers, a
    /// mid-restart timeout.
    Transient(anyhow::Error),
    /// Retrying cannot help (the session is gone, the input is
    /// malformed): fail now, budget notwithstanding.
    Fatal(anyhow::Error),
}

/// Bounded attempts + exponential backoff + the caller's
/// transient-vs-fatal classification.  `Copy` on purpose: callers store
/// one on `self` and run `self.retry.run(|..| self.method(..))` without
/// a double borrow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (first try included).  Clamped to >= 1.
    pub max_attempts: u32,
    /// Sleep before attempt N+1 is `base_backoff * 2^N`, capped at
    /// `max_backoff`.  `Duration::ZERO` disables sleeping — the right
    /// setting when the "backoff" is itself a blocking round-trip
    /// through the executor (the stale-delta re-upload path).
    pub base_backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(100),
        }
    }
}

impl RetryPolicy {
    /// A policy of `max_attempts` immediate attempts (no sleeping) —
    /// for retries whose recovery action (a base re-upload, a fresh
    /// submission) already blocks on the executor.
    pub fn no_backoff(max_attempts: u32) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
        }
    }

    /// The backoff slept before attempt `next_attempt` (0-based; the
    /// first attempt never sleeps).
    pub fn backoff(&self, next_attempt: u32) -> Duration {
        if next_attempt == 0 || self.base_backoff.is_zero() {
            return Duration::ZERO;
        }
        self.base_backoff
            .saturating_mul(2u32.saturating_pow(next_attempt - 1))
            .min(self.max_backoff)
    }

    /// Run `op` until it succeeds, fails fatally, or the attempt budget
    /// is spent.  `op` receives the 0-based attempt number so call
    /// sites can vary the recovery action (attempt 0 = the cheap path,
    /// attempts >= 1 = re-upload and resubmit).  The last transient
    /// error is annotated with the spent budget — the "retry bound
    /// exhausted" diagnosis the old ad-hoc loops buried in per-site
    /// prose.
    pub fn run<T>(
        &self,
        what: &str,
        mut op: impl FnMut(u32) -> std::result::Result<T, Retry>,
    ) -> Result<T> {
        let attempts = self.max_attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            let pause = self.backoff(attempt);
            if !pause.is_zero() {
                std::thread::sleep(pause);
            }
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(Retry::Fatal(e)) => return Err(e),
                Err(Retry::Transient(e)) => last = Some(e),
            }
        }
        let e = last.expect("attempts >= 1, so at least one error was recorded");
        Err(e.context(format!(
            "{what}: retry budget exhausted after {attempts} attempt(s)"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn first_success_needs_one_attempt() {
        let mut calls = 0;
        let out: Result<u32> = RetryPolicy::default().run("op", |_| {
            calls += 1;
            Ok(7)
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls, 1);
    }

    #[test]
    fn transient_failures_spend_the_budget_then_surface_the_last_error() {
        let policy = RetryPolicy::no_backoff(3);
        let mut calls = 0;
        let out: Result<u32> = policy.run("re-upload base", |attempt| {
            calls += 1;
            Err(Retry::Transient(anyhow!("evicted on attempt {attempt}")))
        });
        assert_eq!(calls, 3);
        let msg = format!("{:#}", out.unwrap_err());
        assert!(msg.contains("retry budget exhausted after 3 attempt(s)"), "{msg}");
        assert!(msg.contains("re-upload base"), "{msg}");
        assert!(msg.contains("attempt 2"), "last transient error kept: {msg}");
    }

    #[test]
    fn fatal_failures_stop_immediately() {
        let policy = RetryPolicy::no_backoff(5);
        let mut calls = 0;
        let out: Result<u32> = policy.run("op", |_| {
            calls += 1;
            Err(Retry::Fatal(anyhow!("session is gone")))
        });
        assert_eq!(calls, 1, "fatal must not retry");
        let msg = format!("{:#}", out.unwrap_err());
        assert!(msg.contains("session is gone"), "{msg}");
        assert!(!msg.contains("retry budget"), "fatal keeps the raw error: {msg}");
    }

    #[test]
    fn recovery_on_a_later_attempt_succeeds() {
        let policy = RetryPolicy::no_backoff(4);
        let out: Result<u32> = policy.run("op", |attempt| {
            if attempt < 2 {
                Err(Retry::Transient(anyhow!("not yet")))
            } else {
                Ok(attempt)
            }
        });
        assert_eq!(out.unwrap(), 2);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let policy = RetryPolicy {
            max_attempts: 10,
            base_backoff: Duration::from_millis(4),
            max_backoff: Duration::from_millis(10),
        };
        assert_eq!(policy.backoff(0), Duration::ZERO, "first attempt never sleeps");
        assert_eq!(policy.backoff(1), Duration::from_millis(4));
        assert_eq!(policy.backoff(2), Duration::from_millis(8));
        assert_eq!(policy.backoff(3), Duration::from_millis(10), "capped");
        assert_eq!(policy.backoff(9), Duration::from_millis(10), "still capped");
        assert_eq!(RetryPolicy::no_backoff(3).backoff(2), Duration::ZERO);
    }

    #[test]
    fn zero_attempts_clamps_to_one() {
        let mut calls = 0;
        let out: Result<()> = RetryPolicy::no_backoff(0).run("op", |_| {
            calls += 1;
            Err(Retry::Transient(anyhow!("nope")))
        });
        assert!(out.is_err());
        assert_eq!(calls, 1);
    }
}
