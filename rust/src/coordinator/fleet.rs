//! The scheduler tier above single serving sessions: a [`Fleet`] owns
//! N supervised shards and places *sessions* — one per distinct
//! constraint network — across them by content fingerprint, with
//! admission control in front of every shard queue and failover when a
//! shard dies.
//!
//! # Placement
//!
//! A session is keyed by [`crate::ac::sac::problem_fingerprint`] — the
//! same content fingerprint that guards compiled-session reuse in the
//! SAC engines — so identical networks from different clients share
//! ONE session (one compiled artifact set, one base-slot map) on one
//! shard, while differing networks get disjoint sessions and can never
//! cross-invalidate each other's base slots.  The shard is chosen by
//! rendezvous (highest-random-weight) hashing over the *live* shards:
//! placement is deterministic, identical across fleet restarts, and
//! stable under membership change — removing one shard re-places only
//! that shard's sessions, every other key keeps its home.
//!
//! # Admission control
//!
//! With a latency budget configured ([`FleetPolicy::latency_budget`],
//! `rtac serve --latency-budget MS`), every enforcement call first
//! projects its completion latency from the target shard's queue depth
//! and its EWMA round latency: `ceil((outstanding + k) / max_batch) ×
//! ewma`.  A request whose projection blows the budget is **rejected
//! and counted** (`rejected_requests` — a named error,
//! [`ADMISSION_REJECTED`]), never silently shed and never answered
//! wrongly: the caller degrades to its CPU path exactly like it does
//! for a moribund session.  The batch path additionally enforces a
//! per-client fairness share so one greedy prober cannot starve the
//! other clients of a shard.
//!
//! # Failover
//!
//! Each shard carries a shared health flag; a chaos plan can kill a
//! whole shard mid-flight (`FaultPlan::kill_shard_at`), a session that
//! exhausts its restart budget marks its shard dead, and the load
//! harness can force a kill ([`Fleet::kill_shard`]).  The first client
//! to observe the death (or the forced kill itself) triggers failover:
//! every session homed on the dead shard re-places by rendezvous over
//! the survivors and **re-hydrates** there through the PR-6 replay
//! machinery — the fleet mirrors every client's last uploaded base
//! plane host-side, so the replacement incarnation starts with the
//! full slot map (`replayed_bases`) and chained-delta clients resume
//! with at worst one stale round.  Conservation
//! (`requests == responses + dropped_requests`) holds per shard ledger
//! AND fleet-aggregate across the move: the dying incarnation drains
//! its queue counting every drop, the replacement counts its own
//! traffic, and [`MetricsSnapshot::aggregate`] merges the ledgers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::ac::sac::problem_fingerprint;
use crate::coordinator::chaos::{chaos_reference_executor, FaultPlan, ShardHealth};
use crate::coordinator::fixcache::FixCache;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::service::{
    BatchPolicy, ClientId, Coordinator, CoordinatorConfig, Handle, Response,
};
use crate::core::Problem;
use crate::runtime::{Bucket, PlaneDelta};

/// Leading text of every admission-control rejection error — the
/// *named* drop cause clients match on ([`is_admission_rejected`]) to
/// distinguish "the fleet is over its latency budget, degrade to the
/// CPU path" from a dead session.
pub const ADMISSION_REJECTED: &str = "fleet admission rejected the request";

/// Is `e` an admission-control rejection ([`ADMISSION_REJECTED`])?
/// Rejected requests are counted (`rejected_requests`), carry no
/// verdict, and are not worth retrying against the same shard until
/// its queue drains — callers degrade to their CPU path instead.
pub fn is_admission_rejected(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains(ADMISSION_REJECTED)
}

/// Fleet-level policy: shard count, admission budget, and the
/// per-session knobs every shard's sessions inherit (the
/// [`BatchPolicy`] subset that matters to reference executors).
#[derive(Clone, Debug)]
pub struct FleetPolicy {
    /// Number of shards (supervised executor homes).  Must be >= 1.
    pub shards: usize,
    /// Admission-control latency budget (`--latency-budget`): reject a
    /// request when its projected completion latency exceeds this.
    /// `None` disables admission control — every request is queued.
    pub latency_budget: Option<Duration>,
    /// Per-session resident delta-base cap ([`BatchPolicy::base_slots`]).
    pub base_slots: usize,
    /// Per-request deadline ([`BatchPolicy::request_timeout`]).
    pub request_timeout: Duration,
    /// Per-session supervisor restart budget
    /// ([`BatchPolicy::max_restarts`]).
    pub max_restarts: u32,
    /// Fused-batch ceiling ([`BatchPolicy::max_batch`]) — the
    /// amortisation denominator of the admission-latency projection.
    pub max_batch: usize,
    /// Capacity of each shard's content-addressed fixpoint cache
    /// ([`BatchPolicy::fixcache_entries`], `rtac serve
    /// --fixcache-entries`).  The cache is **per shard, shared by every
    /// session incarnation homed there** — rendezvous-placed duplicate
    /// sessions warm each other, and a failover replacement spawned on
    /// a survivor inherits (and repopulates) the survivor's warm
    /// entries.  0 disables the memo layer fleet-wide.
    pub fixcache_entries: usize,
}

impl Default for FleetPolicy {
    fn default() -> FleetPolicy {
        let b = BatchPolicy::default();
        FleetPolicy {
            shards: 1,
            latency_budget: None,
            base_slots: b.base_slots,
            request_timeout: b.request_timeout,
            max_restarts: b.max_restarts,
            max_batch: b.max_batch,
            fixcache_entries: b.fixcache_entries,
        }
    }
}

/// splitmix64 finalizer — the avalanche behind rendezvous scoring.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Rendezvous (highest-random-weight) placement: the live shard with
/// the highest mixed score for `fp`.  Pure and deterministic, so the
/// same network lands on the same shard across fleets and restarts;
/// removing one shard re-places only the keys that scored it highest.
pub(crate) fn rendezvous_shard(fp: u64, alive: &[usize]) -> usize {
    assert!(!alive.is_empty(), "rendezvous over zero shards");
    *alive
        .iter()
        .max_by_key(|&&s| mix64(fp ^ mix64(s as u64 ^ 0x5851_F42D_4C95_7F2D)))
        .unwrap()
}

/// Projected completion latency (µs) of `k` more requests against a
/// shard with `outstanding` queued requests: the number of fused
/// rounds the queue needs at `max_batch`, times the observed EWMA
/// round latency.  The Berkholz propagation-depth bound is what makes
/// this well-posed: per-request propagation work — and so the round
/// latency — is bounded, not heavy-tailed.
pub(crate) fn admission_estimate_us(
    outstanding: u64,
    k: u64,
    max_batch: usize,
    ewma_round_us: u64,
) -> u64 {
    let rounds = (outstanding + k).div_ceil(max_batch.max(1) as u64);
    rounds.saturating_mul(ewma_round_us)
}

/// Per-client fairness share of a shard queue on the batch path: an
/// equal split of the projected depth across the clients currently in
/// flight, floored at `max_batch` so solo clients keep full fused
/// rounds.
pub(crate) fn fairness_cap(outstanding: u64, k: u64, active_clients: u64, max_batch: usize) -> u64 {
    (outstanding + k).div_ceil(active_clients.max(1)).max(max_batch as u64)
}

/// How a fleet spawns its per-session executors.
#[derive(Clone)]
enum Spawner {
    /// Fault-free CPU-reference executors (offline; `rtac loadgen`'s
    /// determinism oracle).
    Reference,
    /// Chaos reference executors: each session's fault plan is seeded
    /// from this fleet seed mixed with the session fingerprint.
    Chaos(u64),
    /// Production sessions ([`Coordinator::start`]) over compiled
    /// artifacts (`rtac serve --shards N`).
    Artifacts(CoordinatorConfig),
}

/// The thing that keeps a session incarnation's executor alive — and
/// the way to stop it once its handles are gone.
enum Keeper {
    Thread(JoinHandle<()>),
    Session(Coordinator),
}

impl Keeper {
    /// Stop the incarnation.  Thread keepers exit on their own once
    /// every handle clone is dropped (the caller guarantees that);
    /// production sessions shut down explicitly.
    fn stop(self) {
        match self {
            Keeper::Thread(j) => {
                let _ = j.join();
            }
            Keeper::Session(c) => c.shutdown(),
        }
    }
}

/// One shard: a placement home with a shared health flag, queue-depth
/// accounting for admission, and the metrics ledgers of every session
/// incarnation ever homed here (the per-shard conservation unit).
struct ShardState {
    health: ShardHealth,
    /// Set once by the failover that evacuated this shard.
    failed_over: AtomicBool,
    /// Requests currently in flight against this shard (all sessions).
    outstanding: AtomicU64,
    /// In-flight count per fleet client key — the fairness ledger.
    inflight: Mutex<HashMap<u64, u64>>,
    /// EWMA of observed fused-round latency, µs (0 = no signal yet).
    /// Racy read-modify-write by design: it is a latency *estimate*
    /// feeding admission, not an exact counter.
    ewma_round_us: AtomicU64,
    /// Metrics of every incarnation ever homed here.  A snapshot of
    /// this shard aggregates the whole list, so per-shard conservation
    /// spans restarts and outbound failovers.
    metrics: Mutex<Vec<Arc<Metrics>>>,
    /// The shard's fixpoint memo layer, shared by every session
    /// incarnation homed here ([`FleetPolicy::fixcache_entries`]; `None`
    /// when disabled).  Keys carry the session's constraint fingerprint,
    /// so co-homed sessions can never serve each other's planes — they
    /// only pool capacity, and failover replacements land warm.
    fixcache: Option<Arc<FixCache>>,
}

/// One placed session (one distinct constraint network): its current
/// incarnation (shard + handle + keeper) plus the host-side state that
/// survives incarnations — the base-plane mirror that re-hydrates the
/// replacement on failover.
struct SessionState {
    fp: u64,
    problem: Problem,
    bucket: Bucket,
    /// Bumped on every failover re-placement (observability only).
    generation: AtomicU64,
    inner: Mutex<SessionInner>,
}

struct SessionInner {
    shard: usize,
    handle: Handle,
    keeper: Option<Keeper>,
    /// fleet client key → the last base plane that client uploaded.
    /// Replayed into the replacement incarnation on failover (the
    /// fleet-level twin of the executor's own restart re-hydration).
    mirror: HashMap<u64, Vec<f32>>,
    /// fleet client key → this incarnation's session [`ClientId`].
    idmap: HashMap<u64, ClientId>,
}

struct FleetInner {
    policy: FleetPolicy,
    spawner: Spawner,
    shards: Vec<ShardState>,
    sessions: Mutex<HashMap<u64, Arc<SessionState>>>,
    /// Fleet-level ledger: rejections (which are counted requests —
    /// see [`Metrics::on_rejected`]), failovers, replaced sessions,
    /// replayed bases, and the shard count.
    fleet_metrics: Arc<Metrics>,
    /// Issues fleet-wide client keys (stable across failovers, unlike
    /// per-incarnation [`ClientId`]s).
    next_key: AtomicU64,
    /// Serialises failovers so concurrent observers of one death
    /// re-place each session exactly once.
    failover_lock: Mutex<()>,
    /// Keepers of replaced incarnations, joined at shutdown (their
    /// executors drain and exit as soon as their last handle drops —
    /// joining *during* failover would deadlock against in-flight
    /// calls still holding old handle clones).
    graveyard: Mutex<Vec<Keeper>>,
}

/// The scheduler tier: N supervised shards, fingerprint placement,
/// admission control, failover.  Cheap to clone (shared state);
/// clients come from [`Fleet::client`].
#[derive(Clone)]
pub struct Fleet {
    inner: Arc<FleetInner>,
}

impl Fleet {
    /// A fleet of fault-free CPU-reference executors — no compiled
    /// artifacts needed.  The determinism oracle `rtac loadgen`
    /// measures against.
    pub fn reference(policy: FleetPolicy) -> Result<Fleet> {
        Fleet::with_spawner(policy, Spawner::Reference)
    }

    /// A fleet of chaos reference executors: each session runs under a
    /// deterministic fault plan seeded from `seed` and the session's
    /// content fingerprint (crashes, hangs, failed streaks, base
    /// wipes, and whole-shard kills).  Replacement incarnations
    /// spawned by failover run fault-free — chaos keys initial
    /// placements, so a seeded run terminates instead of cascading
    /// kills across every survivor.
    pub fn chaos(policy: FleetPolicy, seed: u64) -> Result<Fleet> {
        Fleet::with_spawner(policy, Spawner::Chaos(seed))
    }

    /// A fleet of production sessions over compiled artifacts
    /// (`rtac serve --shards N`): every placed session is a full
    /// [`Coordinator`] stack with `config`'s artifacts and batching
    /// policy (the fleet policy's session knobs override the
    /// [`BatchPolicy`] ones so both tiers agree on deadlines).
    pub fn with_artifacts(policy: FleetPolicy, config: CoordinatorConfig) -> Result<Fleet> {
        let mut config = config;
        config.policy.base_slots = policy.base_slots;
        config.policy.request_timeout = policy.request_timeout;
        config.policy.max_restarts = policy.max_restarts;
        config.policy.max_batch = policy.max_batch;
        config.policy.fixcache_entries = policy.fixcache_entries;
        Fleet::with_spawner(policy, Spawner::Artifacts(config))
    }

    fn with_spawner(policy: FleetPolicy, spawner: Spawner) -> Result<Fleet> {
        if policy.shards == 0 {
            bail!("a fleet needs at least one shard (got --shards 0)");
        }
        let shards = (0..policy.shards)
            .map(|_| ShardState {
                health: ShardHealth::new(),
                failed_over: AtomicBool::new(false),
                outstanding: AtomicU64::new(0),
                inflight: Mutex::new(HashMap::new()),
                ewma_round_us: AtomicU64::new(0),
                metrics: Mutex::new(Vec::new()),
                fixcache: FixCache::shared(policy.fixcache_entries),
            })
            .collect();
        let fleet_metrics = Arc::new(Metrics::new());
        fleet_metrics.set_shards(policy.shards as u64);
        Ok(Fleet {
            inner: Arc::new(FleetInner {
                policy,
                spawner,
                shards,
                sessions: Mutex::new(HashMap::new()),
                fleet_metrics,
                next_key: AtomicU64::new(0),
                failover_lock: Mutex::new(()),
                graveyard: Mutex::new(Vec::new()),
            }),
        })
    }

    pub fn policy(&self) -> &FleetPolicy {
        &self.inner.policy
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Shards whose health flag still reads live.
    pub fn live_shards(&self) -> usize {
        self.inner.shards.iter().filter(|s| !s.health.is_dead()).count()
    }

    /// Attach a client for `problem`: places (or joins) the session
    /// keyed by the problem's content fingerprint.  Two callers with
    /// identical constraint content share one session; differing
    /// content gets disjoint sessions (and so disjoint base slots).
    pub fn client(&self, problem: &Problem) -> Result<FleetClient> {
        let fp = problem_fingerprint(problem);
        let session = {
            let mut map = self.inner.sessions.lock().unwrap();
            match map.get(&fp) {
                Some(s) => s.clone(),
                None => {
                    let alive = self.alive();
                    if alive.is_empty() {
                        bail!("fleet has no live shards left to place session {fp:016x} on");
                    }
                    let shard = rendezvous_shard(fp, &alive);
                    let bucket = Bucket { n: problem.n_vars(), d: problem.max_dom_size() };
                    let (handle, keeper) = self.spawn_incarnation(shard, problem, bucket, fp)?;
                    let s = Arc::new(SessionState {
                        fp,
                        problem: problem.clone(),
                        bucket,
                        generation: AtomicU64::new(0),
                        inner: Mutex::new(SessionInner {
                            shard,
                            handle,
                            keeper: Some(keeper),
                            mirror: HashMap::new(),
                            idmap: HashMap::new(),
                        }),
                    });
                    map.insert(fp, s.clone());
                    s
                }
            }
        };
        let key = self.inner.next_key.fetch_add(1, Ordering::Relaxed);
        Ok(FleetClient { fleet: self.clone(), session, key })
    }

    fn alive(&self) -> Vec<usize> {
        self.inner
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.health.is_dead())
            .map(|(i, _)| i)
            .collect()
    }

    /// Spawn one session incarnation on `shard` and register its
    /// metrics ledger with that shard.
    fn spawn_incarnation(
        &self,
        shard: usize,
        problem: &Problem,
        bucket: Bucket,
        fp: u64,
    ) -> Result<(Handle, Keeper)> {
        let p = &self.inner.policy;
        // every incarnation on this shard — initial placements AND
        // failover replacements — shares the shard's memo layer, so a
        // re-placed session repopulates (and benefits from) the
        // survivor's warm entries
        let fixcache = self.inner.shards[shard].fixcache.clone();
        let (handle, keeper) = match &self.inner.spawner {
            Spawner::Artifacts(config) => {
                let coord = Coordinator::start_with_cache(problem, config.clone(), fixcache)?;
                (coord.handle(), Keeper::Session(coord))
            }
            Spawner::Reference | Spawner::Chaos(_) => {
                let plan = match self.inner.spawner {
                    Spawner::Chaos(seed) => FaultPlan::seeded_fleet(mix64(seed ^ fp)),
                    _ => FaultPlan::default(),
                };
                let (handle, rx) =
                    Handle::for_reference_executor(bucket, p.base_slots, p.request_timeout);
                let join = chaos_reference_executor(
                    problem.clone(),
                    bucket,
                    p.base_slots,
                    p.request_timeout,
                    p.max_restarts,
                    plan,
                    self.inner.shards[shard].health.clone(),
                    fixcache,
                    rx,
                    handle.metrics.clone(),
                );
                (handle, Keeper::Thread(join))
            }
        };
        self.inner.shards[shard].metrics.lock().unwrap().push(handle.metrics.clone());
        Ok((handle, keeper))
    }

    /// Force-kill `shard` (the load harness's deterministic failover
    /// trigger) and evacuate its sessions.
    pub fn kill_shard(&self, shard: usize) {
        assert!(shard < self.inner.shards.len(), "no shard {shard}");
        self.inner.shards[shard].health.mark_dead();
        self.failover(shard);
    }

    /// A client observed an error against `shard`.  If the shard is
    /// dead, evacuate it and tell the caller to retry on the new
    /// placement.
    fn recover_shard(&self, shard: usize) -> bool {
        if !self.inner.shards[shard].health.is_dead() {
            return false;
        }
        self.failover(shard);
        true
    }

    /// Evacuate a dead shard: re-place every session homed on it by
    /// rendezvous over the survivors and re-hydrate the replacement
    /// from the host-side base mirror.  Idempotent — exactly one
    /// caller does the work per shard death.
    fn failover(&self, dead: usize) {
        let _serial = self.inner.failover_lock.lock().unwrap();
        let shard = &self.inner.shards[dead];
        if shard.failed_over.swap(true, Ordering::SeqCst) {
            return;
        }
        self.inner.fleet_metrics.on_failover();
        let alive = self.alive();
        let sessions: Vec<Arc<SessionState>> =
            self.inner.sessions.lock().unwrap().values().cloned().collect();
        for session in sessions {
            let mut s = session.inner.lock().unwrap();
            if s.shard != dead {
                continue;
            }
            if alive.is_empty() {
                eprintln!(
                    "fleet: shard {dead} died with no survivors — session \
                     {:016x} stays down (its requests drop counted)",
                    session.fp
                );
                continue;
            }
            let target = rendezvous_shard(session.fp, &alive);
            let (handle, keeper) =
                match self.spawn_incarnation(target, &session.problem, session.bucket, session.fp)
                {
                    Ok(v) => v,
                    Err(e) => {
                        eprintln!(
                            "fleet: could not respawn session {:016x} on shard {target}: {e:#}",
                            session.fp
                        );
                        continue;
                    }
                };
            // re-hydrate: replay every mirrored base under a fresh
            // client id on the replacement incarnation
            let mut idmap = HashMap::new();
            for (&key, plane) in &s.mirror {
                let id = handle.attach();
                match handle.upload_base(id, plane.clone()) {
                    Ok(_) => self.inner.fleet_metrics.on_base_replayed(),
                    Err(e) => eprintln!("fleet: base replay failed: {e:#}"),
                }
                idmap.insert(key, id);
            }
            let old_keeper = s.keeper.take();
            s.keeper = Some(keeper);
            s.handle = handle;
            s.idmap = idmap;
            s.shard = target;
            drop(s);
            if let Some(k) = old_keeper {
                self.inner.graveyard.lock().unwrap().push(k);
            }
            session.generation.fetch_add(1, Ordering::SeqCst);
            self.inner.fleet_metrics.on_session_replaced();
            eprintln!(
                "fleet: session {:016x} failed over shard {dead} → shard {target}",
                session.fp
            );
        }
    }

    /// Admission check for `k` requests from client `key` against
    /// `shard`; `fair` additionally enforces the batch-path fairness
    /// share.  A rejection is counted (`rejected_requests` — which
    /// self-conserves, see [`Metrics::on_rejected`]) and returned as a
    /// named error.
    fn admit(&self, shard: &ShardState, key: u64, k: u64, fair: bool) -> Result<()> {
        let p = &self.inner.policy;
        if let Some(budget) = p.latency_budget {
            let ewma = shard.ewma_round_us.load(Ordering::Relaxed);
            if ewma > 0 {
                let depth = shard.outstanding.load(Ordering::Relaxed);
                let est = admission_estimate_us(depth, k, p.max_batch, ewma);
                if est > budget.as_micros().min(u128::from(u64::MAX)) as u64 {
                    for _ in 0..k {
                        self.inner.fleet_metrics.on_rejected();
                    }
                    bail!(
                        "{ADMISSION_REJECTED}: projected completion in {est}µs \
                         (queue depth {depth} + {k}, ewma round {ewma}µs) exceeds \
                         the {budget:?} latency budget — degrade to the CPU path"
                    );
                }
            }
        }
        if fair {
            let inflight = shard.inflight.lock().unwrap();
            let active = inflight.len() as u64 + u64::from(!inflight.contains_key(&key));
            let own = inflight.get(&key).copied().unwrap_or(0);
            let cap =
                fairness_cap(shard.outstanding.load(Ordering::Relaxed), k, active, p.max_batch);
            if own + k > cap {
                drop(inflight);
                for _ in 0..k {
                    self.inner.fleet_metrics.on_rejected();
                }
                bail!(
                    "{ADMISSION_REJECTED}: client holds {own} request(s) in flight and \
                     asked for {k} more, over its fair share of {cap} across {active} \
                     active client(s) on the shard"
                );
            }
        }
        Ok(())
    }

    /// Per-shard ledgers: each shard's snapshot aggregates every
    /// session incarnation ever homed on it, so `requests == responses
    /// + dropped_requests` holds per shard across restarts and
    /// outbound failovers (at quiescence).
    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.inner
            .shards
            .iter()
            .map(|s| {
                let parts: Vec<MetricsSnapshot> =
                    s.metrics.lock().unwrap().iter().map(|m| m.snapshot()).collect();
                MetricsSnapshot::aggregate(&parts)
            })
            .collect()
    }

    /// The fleet-aggregate ledger: every incarnation on every shard
    /// plus the fleet-level counters (rejections, failovers, replaced
    /// sessions, the shard count).  `shard_conserved` on the result
    /// demands conservation of every merged part individually.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut parts: Vec<MetricsSnapshot> = Vec::new();
        for s in &self.inner.shards {
            parts.extend(s.metrics.lock().unwrap().iter().map(|m| m.snapshot()));
        }
        parts.push(self.inner.fleet_metrics.snapshot());
        MetricsSnapshot::aggregate(&parts)
    }

    /// Shut the fleet down: disconnect every session's executor and
    /// join every incarnation (current and replaced).  Call after all
    /// in-flight calls have returned; clients attached to this fleet
    /// fail cleanly afterwards.  Executors drain their queues before
    /// exiting, so a post-shutdown [`Fleet::snapshot`] is quiescent —
    /// the state the conservation asserts run against.
    pub fn shutdown(&self) {
        let sessions: Vec<Arc<SessionState>> = {
            let mut map = self.inner.sessions.lock().unwrap();
            map.drain().map(|(_, s)| s).collect()
        };
        let mut keepers: Vec<Keeper> = self.inner.graveyard.lock().unwrap().drain(..).collect();
        for session in &sessions {
            let mut s = session.inner.lock().unwrap();
            // swap in a dead handle: the executor's channel disconnects
            // (it drains, counts, and exits), and late client calls get
            // a clean "shut down" error instead of a hang
            let (dead, _) = Handle::for_reference_executor(
                session.bucket,
                0,
                Duration::from_millis(1),
            );
            let old = std::mem::replace(&mut s.handle, dead);
            drop(old);
            if let Some(k) = s.keeper.take() {
                keepers.push(k);
            }
        }
        drop(sessions);
        for k in keepers {
            k.stop();
        }
    }
}

/// In-flight accounting guard: holds `k` slots of a shard's queue
/// depth (and the owning client's fairness share) for the duration of
/// one blocking call.
struct InflightGuard<'a> {
    shard: &'a ShardState,
    key: u64,
    k: u64,
}

impl<'a> InflightGuard<'a> {
    fn enter(shard: &'a ShardState, key: u64, k: u64) -> InflightGuard<'a> {
        shard.outstanding.fetch_add(k, Ordering::Relaxed);
        *shard.inflight.lock().unwrap().entry(key).or_insert(0) += k;
        InflightGuard { shard, key, k }
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.shard.outstanding.fetch_sub(self.k, Ordering::Relaxed);
        let mut m = self.shard.inflight.lock().unwrap();
        if let Some(v) = m.get_mut(&self.key) {
            *v = v.saturating_sub(self.k);
            if *v == 0 {
                m.remove(&self.key);
            }
        }
    }
}

/// A fleet client: one logical delta writer bound to the session its
/// constraint network placed on.  The fleet key is stable across
/// failovers (per-incarnation [`ClientId`]s are re-minted by the
/// replay), and the client transparently retries ONCE through a
/// failover — the failed attempt is a counted drop on the dying
/// shard, the retry a fresh request on the survivor, so conservation
/// holds on both ledgers.
pub struct FleetClient {
    fleet: Fleet,
    session: Arc<SessionState>,
    key: u64,
}

impl FleetClient {
    /// The placed session's content fingerprint.
    pub fn fingerprint(&self) -> u64 {
        self.session.fp
    }

    /// The shard currently hosting this client's session.
    pub fn shard(&self) -> usize {
        self.session.inner.lock().unwrap().shard
    }

    /// The session's failover generation (0 until the first failover).
    pub fn generation(&self) -> u64 {
        self.session.generation.load(Ordering::SeqCst)
    }

    /// The raw protocol [`Handle`] of the session's current
    /// incarnation — the bridge for Handle-based stacks (the MAC
    /// solver workers behind `rtac serve --shards N`).  Raw-handle
    /// traffic speaks the session protocol directly, so it is **not**
    /// admission-checked (that guard lives on the [`FleetClient`]
    /// enforcement paths), and the clone does not follow a failover
    /// re-placement — take it again to pick up the replacement
    /// incarnation.
    pub fn session_handle(&self) -> Handle {
        self.session.inner.lock().unwrap().handle.clone()
    }

    /// Do two clients share one placed session (identical constraint
    /// content)?
    pub fn shares_session(&self, other: &FleetClient) -> bool {
        Arc::ptr_eq(&self.session, &other.session)
    }

    /// The session's plane bucket (shapes for
    /// [`crate::runtime::encode_vars`] / [`PlaneDelta::diff`]).
    pub fn bucket(&self) -> Bucket {
        self.session.bucket
    }

    /// Current incarnation route: handle, this client's session id
    /// there, and the hosting shard.
    fn route(&self) -> (Handle, ClientId, usize) {
        let mut s = self.session.inner.lock().unwrap();
        let client = match s.idmap.get(&self.key) {
            Some(&id) => id,
            None => {
                let id = s.handle.attach();
                s.idmap.insert(self.key, id);
                id
            }
        };
        (s.handle.clone(), client, s.shard)
    }

    /// Upload (or replace) this client's delta base.  Mirrored
    /// host-side for failover re-hydration.  Not admission-checked:
    /// bases are the recovery path — rejecting them would wedge
    /// clients that only need to re-sync.
    pub fn upload_base(&self, plane: Vec<f32>) -> Result<u64> {
        for attempt in 0..2 {
            let (handle, client, shard) = self.route();
            match handle.upload_base(client, plane.clone()) {
                Ok(fp) => {
                    self.session.inner.lock().unwrap().mirror.insert(self.key, plane);
                    return Ok(fp);
                }
                Err(e) => {
                    if attempt == 0 && self.fleet.recover_shard(shard) {
                        continue;
                    }
                    return Err(e);
                }
            }
        }
        unreachable!("the second attempt returned")
    }

    /// One admitted blocking call of weight `k` against the current
    /// incarnation, with the single transparent failover retry.
    fn call<T>(
        &self,
        k: u64,
        fair: bool,
        mut op: impl FnMut(&Handle, ClientId) -> Result<T>,
    ) -> Result<T> {
        for attempt in 0..2 {
            let (handle, client, shard_id) = self.route();
            let shard = &self.fleet.inner.shards[shard_id];
            self.fleet.admit(shard, self.key, k, fair)?;
            let _guard = InflightGuard::enter(shard, self.key, k);
            let t0 = Instant::now();
            match op(&handle, client) {
                Ok(v) => {
                    observe_round(shard, t0.elapsed());
                    return Ok(v);
                }
                Err(e) => {
                    if attempt == 0 && self.fleet.recover_shard(shard_id) {
                        continue;
                    }
                    return Err(e);
                }
            }
        }
        unreachable!("the second attempt returned")
    }

    /// Enforce one chained delta ([`Handle::submit_delta`] semantics:
    /// the slot advances).  On success the host-side mirror advances
    /// in lockstep, so a later failover replays the plane the executor
    /// slot actually held.
    pub fn enforce_delta(&self, delta: PlaneDelta) -> Result<Response> {
        let resp = self.call(1, false, |h, c| h.enforce_delta_blocking(c, delta.clone()))?;
        let mut s = self.session.inner.lock().unwrap();
        if let Some(base) = s.mirror.get(&self.key) {
            let mut next = Vec::new();
            // a fingerprint mismatch means the mirror lost sync with
            // the slot (a failover raced the call) — leave it; the
            // client's next stale round re-uploads and re-syncs both
            if delta.apply_into(base, self.session.bucket, &mut next).is_ok() {
                s.mirror.insert(self.key, next);
            }
        }
        Ok(resp)
    }

    /// Enforce a probe round of deltas against this client's base
    /// (slot unchanged) — the SAC probe path, admission-checked AND
    /// fairness-capped: the round's weight is its probe count.
    pub fn enforce_batch_delta(&self, deltas: Vec<PlaneDelta>) -> Result<Vec<Response>> {
        let k = deltas.len() as u64;
        if k == 0 {
            return Ok(Vec::new());
        }
        self.call(k, true, |h, c| h.enforce_batch_delta_blocking(c, deltas.clone()))
    }

    /// Enforce one full plane (no delta base involved).
    pub fn enforce_full(&self, plane: Vec<f32>) -> Result<Response> {
        self.call(1, false, |h, _| h.enforce_blocking(plane.clone()))
    }
}

/// Fold one observed round latency into the shard's EWMA (3:1 old:new).
fn observe_round(shard: &ShardState, elapsed: Duration) {
    let sample = (elapsed.as_micros().min(u128::from(u64::MAX)) as u64).max(1);
    let old = shard.ewma_round_us.load(Ordering::Relaxed);
    let new = if old == 0 { sample } else { (old * 3 + sample) / 4 };
    shard.ewma_round_us.store(new, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::load::{run_load, LoadSpec};
    use crate::coordinator::chaos::dump_chaos_snapshot;
    use crate::core::State;
    use crate::gen::random::{random_csp, RandomSpec};
    use crate::runtime::encode_vars;
    use crate::util::quickcheck::forall;

    fn small_problem(seed: u64) -> Problem {
        random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, seed))
    }

    fn quick_policy(shards: usize) -> FleetPolicy {
        FleetPolicy {
            shards,
            request_timeout: Duration::from_secs(5),
            max_restarts: 2,
            max_batch: 4,
            ..FleetPolicy::default()
        }
    }

    fn initial_plane(p: &Problem, bucket: Bucket) -> Vec<f32> {
        encode_vars(p, &State::new(p), bucket).unwrap()
    }

    // ---- placement properties ----

    #[test]
    fn placement_is_deterministic_and_rendezvous_stable() {
        forall("fleet-placement", 0xF1EE7, 256, |rng| {
            let n = 2 + rng.gen_range(8);
            let fp = rng.next_u64();
            let alive: Vec<usize> = (0..n).collect();
            let s = rendezvous_shard(fp, &alive);
            if s >= n {
                return Err(format!("placed {fp:016x} on shard {s} of {n}"));
            }
            if rendezvous_shard(fp, &alive) != s {
                return Err("placement is not deterministic".into());
            }
            // membership change: removing any OTHER shard never moves
            // this key; removing its own home moves it to a survivor
            let dead = rng.gen_range(n);
            let survivors: Vec<usize> = (0..n).filter(|&i| i != dead).collect();
            let re = rendezvous_shard(fp, &survivors);
            if dead != s && re != s {
                return Err(format!("removing shard {dead} moved {fp:016x} from {s} to {re}"));
            }
            if dead == s && re == dead {
                return Err("re-placed a key onto the removed shard".into());
            }
            Ok(())
        });
    }

    #[test]
    fn same_network_places_on_the_same_shard_across_fleet_restarts() {
        let problems: Vec<Problem> = (1..=6).map(small_problem).collect();
        let first = Fleet::reference(quick_policy(4)).unwrap();
        let second = Fleet::reference(quick_policy(4)).unwrap();
        for p in &problems {
            let a = first.client(p).unwrap();
            let b = second.client(p).unwrap();
            assert_eq!(a.shard(), b.shard(), "restart moved {:016x}", a.fingerprint());
            assert_eq!(a.fingerprint(), b.fingerprint());
        }
        first.shutdown();
        second.shutdown();
    }

    #[test]
    fn identical_content_shares_a_session_and_differing_content_never_cross_invalidates() {
        let fleet = Fleet::reference(quick_policy(3)).unwrap();
        let p1 = small_problem(21);
        let p1_again = small_problem(21); // separately constructed, identical content
        let p2 = small_problem(22);
        let a = fleet.client(&p1).unwrap();
        let b = fleet.client(&p1_again).unwrap();
        let c = fleet.client(&p2).unwrap();
        assert!(a.shares_session(&b), "identical constraint content must share a session");
        assert!(!a.shares_session(&c), "distinct content must not share a session");
        assert_ne!(a.fingerprint(), c.fingerprint());
        // interleave delta traffic from all three clients: nobody may
        // invalidate anybody else's slot (zero stale drops)
        let base1 = initial_plane(&p1, a.bucket());
        let base2 = initial_plane(&p2, c.bucket());
        let fp1 = a.upload_base(base1.clone()).unwrap();
        let fp1b = b.upload_base(base1.clone()).unwrap();
        let fp2 = c.upload_base(base2.clone()).unwrap();
        assert_eq!(fp1, fp1b, "same plane, same content fingerprint");
        for round in 0..4usize {
            let var = round % 4;
            let d1 = PlaneDelta::singleton(fp1, var, 0, a.bucket());
            let d2 = PlaneDelta::singleton(fp2, var, 0, c.bucket());
            a.enforce_batch_delta(vec![d1.clone()]).unwrap();
            c.enforce_batch_delta(vec![d2]).unwrap();
            b.enforce_batch_delta(vec![d1]).unwrap();
        }
        let agg = fleet.snapshot();
        assert_eq!(agg.stale_deltas, 0, "cross-invalidation: {agg:?}");
        assert!(agg.conserved() && agg.shard_conserved, "{agg:?}");
        fleet.shutdown();
    }

    // ---- admission control ----

    #[test]
    fn admission_estimate_grows_with_depth_and_fairness_splits_evenly() {
        // 0 outstanding + 1 request at ewma 100µs = one round
        assert_eq!(admission_estimate_us(0, 1, 4, 100), 100);
        // 7 outstanding + 1 = 2 rounds of 4
        assert_eq!(admission_estimate_us(7, 1, 4, 100), 200);
        // deeper queue, more rounds
        assert_eq!(admission_estimate_us(15, 1, 4, 100), 400);
        // batch ceiling 1: every request is its own round
        assert_eq!(admission_estimate_us(2, 1, 1, 50), 150);
        // saturation, not overflow
        assert_eq!(admission_estimate_us(u64::MAX - 1, 1, 1, u64::MAX), u64::MAX);
        // fairness: 2 clients on a 10-deep queue split 5 each, floored
        // at max_batch
        assert_eq!(fairness_cap(8, 2, 2, 4), 5);
        assert_eq!(fairness_cap(0, 1, 1, 4), 4, "solo clients keep full rounds");
        assert_eq!(fairness_cap(100, 4, 4, 4), 26);
    }

    #[test]
    fn budget_exceeded_requests_are_rejected_and_counted_not_silently_dropped() {
        let policy = FleetPolicy {
            latency_budget: Some(Duration::ZERO), // any projection blows it
            ..quick_policy(1)
        };
        let fleet = Fleet::reference(policy).unwrap();
        let p = small_problem(31);
        let client = fleet.client(&p).unwrap();
        let plane = initial_plane(&p, client.bucket());
        // no latency signal yet: the first request is admitted and
        // seeds the EWMA
        client.enforce_full(plane.clone()).expect("first request admitted");
        // now every projection exceeds the zero budget
        let e = client.enforce_full(plane.clone()).unwrap_err();
        assert!(is_admission_rejected(&e), "named rejection, got: {e:#}");
        let e2 = client
            .enforce_batch_delta(vec![PlaneDelta::singleton(
                crate::runtime::plane_fingerprint(&plane),
                0,
                0,
                client.bucket(),
            )])
            .unwrap_err();
        assert!(is_admission_rejected(&e2), "{e2:#}");
        fleet.shutdown();
        let agg = fleet.snapshot();
        assert_eq!(agg.rejected_requests, 2);
        assert_eq!(agg.requests, 3, "rejections are counted requests");
        assert_eq!(agg.responses, 1);
        assert!(agg.conserved() && agg.shard_conserved, "rejected-and-counted: {agg:?}");
        assert_eq!(agg.failovers, 0, "a rejection is not a death");
    }

    #[test]
    fn generous_budget_admits_everything() {
        let policy = FleetPolicy {
            latency_budget: Some(Duration::from_secs(60)),
            ..quick_policy(2)
        };
        let fleet = Fleet::reference(policy).unwrap();
        let p = small_problem(32);
        let client = fleet.client(&p).unwrap();
        let plane = initial_plane(&p, client.bucket());
        let fp = client.upload_base(plane.clone()).unwrap();
        for _ in 0..6 {
            client.enforce_full(plane.clone()).unwrap();
            client
                .enforce_batch_delta(vec![PlaneDelta::singleton(fp, 0, 0, client.bucket())])
                .unwrap();
        }
        fleet.shutdown();
        let agg = fleet.snapshot();
        assert_eq!(agg.rejected_requests, 0, "{agg:?}");
        assert!(agg.conserved() && agg.shard_conserved);
    }

    // ---- failover ----

    #[test]
    fn forced_kill_re_places_only_the_dead_shards_sessions_and_replays_bases() {
        let fleet = Fleet::reference(quick_policy(3)).unwrap();
        let problems: Vec<Problem> = (41..=46).map(small_problem).collect();
        let clients: Vec<FleetClient> =
            problems.iter().map(|p| fleet.client(p).unwrap()).collect();
        let planes: Vec<Vec<f32>> =
            problems.iter().zip(&clients).map(|(p, c)| initial_plane(p, c.bucket())).collect();
        for (c, plane) in clients.iter().zip(&planes) {
            c.upload_base(plane.clone()).unwrap();
            c.enforce_full(plane.clone()).unwrap();
        }
        let before: Vec<usize> = clients.iter().map(|c| c.shard()).collect();
        let victim = before[0];
        let expected_moves = before.iter().filter(|&&s| s == victim).count() as u64;
        fleet.kill_shard(victim);
        let after: Vec<usize> = clients.iter().map(|c| c.shard()).collect();
        for (i, (&b, &a)) in before.iter().zip(&after).enumerate() {
            if b == victim {
                assert_ne!(a, victim, "session {i} must leave the dead shard");
                assert_eq!(clients[i].generation(), 1);
            } else {
                assert_eq!(a, b, "survivor session {i} must not move");
                assert_eq!(clients[i].generation(), 0);
            }
        }
        // the re-placed clients keep working — their bases were
        // replayed, so a delta against the pre-kill fingerprint holds
        for (i, (c, plane)) in clients.iter().zip(&planes).enumerate() {
            let fp = crate::runtime::plane_fingerprint(plane);
            let out = c
                .enforce_batch_delta(vec![PlaneDelta::singleton(fp, 0, 0, c.bucket())])
                .unwrap_or_else(|e| panic!("client {i} after failover: {e:#}"));
            assert_eq!(out.len(), 1);
        }
        fleet.shutdown();
        let agg = fleet.snapshot();
        assert_eq!(agg.failovers, 1);
        assert_eq!(agg.replaced_sessions, expected_moves);
        assert!(agg.replayed_bases >= expected_moves, "one mirrored base per moved client");
        assert_eq!(agg.stale_deltas, 0, "replayed bases must not go stale: {agg:?}");
        assert!(agg.conserved() && agg.shard_conserved, "{agg:?}");
        assert_eq!(fleet.live_shards(), 2);
    }

    // ---- the seeded fleet chaos battery (the CI `chaos` job runs this
    // by name; snapshots dump per seed AND per shard when
    // RTAC_CHAOS_SNAPSHOT_DIR is set) ----

    #[test]
    fn shard_fixcache_serves_warm_hits_and_survives_failover() {
        let policy = FleetPolicy { fixcache_entries: 32, ..quick_policy(3) };
        let fleet = Fleet::reference(policy).unwrap();
        let p = small_problem(51);
        let client = fleet.client(&p).unwrap();
        let plane = initial_plane(&p, client.bucket());
        let cold = client.enforce_full(plane.clone()).unwrap();
        let warm = client.enforce_full(plane.clone()).unwrap();
        assert_eq!(cold.plane, warm.plane, "a warm hit must serve the identical closure");
        assert_eq!(cold.iters, warm.iters);
        let agg = fleet.snapshot();
        assert_eq!(agg.fixcache_hits, 1, "{}", agg.summary());
        assert_eq!(agg.fixcache_misses, 1, "{}", agg.summary());
        // kill the hosting shard: the replacement incarnation shares
        // the SURVIVOR's cache — the first post-failover solve is a
        // miss there, the repeat a hit (the replay repopulates it)
        let home = client.shard();
        fleet.kill_shard(home);
        let moved = client.enforce_full(plane.clone()).unwrap();
        assert_eq!(cold.plane, moved.plane, "failover must not change the closure");
        let rewarmed = client.enforce_full(plane).unwrap();
        assert_eq!(cold.plane, rewarmed.plane);
        fleet.shutdown();
        let agg = fleet.snapshot();
        assert_eq!(agg.fixcache_hits, 2, "{}", agg.summary());
        assert_eq!(agg.fixcache_misses, 2, "{}", agg.summary());
        assert!(agg.fixcache_bytes > 0);
        assert!(agg.conserved() && agg.shard_conserved, "{agg:?}");
    }

    #[test]
    fn fleet_chaos_plans_conserve_per_shard_and_reach_native_fixpoints() {
        for seed in 1..=8u64 {
            let spec = LoadSpec {
                shards: 3,
                clients: 6,
                rounds: 6,
                seed,
                latency_budget: None,
                chaos: true,
                fixcache_entries: 0,
            };
            let report = run_load(&spec).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
            assert_eq!(
                report.mismatches, 0,
                "seed {seed}: every response must be bit-identical to the native fixpoint"
            );
            assert!(
                report.aggregate.failovers >= 1,
                "seed {seed}: the forced kill must register a failover: {:?}",
                report.aggregate
            );
            assert!(
                report.aggregate.conserved() && report.aggregate.shard_conserved,
                "seed {seed}: fleet-aggregate conservation: {:?}",
                report.aggregate
            );
            for (i, shard) in report.shards.iter().enumerate() {
                assert!(
                    shard.conserved(),
                    "seed {seed} shard {i}: requests {} != responses {} + dropped {}",
                    shard.requests,
                    shard.responses,
                    shard.dropped_requests
                );
            }
            let ledger_requests: u64 = report.ledger.iter().map(|c| c.requests).sum();
            assert!(ledger_requests > 0, "seed {seed}: the workload must have run");
            dump_chaos_snapshot(&format!("fleet_seed_{seed}"), &report.aggregate);
            for (i, shard) in report.shards.iter().enumerate() {
                dump_chaos_snapshot(&format!("fleet_seed_{seed}_shard_{i}"), shard);
            }
        }
    }

    /// The cache-enabled leg of the seeded battery (satellite of the
    /// fixcache PR; the CI `chaos` job runs this by name): with every
    /// shard carrying a warm memo layer — and seeded plans now also
    /// wiping it mid-run — restarts, failovers, and cache hits
    /// interleave, yet every response stays bit-identical to the
    /// native fixpoint and every ledger conserves.
    #[test]
    fn fleet_chaos_with_fixcache_stays_bit_identical_and_conserves() {
        let mut total_hits = 0u64;
        for seed in 1..=8u64 {
            let spec = LoadSpec {
                shards: 3,
                clients: 6,
                rounds: 6,
                seed,
                latency_budget: None,
                chaos: true,
                fixcache_entries: 64,
            };
            let report = run_load(&spec).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
            assert_eq!(
                report.mismatches, 0,
                "seed {seed}: cache-served responses must stay bit-identical to the \
                 native fixpoint"
            );
            assert!(
                report.aggregate.conserved() && report.aggregate.shard_conserved,
                "seed {seed}: conservation with the memo layer on: {:?}",
                report.aggregate
            );
            for (i, shard) in report.shards.iter().enumerate() {
                assert!(shard.conserved(), "seed {seed} shard {i}: {}", shard.summary());
            }
            total_hits += report.aggregate.fixcache_hits;
            dump_chaos_snapshot(&format!("fleet_fixcache_seed_{seed}"), &report.aggregate);
        }
        assert!(
            total_hits > 0,
            "across 8 seeds of repeated probe traffic the memo layer must hit"
        );
    }

    #[test]
    fn a_fleet_of_zero_shards_is_rejected() {
        let e = Fleet::reference(FleetPolicy { shards: 0, ..FleetPolicy::default() })
            .err()
            .expect("zero shards must fail");
        assert!(format!("{e:#}").contains("at least one shard"));
    }
}
