//! Deterministic fault injection over the session protocol — the
//! offline chaos harness behind the CI `chaos` job, the protocol test
//! batteries, and (since the fleet PR) the runtime reference executors
//! that `coordinator::fleet` and `bench::load` shard over.
//!
//! The centerpiece is [`chaos_reference_executor`]: a stand-in executor
//! thread that serves the exact client→executor wire protocol
//! (`service::Msg` over the same `BaseSlots` + `resolve_payload` the
//! production executor thread uses) with the native CPU engine instead
//! of XLA, while a seeded [`FaultPlan`] injects crashes, hangs,
//! failed-execution streaks, base-cache wipes, and — for the sharded
//! tier — whole-shard kills.  It is supervised by the SAME
//! `service::Supervisor` state machine production runs, so the offline
//! e2e tests exercise production's restart/deadline/drop decisions with
//! no compiled artifacts.
//!
//! Everything here used to live inside `service.rs`'s test module; it
//! was promoted to a runtime module so a [`crate::coordinator::fleet`]
//! built from reference executors can serve real (offline) traffic —
//! `rtac loadgen` drives exactly these executors.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::coordinator::fixcache::FixCache;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::service::{resolve_payload, BaseSlots, Msg, Response, Supervisor};
use crate::core::Problem;
use crate::runtime::{Bucket, STATUS_WIPEOUT};

/// Shared liveness flag of one fleet shard: flipped dead by a
/// [`FaultPlan::kill_shard_at`] fault (or by a session going moribund)
/// and polled by the fleet tier, which then fails the shard over —
/// every session homed on it re-places onto a surviving shard.
/// Standalone (non-fleet) sessions pass a fresh flag and ignore it.
#[derive(Clone, Debug, Default)]
pub(crate) struct ShardHealth(Arc<AtomicBool>);

impl ShardHealth {
    pub(crate) fn new() -> ShardHealth {
        ShardHealth::default()
    }

    /// True once the shard has been declared dead (sticky; a dead shard
    /// never comes back — its sessions move instead).
    pub(crate) fn is_dead(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }

    /// Declare the shard dead.
    pub(crate) fn mark_dead(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// §Fault injection: one deterministic chaos plan for the supervised
/// CPU-reference executor ([`chaos_reference_executor`]).  Fault sites
/// are *request indices* — the Nth enforcement request the executor
/// receives (base uploads and restart messages do not count) — so a
/// plan replays bit-identically for a deterministic client.
#[derive(Clone, Debug, Default)]
pub(crate) struct FaultPlan {
    /// Simulated executor crashes: before serving request N the
    /// session state dies and the supervisor restarts it — same
    /// `Supervisor` budget/backoff decisions, same re-hydration
    /// accounting (base replay + in-flight re-enqueue) as the
    /// production executor thread.
    pub(crate) crash_at: Vec<u64>,
    /// Hangs: serving request N stalls until past the per-request
    /// deadline, so the client's `recv_deadline` fires and the
    /// executor counts the expired request when it reaches it.
    pub(crate) hang_at: Vec<u64>,
    /// Failed fused executions: requests N and N+1 both fail — a
    /// streak of `Supervisor::FAILED_STREAK_LIMIT`, driving the
    /// streak→restart path.
    pub(crate) fail_streak_at: Vec<u64>,
    /// Base-cache wipes (`BaseSlots::wipe`) before request N: every
    /// delta client's next round drops stale and must recover through
    /// its bounded fresh-base retry.
    pub(crate) wipe_bases_at: Vec<u64>,
    /// Whole-shard kills (the fleet-tier fault): before serving request
    /// N the session's [`ShardHealth`] flips dead and the session goes
    /// moribund — request N and everything after it is dropped AND
    /// counted (`restart_dropped_requests`), so per-shard conservation
    /// holds while the fleet re-places the shard's sessions onto
    /// survivors.
    pub(crate) kill_shard_at: Vec<u64>,
    /// Fixpoint-cache wipes ([`FixCache::wipe`]) before request N: the
    /// memo layer loses every warm entry and request N (plus everything
    /// after it, until re-warmed) takes the miss path — the closure
    /// served must stay bit-identical, only `fixcache_hits` moves.
    /// A no-op when the session runs cache-less.
    pub(crate) wipe_fixcache_at: Vec<u64>,
}

impl FaultPlan {
    /// Deterministic plan derived from `seed` (xorshift64 — no
    /// external RNG dependency): 1–3 faults of mixed kinds spread
    /// over the first ~12 requests.  Single-session fault kinds only —
    /// the historical chaos battery replays these seeds bit-identically.
    pub(crate) fn seeded(seed: u64) -> FaultPlan {
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        let mut plan = FaultPlan::default();
        let n_faults = 1 + next() % 3;
        for i in 0..n_faults {
            let at = 1 + i * 4 + next() % 3;
            match next() % 4 {
                0 => plan.crash_at.push(at),
                1 => plan.hang_at.push(at),
                2 => plan.fail_streak_at.push(at),
                _ => plan.wipe_bases_at.push(at),
            }
        }
        // fixpoint-cache wipes ride a DISJOINT xorshift stream (like
        // the fleet kill stream below) so the four historical fault
        // kinds replay bit-identically under every seed that predates
        // the memo layer; roughly one seed in three wipes once.
        let mut s2 = seed.wrapping_mul(0xBF58_476D_1CE4_E5B9) | 1;
        let mut next2 = move || {
            s2 ^= s2 << 13;
            s2 ^= s2 >> 7;
            s2 ^= s2 << 17;
            s2
        };
        if next2() % 3 == 0 {
            plan.wipe_fixcache_at.push(1 + next2() % 8);
        }
        plan
    }

    /// Deterministic *fleet* plan: the single-session faults of
    /// [`FaultPlan::seeded`], plus — on roughly one seed in three — a
    /// whole-shard kill, so a seeded fleet run exercises failover
    /// organically on top of any forced kills the driver injects.
    pub(crate) fn seeded_fleet(seed: u64) -> FaultPlan {
        let mut plan = FaultPlan::seeded(seed);
        // derive the kill decision from a disjoint xorshift stream so
        // the shared single-session faults stay bit-identical to
        // `seeded(seed)`
        let mut s = seed.wrapping_mul(0xD134_2543_DE82_EF95) | 1;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        if next() % 3 == 0 {
            plan.kill_shard_at.push(2 + next() % 6);
        }
        plan
    }

    /// Does request `i` fall in a failed-execution streak?
    fn fails(&self, i: u64) -> bool {
        self.fail_streak_at.iter().any(|&at| i == at || i == at + 1)
    }
}

/// The CPU-reference executor wrapped in deterministic fault
/// injection: serves the session protocol with the native CPU engine
/// (same `resolve_payload` over the same `BaseSlots` as the real
/// executor) while a [`FaultPlan`] injects crashes, hangs, failed
/// executions, base-cache wipes, and whole-shard kills — supervised by
/// the SAME `Supervisor` state machine the production executor thread
/// runs.  With an empty plan this *is* the plain CPU-reference
/// executor.  `health` is the hosting shard's liveness flag (flipped by
/// kill-shard faults and by moribund exhaustion so the fleet tier can
/// fail the shard over); standalone sessions pass `ShardHealth::new()`.
/// `fixcache` is the (optionally shard-shared) fixpoint memo layer:
/// exactly like the production executor thread, a hit skips the native
/// enforcement and still answers as a normal response, keyed here by
/// `(problem fingerprint, input-plane fingerprint)`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn chaos_reference_executor(
    problem: Problem,
    bucket: Bucket,
    base_slots: usize,
    request_timeout: Duration,
    max_restarts: u32,
    plan: FaultPlan,
    health: ShardHealth,
    fixcache: Option<Arc<FixCache>>,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
) -> std::thread::JoinHandle<()> {
    /// Spend one restart (mirroring `restart_session`): true when
    /// the session re-hydrated, false when the budget is exhausted
    /// and the session must go moribund (`drain_moribund`).
    fn restart(supervisor: &mut Supervisor, slots: &BaseSlots, metrics: &Metrics, why: &str) -> bool {
        match supervisor.begin_restart() {
            Some(backoff) => {
                std::thread::sleep(backoff);
                metrics.on_executor_restart();
                for _ in 0..slots.len() {
                    metrics.on_base_replayed();
                }
                eprintln!(
                    "chaos-executor: restart {} after {why} ({} base slot(s) replayed)",
                    supervisor.restarts(),
                    slots.len()
                );
                true
            }
            None => {
                eprintln!("chaos-executor: restart budget exhausted after {why} — moribund");
                false
            }
        }
    }
    // lint:allow(thread-placement): chaos reference executor thread (the
    // offline stand-in for the production rtac-executor thread)
    std::thread::spawn(move || {
        use crate::ac::{rtac::RtacNative, Counters, Propagator};
        use crate::runtime::{decode_vars, encode_vars, plane_fingerprint};
        // the constraint half of every cache key, hashed once — the
        // reference executor is content-addressed by the problem itself
        // (the production executor hashes its encoded constraint tensor)
        let cons_fp = crate::ac::sac::problem_fingerprint(&problem);
        let mut slots = BaseSlots::new(base_slots);
        let mut engine = RtacNative::dense();
        let mut supervisor = Supervisor::new(max_restarts);
        let mut idx: u64 = 0;
        let mut moribund = false;
        while let Ok(msg) = rx.recv() {
            let req = match msg {
                Msg::Base { client, fp, plane } => {
                    if !moribund && slots.insert(client, fp, plane) {
                        metrics.on_base_evicted();
                    }
                    continue;
                }
                Msg::ForceRestart => {
                    if !moribund
                        && !restart(&mut supervisor, &slots, &metrics, "a forced restart")
                    {
                        moribund = true;
                        health.mark_dead();
                    }
                    continue;
                }
                Msg::Req(r) => r,
            };
            if moribund {
                // the drain_moribund contract: drop AND count every
                // remaining request until all handles disconnect
                metrics.on_restart_dropped(req.payload.client());
                continue;
            }
            let i = idx;
            idx += 1;
            if plan.kill_shard_at.contains(&i) {
                // the fleet-tier fault: the whole shard dies with
                // request i in flight.  The session goes moribund (all
                // further requests dropped AND counted) and the shard's
                // health flag flips, so the fleet re-places every
                // session homed here onto a surviving shard.
                eprintln!("chaos-executor: shard killed before request {i}");
                health.mark_dead();
                moribund = true;
                metrics.on_restart_dropped(req.payload.client());
                continue;
            }
            if plan.wipe_bases_at.contains(&i) {
                let n = slots.wipe();
                eprintln!("chaos-executor: wiped {n} base slot(s) before request {i}");
            }
            if plan.wipe_fixcache_at.contains(&i) {
                if let Some(cache) = &fixcache {
                    let n = cache.wipe();
                    eprintln!("chaos-executor: wiped {n} fixcache entr(y/ies) before request {i}");
                }
            }
            if plan.crash_at.contains(&i) {
                // the crash kills the exec state with request i in
                // flight; after the restart the request is served
                // from the re-enqueued pending set (the
                // `restart_session` replay)
                if !restart(&mut supervisor, &slots, &metrics, "a crash") {
                    moribund = true;
                    health.mark_dead();
                    metrics.on_restart_dropped(req.payload.client());
                    continue;
                }
            }
            if plan.hang_at.contains(&i) {
                std::thread::sleep(request_timeout + Duration::from_millis(20));
            }
            // the executor half of the per-request deadline
            // (mirrors the real drain loop)
            if req.submitted.elapsed() > request_timeout {
                metrics.on_request_timeout(req.payload.client());
                continue;
            }
            if plan.fails(i) {
                metrics.on_batch_failed(&[req.payload.client()]);
                drop(req); // responder gone: the client sees dropped_err
                if supervisor.on_batch_failed()
                    && !restart(&mut supervisor, &slots, &metrics, "a failed-execution streak")
                {
                    moribund = true;
                    health.mark_dead();
                }
                continue;
            }
            let client = req.payload.client();
            let Some(plane) = resolve_payload(req.payload, &mut slots, bucket) else {
                let client = client.expect("only deltas can fail to resolve");
                metrics.on_stale_delta(client);
                continue; // responder dropped, like the real executor
            };
            // fixpoint-cache consult (mirrors the production executor's
            // step 3b): a hit answers as a normal response — counted in
            // `responses`, NOT in `batches` — with the stored closure
            // and sweep count, bit-identical to running the engine
            let input_fp = plane_fingerprint(&plane);
            if let Some(cache) = &fixcache {
                if let Some(hit) = cache.lookup_plane(cons_fp, input_fp) {
                    metrics.on_fixcache_hit();
                    let status = if hit.wiped { STATUS_WIPEOUT } else { 0 };
                    metrics.on_response(client, Duration::ZERO, Duration::ZERO, hit.iters, hit.wiped);
                    let _ = req.resp.send(Response {
                        plane: hit.plane,
                        status,
                        iters: hit.iters,
                        batch_real: 1,
                        batch_capacity: 1,
                        queue_time: Duration::ZERO,
                        total_time: Duration::ZERO,
                    });
                    continue;
                }
                metrics.on_fixcache_miss();
            }
            let mut state = crate::core::State::new(&problem);
            decode_vars(&problem, &mut state, &plane, bucket).expect("monotone input plane");
            let mut c = Counters::default();
            engine.reset(&problem);
            let out = engine.enforce(&problem, &mut state, &[], &mut c);
            supervisor.on_batch_ok();
            let status = if out.is_consistent() { 0 } else { STATUS_WIPEOUT };
            let out_plane = encode_vars(&problem, &state, bucket).expect("fits the bucket");
            if let Some(cache) = &fixcache {
                let (evicted, bytes) = cache.insert_plane(
                    cons_fp,
                    input_fp,
                    out_plane.clone(),
                    status == STATUS_WIPEOUT,
                    c.recurrences as i32,
                );
                metrics.on_fixcache_insert(bytes, evicted);
            }
            metrics.on_batch(1, 1, Duration::from_micros(1));
            metrics.on_response(
                client,
                Duration::ZERO,
                Duration::ZERO,
                c.recurrences as i32,
                status == STATUS_WIPEOUT,
            );
            let _ = req.resp.send(Response {
                plane: out_plane,
                status,
                iters: c.recurrences as i32,
                batch_real: 1,
                batch_capacity: 1,
                queue_time: Duration::ZERO,
                total_time: Duration::ZERO,
            });
        }
    })
}

/// A stand-in executor thread that serves the session protocol with
/// the native CPU engine instead of XLA — the fault-free
/// specialisation of [`chaos_reference_executor`].  Lets the delta
/// protocol — and clients built on it, up to whole parallel searches
/// and reference fleets — run end-to-end with no compiled artifacts.
/// (The fleet tier calls [`chaos_reference_executor`] directly so its
/// shard health flag is wired in; this convenience wrapper serves the
/// single-session test fixtures.)
#[cfg(test)]
pub(crate) fn cpu_reference_executor(
    problem: Problem,
    bucket: Bucket,
    base_slots: usize,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
) -> std::thread::JoinHandle<()> {
    let policy = crate::coordinator::BatchPolicy::default();
    chaos_reference_executor(
        problem,
        bucket,
        base_slots,
        policy.request_timeout,
        policy.max_restarts,
        FaultPlan::default(),
        ShardHealth::new(),
        None,
        rx,
        metrics,
    )
}

/// When `RTAC_CHAOS_SNAPSHOT_DIR` is set (the CI chaos job), dump a
/// final [`crate::coordinator::MetricsSnapshot`] there as
/// `<name>.txt` — one artifact per chaos seed / fleet shard, so a CI
/// failure is diagnosable from the uploaded artifacts alone.
pub(crate) fn dump_chaos_snapshot(name: &str, m: &crate::coordinator::MetricsSnapshot) {
    let Ok(dir) = std::env::var("RTAC_CHAOS_SNAPSHOT_DIR") else { return };
    let path = std::path::Path::new(&dir).join(format!("{name}.txt"));
    if let Err(e) = std::fs::write(&path, format!("{}\n\n{m:#?}\n", m.summary())) {
        eprintln!("chaos snapshot: could not write {path:?}: {e}");
    }
}

/// Session fixture around [`chaos_reference_executor`] with an
/// explicit fault plan, deadline, and restart budget (all mirrored
/// onto the handle like `Coordinator::start` does from the policy).
#[cfg(test)]
pub(crate) fn chaos_session(
    problem: &Problem,
    bucket: Bucket,
    plan: FaultPlan,
    request_timeout: Duration,
    max_restarts: u32,
) -> (crate::coordinator::Handle, std::thread::JoinHandle<()>) {
    chaos_session_with_cache(problem, bucket, plan, request_timeout, max_restarts, None)
}

/// [`chaos_session`] with an explicit (possibly shared) fixpoint memo
/// layer — the fixture behind the differential cache-equivalence
/// battery: the same problem, plan, and request stream served cache-off
/// vs cache-on vs capacity-1 must reach bit-identical closures.
#[cfg(test)]
pub(crate) fn chaos_session_with_cache(
    problem: &Problem,
    bucket: Bucket,
    plan: FaultPlan,
    request_timeout: Duration,
    max_restarts: u32,
    fixcache: Option<Arc<FixCache>>,
) -> (crate::coordinator::Handle, std::thread::JoinHandle<()>) {
    let base_slots = crate::coordinator::BatchPolicy::default().base_slots;
    let (h, rx) =
        crate::coordinator::Handle::for_reference_executor(bucket, base_slots, request_timeout);
    let join = chaos_reference_executor(
        problem.clone(),
        bucket,
        base_slots,
        request_timeout,
        max_restarts,
        plan,
        ShardHealth::new(),
        fixcache,
        rx,
        h.metrics.clone(),
    );
    (h, join)
}

/// Session fixture around [`cpu_reference_executor`] with an
/// explicit base-slot cap (mirrored onto the handle, like
/// `Coordinator::start` does from the policy).
#[cfg(test)]
pub(crate) fn reference_session_with_slots(
    problem: &Problem,
    bucket: Bucket,
    base_slots: usize,
) -> (crate::coordinator::Handle, std::thread::JoinHandle<()>) {
    let timeout = crate::coordinator::BatchPolicy::default().request_timeout;
    let (h, rx) = crate::coordinator::Handle::for_reference_executor(bucket, base_slots, timeout);
    let join = cpu_reference_executor(problem.clone(), bucket, base_slots, rx, h.metrics.clone());
    (h, join)
}

/// Session fixture at the default slot cap.
#[cfg(test)]
pub(crate) fn reference_session(
    problem: &Problem,
    bucket: Bucket,
) -> (crate::coordinator::Handle, std::thread::JoinHandle<()>) {
    reference_session_with_slots(problem, bucket, crate::coordinator::BatchPolicy::default().base_slots)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_fleet_plans_extend_but_never_reshuffle_the_session_faults() {
        for seed in 1..=32u64 {
            let base = FaultPlan::seeded(seed);
            let fleet = FaultPlan::seeded_fleet(seed);
            assert_eq!(base.crash_at, fleet.crash_at, "seed {seed}");
            assert_eq!(base.hang_at, fleet.hang_at, "seed {seed}");
            assert_eq!(base.fail_streak_at, fleet.fail_streak_at, "seed {seed}");
            assert_eq!(base.wipe_bases_at, fleet.wipe_bases_at, "seed {seed}");
            assert_eq!(base.wipe_fixcache_at, fleet.wipe_fixcache_at, "seed {seed}");
            assert!(base.kill_shard_at.is_empty(), "seeded() must stay single-session");
        }
        // the fleet variant does inject shard kills on some seeds
        let kills: usize =
            (1..=32u64).map(|s| FaultPlan::seeded_fleet(s).kill_shard_at.len()).sum();
        assert!(kills > 0, "at least one of 32 seeds must kill a shard");
        // and the disjoint fixcache stream does wipe on some seeds
        let wipes: usize =
            (1..=32u64).map(|s| FaultPlan::seeded(s).wipe_fixcache_at.len()).sum();
        assert!(wipes > 0, "at least one of 32 seeds must wipe the fixcache");
    }

    #[test]
    fn fixcache_hit_serves_the_identical_closure_without_a_batch() {
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 23));
        let cache = FixCache::shared(16);
        let (h, join) = chaos_session_with_cache(
            &p,
            bucket,
            FaultPlan::default(),
            Duration::from_secs(5),
            3,
            cache.clone(),
        );
        let s = crate::core::State::new(&p);
        let plane = encode_vars(&p, &s, bucket).unwrap();
        let cold = h.enforce_blocking(plane.clone()).unwrap();
        let warm = h.enforce_blocking(plane).unwrap();
        assert_eq!(cold.plane, warm.plane, "a hit must serve the identical closure");
        assert_eq!(cold.status, warm.status);
        assert_eq!(cold.iters, warm.iters, "the stored sweep count replays bit-identically");
        let m = h.metrics.snapshot();
        drop(h);
        join.join().unwrap();
        assert!(m.conserved(), "{}", m.summary());
        assert_eq!(m.fixcache_hits, 1, "{}", m.summary());
        assert_eq!(m.fixcache_misses, 1, "{}", m.summary());
        assert_eq!(m.batches, 1, "the hit must skip the enforcement entirely");
        assert_eq!(m.responses, 2, "the hit still counts as a normal response");
        let stats = cache.unwrap().stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }

    #[test]
    fn poisoned_fixcache_entry_is_detected_and_recomputed_not_served() {
        use crate::ac::sac::problem_fingerprint;
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::{encode_vars, plane_fingerprint};
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 29));
        let cache = FixCache::shared(16).unwrap();
        let (h, join) = chaos_session_with_cache(
            &p,
            bucket,
            FaultPlan::default(),
            Duration::from_secs(5),
            3,
            Some(cache.clone()),
        );
        let s = crate::core::State::new(&p);
        let plane = encode_vars(&p, &s, bucket).unwrap();
        let cold = h.enforce_blocking(plane.clone()).unwrap();
        // corrupt the resident entry's payload WITHOUT refreshing its
        // stored fingerprint — the canary: the lookup's re-check must
        // catch the mismatch, evict, and fall through to a recompute
        let cons_fp = problem_fingerprint(&p);
        let input_fp = plane_fingerprint(&plane);
        assert!(cache.poison(cons_fp, input_fp), "the cold solve must have been admitted");
        let recomputed = h.enforce_blocking(plane).unwrap();
        assert_eq!(
            cold.plane, recomputed.plane,
            "the corrupted entry must never be served — the engine reruns"
        );
        let m = h.metrics.snapshot();
        drop(h);
        join.join().unwrap();
        assert!(m.conserved(), "{}", m.summary());
        assert_eq!(m.fixcache_hits, 0, "a poisoned entry is not a hit");
        assert_eq!(m.fixcache_misses, 2, "{}", m.summary());
        assert!(
            cache.stats().evictions >= 1,
            "poison detection must eject the corrupted entry"
        );
        assert_eq!(m.batches, 2, "both solves ran the engine");
    }

    /// The 8-seed differential leg of the cache-equivalence battery:
    /// the same seeded chaos plan (crashes, hangs, failed streaks, base
    /// wipes, fixcache wipes) driven over the same request stream must
    /// produce *bit-identical per-request outcomes* — same closure
    /// plane, status, and sweep count on success, an error on the same
    /// requests otherwise — whether the memo layer is off, ample, or a
    /// thrashing capacity-1, and every variant's ledger must conserve.
    #[test]
    fn seeded_chaos_replays_bit_identically_across_cache_variants() {
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 7));
        let s0 = crate::core::State::new(&p);
        let full = encode_vars(&p, &s0, bucket).unwrap();
        // a second, tighter input plane so capacity-1 actually thrashes
        let mut pruned = full.clone();
        pruned[0] = 0.0;
        let planes = [full, pruned];
        let mut total_hits = 0u64;
        for seed in [1u64, 2, 3, 5, 8, 13, 21, 34] {
            // hang faults turn on wall-clock timing (sleep past the
            // deadline), which can flip a *neighbouring* request's
            // outcome under scheduler noise — strip them so the
            // bit-identity comparison is deterministic; the timeout
            // path has its own dedicated battery
            let mut plan = FaultPlan::seeded(seed);
            plan.hang_at.clear();
            let mut outcomes: Vec<Vec<Result<(Vec<f32>, i32, i32), String>>> = Vec::new();
            for entries in [0usize, 64, 1] {
                let (h, join) = chaos_session_with_cache(
                    &p,
                    bucket,
                    plan.clone(),
                    Duration::from_secs(1),
                    8,
                    FixCache::shared(entries),
                );
                let mut run = Vec::new();
                for _round in 0..3 {
                    for plane in &planes {
                        run.push(
                            h.enforce_blocking(plane.clone())
                                .map(|r| (r.plane, r.status, r.iters))
                                .map_err(|e| format!("{e:#}")),
                        );
                    }
                }
                let m = h.metrics.snapshot();
                drop(h);
                join.join().unwrap();
                assert!(m.conserved(), "seed {seed} entries {entries}: {}", m.summary());
                if entries == 64 {
                    total_hits += m.fixcache_hits;
                }
                outcomes.push(run);
            }
            let (off, on, cap1) = (&outcomes[0], &outcomes[1], &outcomes[2]);
            for (i, base) in off.iter().enumerate() {
                match (base, &on[i], &cap1[i]) {
                    (Ok(a), Ok(b), Ok(c)) => {
                        assert_eq!(a, b, "seed {seed} req {i}: cache-on diverged");
                        assert_eq!(a, c, "seed {seed} req {i}: capacity-1 diverged");
                    }
                    (Err(_), Err(_), Err(_)) => {}
                    _ => panic!(
                        "seed {seed} req {i}: fault outcomes diverged across cache variants"
                    ),
                }
            }
        }
        assert!(total_hits > 0, "the warm variant must hit at least once across 8 seeds");
    }

    #[test]
    fn kill_shard_fault_flips_health_and_drains_with_conservation() {
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 11));
        let health = ShardHealth::new();
        let base_slots = crate::coordinator::BatchPolicy::default().base_slots;
        let (h, rx) = crate::coordinator::Handle::for_reference_executor(
            bucket,
            base_slots,
            Duration::from_secs(5),
        );
        let plan = FaultPlan { kill_shard_at: vec![1], ..FaultPlan::default() };
        let join = chaos_reference_executor(
            p.clone(),
            bucket,
            base_slots,
            Duration::from_secs(5),
            3,
            plan,
            health.clone(),
            None,
            rx,
            h.metrics.clone(),
        );
        let s = crate::core::State::new(&p);
        let plane = encode_vars(&p, &s, bucket).unwrap();
        h.enforce_blocking(plane.clone()).expect("request 0 precedes the kill");
        assert!(!health.is_dead(), "the shard dies at request 1, not before");
        let e = h.enforce_blocking(plane.clone()).unwrap_err();
        assert!(format!("{e:#}").contains("dropped"), "{e:#}");
        assert!(health.is_dead(), "the kill-shard fault must flip the health flag");
        // moribund drain: later requests also drop AND count
        let _ = h.enforce_blocking(plane).unwrap_err();
        drop(h);
        join.join().unwrap();
    }
}
