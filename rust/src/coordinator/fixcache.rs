//! Content-addressed fixpoint memo layer — the bounded LRU cache
//! consulted before any enforcement round actually runs.
//!
//! Everything on the serving path is already content-fingerprinted:
//! constraint networks ([`crate::ac::sac::problem_fingerprint`], the
//! fleet's placement key), domain planes
//! ([`crate::runtime::plane_fingerprint`], the delta-base key).  A
//! [`FixCache`] composes the two into a memo key
//! `(constraint fingerprint, input-plane fingerprint)` and stores the
//! *result* of enforcement: the fixpoint plane (or the UNSAT verdict)
//! plus the sweep count the recurrence took to reach it.
//!
//! Memoisation is sound because the AC/SAC closure is **unique** (the
//! paper's Prop. 1 — the same argument that makes probe backends
//! interchangeable): two enforcements of the same constraint network on
//! the same input plane can only ever produce the same fixpoint, the
//! same wipeout verdict, and the same joint sweep count.  A hit
//! therefore answers bit-identically to the execution it skipped.
//!
//! Three layers consult one of these (all through this one type, so
//! the eviction and poison-detection rules cannot drift):
//!
//! * the production executor thread and the chaos CPU-reference
//!   executor, before dispatching a fused `fixb*` execution — a hit
//!   skips the tensor round entirely and still counts toward
//!   conservation as a normal response;
//! * [`crate::ac::sac::SacParallel`] probe rounds, so repeated
//!   singleton probes across SAC iterations and search restarts
//!   short-circuit.  A probe *round* is itself content-addressed —
//!   `(constraint network, launch domains, probe list) → (verdict
//!   vector, counter delta)` — and closure uniqueness makes replaying
//!   a memoised round bit-identical to running it, work counters
//!   included ([`FixCache::insert_round`]/[`FixCache::lookup_round`]);
//! * the fleet tier, which owns one shared cache **per shard** —
//!   rendezvous-placed duplicate sessions share warm entries, and
//!   failover replays repopulate the survivors' caches.
//!
//! # Poison detection
//!
//! Every plane entry stores its own content fingerprint, computed at
//! insert.  A plane lookup re-fingerprints the resident plane before
//! serving it; a mismatch means the entry was corrupted after
//! admission (a torn write, a stray mutation, a bug) — the entry is
//! **evicted and reported as a miss**, never served.  The canary test
//! battery corrupts an entry deliberately and proves exactly that.
//!
//! ```
//! use rtac::coordinator::FixCache;
//!
//! let cache = FixCache::new(2);
//! assert!(cache.lookup_plane(1, 2).is_none(), "cold cache");
//! cache.insert_plane(1, 2, vec![1.0, 0.0], false, 3);
//! let hit = cache.lookup_plane(1, 2).expect("warm cache");
//! assert_eq!(hit.plane, vec![1.0, 0.0]);
//! assert_eq!(hit.iters, 3);
//! let stats = cache.stats();
//! assert_eq!((stats.hits, stats.misses), (1, 1));
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::ac::Counters;
use crate::runtime::plane_fingerprint;

/// Cumulative cache statistics, mirrored into
/// [`crate::coordinator::Metrics`] on the serving paths (`fixcache_*`
/// counters) and read directly by layers that carry no metrics sink
/// (the SAC probe loop, the bench cells).  `bytes` is the cumulative
/// volume **admitted** (a monotonic counter, like `shipped_f32`), not
/// a residency gauge — so per-shard stats aggregate by summation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FixCacheStats {
    /// Lookups answered from a resident entry.
    pub hits: u64,
    /// Lookups that found no (usable) entry — including plane lookups
    /// that found only a verdict entry, and poisoned entries that
    /// failed the fingerprint re-check.
    pub misses: u64,
    /// Entries evicted: LRU displacement under the capacity bound,
    /// plus poisoned entries ejected by the fingerprint re-check.
    /// Fault-injected wipes ([`FixCache::wipe`]) are *not* counted
    /// here — they are a chaos event, not cache pressure.
    pub evictions: u64,
    /// Bytes admitted across all inserts (entry header + plane
    /// payload), cumulative.
    pub bytes: u64,
}

/// What a plane lookup returns: everything the executor needs to
/// synthesise a [`crate::coordinator::Response`] without running the
/// recurrence — the fixpoint plane, the wipeout verdict, and the joint
/// sweep count of the execution that originally produced it (unique,
/// so replaying it keeps iteration accounting bit-identical to the
/// skipped run).
#[derive(Clone, Debug, PartialEq)]
pub struct CachedFixpoint {
    /// The enforced fixpoint plane, exactly as the original execution
    /// produced it.
    pub plane: Vec<f32>,
    /// True when the original enforcement wiped out (UNSAT).
    pub wiped: bool,
    /// Joint sweep count of the original enforcement.
    pub iters: i32,
    /// Work-counter delta of the original enforcement.  Executor plane
    /// entries carry the tensor-side accounting (`recurrences =
    /// iters`); probe-round entries carry the full delta the round
    /// contributed, so a hit replays counter state bit-identically.
    pub delta: Counters,
}

/// One resident memo entry.  `plane` is `None` for verdict-only
/// entries (SAC probe rounds record pass/fail + sweeps; the probe's
/// closure plane is never read back).
struct Entry {
    cons_fp: u64,
    input_fp: u64,
    plane: Option<Vec<f32>>,
    /// Content fingerprint of `plane` at admission — re-checked on
    /// every plane lookup (poison detection).  0 for verdict entries.
    plane_fp: u64,
    wiped: bool,
    iters: i32,
    /// Counter delta of the original enforcement (see
    /// [`CachedFixpoint::delta`]).
    delta: Counters,
}

impl Entry {
    /// Admission size: the fixed header plus the plane payload.
    fn bytes(&self) -> u64 {
        (std::mem::size_of::<Entry>()
            + self.plane.as_ref().map_or(0, |p| p.len() * std::mem::size_of::<f32>()))
            as u64
    }
}

/// The bounded store: at most `cap` entries, most-recently-used LAST
/// (the same `Vec`-scan LRU as the executor's `BaseSlots` — capacities
/// are tens to hundreds, where a scan beats a map and keeps recency
/// maintenance a `remove`+`push`).
struct Slots {
    cap: usize,
    entries: Vec<Entry>,
}

impl Slots {
    fn position(&self, cons_fp: u64, input_fp: u64) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| e.cons_fp == cons_fp && e.input_fp == input_fp)
    }
}

/// A bounded, LRU-evicting, content-addressed fixpoint cache, shared
/// across threads (`Arc<FixCache>`): the executor thread, K probe
/// workers, or every session of a fleet shard.  See the module docs
/// for the key derivation and the soundness argument; `0` configured
/// entries means "no cache" and is represented as `None` at the call
/// sites ([`FixCache::shared`]).
pub struct FixCache {
    slots: Mutex<Slots>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    bytes: AtomicU64,
}

impl std::fmt::Debug for FixCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stats = self.stats();
        f.debug_struct("FixCache")
            .field("len", &self.len())
            .field("stats", &stats)
            .finish()
    }
}

impl FixCache {
    /// A cache bounded at `entries` resident fixpoints (clamped to
    /// >= 1 — a zero-capacity cache is "no cache", spelled `None`;
    /// see [`FixCache::shared`]).
    pub fn new(entries: usize) -> FixCache {
        FixCache {
            slots: Mutex::new(Slots { cap: entries.max(1), entries: Vec::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    /// The configuration-boundary constructor: `--fixcache-entries 0`
    /// disables the cache, so `0` maps to `None` and every consult
    /// site stays a plain `if let Some(cache)`.
    pub fn shared(entries: usize) -> Option<Arc<FixCache>> {
        (entries > 0).then(|| Arc::new(FixCache::new(entries)))
    }

    /// Look up the fixpoint plane memoised under `(cons_fp,
    /// input_fp)`.  Refreshes the entry's recency on a hit.  Returns
    /// `None` (a counted miss) when the key is absent, resident only
    /// as a verdict entry, or **poisoned** — the resident plane no
    /// longer matches the fingerprint recorded at admission, in which
    /// case the entry is also evicted (counted) so corruption cannot
    /// be served later either.
    pub fn lookup_plane(&self, cons_fp: u64, input_fp: u64) -> Option<CachedFixpoint> {
        let mut slots = self.slots.lock().unwrap();
        let Some(i) = slots.position(cons_fp, input_fp) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        if slots.entries[i].plane.is_none() {
            // verdict-only entry: nothing to serve a plane lookup with
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        // poison detection: re-fingerprint the resident plane before
        // serving it; a mismatch evicts instead of answering
        let entry = &slots.entries[i];
        let plane = entry.plane.as_ref().expect("checked above");
        if plane_fingerprint(plane) != entry.plane_fp {
            slots.entries.remove(i);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let entry = slots.entries.remove(i);
        let hit = CachedFixpoint {
            plane: entry.plane.clone().expect("checked above"),
            wiped: entry.wiped,
            iters: entry.iters,
            delta: entry.delta,
        };
        slots.entries.push(entry);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(hit)
    }

    /// Look up just the wipeout verdict (+ sweep count) memoised under
    /// `(cons_fp, input_fp)` — the SAC probe-round consult: the merge
    /// loop needs pass/fail and the counter delta, never the probe's
    /// closure plane.  Served by plane entries too (a memoised plane
    /// implies its verdict).  Refreshes recency on a hit.
    pub fn lookup_verdict(&self, cons_fp: u64, input_fp: u64) -> Option<(bool, i32)> {
        let mut slots = self.slots.lock().unwrap();
        let Some(i) = slots.position(cons_fp, input_fp) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        let entry = slots.entries.remove(i);
        let verdict = (entry.wiped, entry.iters);
        slots.entries.push(entry);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(verdict)
    }

    /// Admit the fixpoint `plane` (with its wipeout verdict and sweep
    /// count) under `(cons_fp, input_fp)`.  Re-inserting a resident
    /// key replaces it in place (upgrading a verdict-only entry to a
    /// full plane entry); a fresh key under a full cache evicts the
    /// LRU entry.  Returns `(evicted, bytes_admitted)` so serving
    /// paths can mirror the accounting into their
    /// [`crate::coordinator::Metrics`].
    pub fn insert_plane(
        &self,
        cons_fp: u64,
        input_fp: u64,
        plane: Vec<f32>,
        wiped: bool,
        iters: i32,
    ) -> (bool, u64) {
        let plane_fp = plane_fingerprint(&plane);
        // the tensor-side counter accounting of a fused response is
        // exactly its joint sweep count
        let delta = Counters { recurrences: iters.max(0) as u64, ..Counters::default() };
        self.insert(Entry { cons_fp, input_fp, plane: Some(plane), plane_fp, wiped, iters, delta })
    }

    /// Admit one SAC probe *round*: the verdict vector (`true` = that
    /// probe's fixpoint stayed consistent, in probe order) plus the
    /// counter delta the round contributed, keyed by `(cons_fp,
    /// round_fp)` where `round_fp` fingerprints the launch domains and
    /// the probe list.  Stored as a 0.0/1.0 plane payload, so round
    /// entries get the same LRU, byte accounting, and poison-detection
    /// re-check as executor plane entries.
    pub fn insert_round(
        &self,
        cons_fp: u64,
        round_fp: u64,
        verdicts: &[bool],
        delta: &Counters,
    ) -> (bool, u64) {
        let plane: Vec<f32> = verdicts.iter().map(|&v| if v { 1.0 } else { 0.0 }).collect();
        let plane_fp = plane_fingerprint(&plane);
        self.insert(Entry {
            cons_fp,
            input_fp: round_fp,
            plane: Some(plane),
            plane_fp,
            wiped: false,
            iters: delta.recurrences.min(i32::MAX as u64) as i32,
            delta: *delta,
        })
    }

    /// Look up a memoised probe round (see [`FixCache::insert_round`]).
    /// Shares the plane-lookup internals, so a poisoned round entry is
    /// detected by the fingerprint re-check, evicted, and reported as a
    /// miss — a corrupted verdict vector is never replayed.
    pub fn lookup_round(&self, cons_fp: u64, round_fp: u64) -> Option<(Vec<bool>, Counters)> {
        let hit = self.lookup_plane(cons_fp, round_fp)?;
        Some((hit.plane.iter().map(|&v| v != 0.0).collect(), hit.delta))
    }

    /// Admit a verdict-only entry (no plane payload) — the SAC
    /// probe-round insert.  A resident plane entry for the same key is
    /// left intact (it already implies the verdict).  Returns
    /// `(evicted, bytes_admitted)` like [`FixCache::insert_plane`].
    pub fn insert_verdict(
        &self,
        cons_fp: u64,
        input_fp: u64,
        wiped: bool,
        iters: i32,
    ) -> (bool, u64) {
        let mut slots = self.slots.lock().unwrap();
        if let Some(i) = slots.position(cons_fp, input_fp) {
            // refresh recency; never downgrade a plane entry
            let entry = slots.entries.remove(i);
            slots.entries.push(entry);
            return (false, 0);
        }
        drop(slots);
        let delta = Counters { recurrences: iters.max(0) as u64, ..Counters::default() };
        self.insert(Entry { cons_fp, input_fp, plane: None, plane_fp: 0, wiped, iters, delta })
    }

    fn insert(&self, entry: Entry) -> (bool, u64) {
        let bytes = entry.bytes();
        let mut slots = self.slots.lock().unwrap();
        if let Some(i) = slots.position(entry.cons_fp, entry.input_fp) {
            slots.entries.remove(i);
            slots.entries.push(entry);
            self.bytes.fetch_add(bytes, Ordering::Relaxed);
            return (false, bytes);
        }
        let evicted = slots.entries.len() >= slots.cap;
        if evicted {
            slots.entries.remove(0);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        slots.entries.push(entry);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        (evicted, bytes)
    }

    /// Drop every resident entry — the fault-injection cache wipe
    /// (`FaultPlan::wipe_fixcache_at`).  Semantically invisible:
    /// every later lookup simply misses and re-derives.  Returns how
    /// many entries were wiped; they are *not* counted as evictions
    /// (a wipe is a chaos event, not cache pressure).
    pub fn wipe(&self) -> usize {
        let mut slots = self.slots.lock().unwrap();
        let n = slots.entries.len();
        slots.entries.clear();
        n
    }

    /// Resident entries right now (a gauge, unlike the cumulative
    /// [`FixCacheStats`]).
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cumulative statistics since construction.
    pub fn stats(&self) -> FixCacheStats {
        FixCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }

    /// Corrupt the resident plane stored under `(cons_fp, input_fp)`
    /// *without* updating its admission fingerprint — the canary
    /// battery's deliberate poisoning.  Returns true when an entry
    /// with a plane was found and corrupted.
    #[cfg(test)]
    pub(crate) fn poison(&self, cons_fp: u64, input_fp: u64) -> bool {
        let mut slots = self.slots.lock().unwrap();
        let Some(i) = slots.position(cons_fp, input_fp) else { return false };
        match slots.entries[i].plane.as_mut() {
            Some(plane) if !plane.is_empty() => {
                // flip one domain bit: 1.0 <-> 0.0
                plane[0] = if plane[0] == 0.0 { 1.0 } else { 0.0 };
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_miss_then_warm_hit_round_trips_the_fixpoint() {
        let cache = FixCache::new(4);
        assert!(cache.lookup_plane(7, 9).is_none());
        cache.insert_plane(7, 9, vec![1.0, 0.0, 1.0], true, 5);
        let hit = cache.lookup_plane(7, 9).expect("warm");
        assert_eq!(hit.plane, vec![1.0, 0.0, 1.0]);
        assert!(hit.wiped);
        assert_eq!(hit.iters, 5);
        // the verdict view serves plane entries too
        assert_eq!(cache.lookup_verdict(7, 9), Some((true, 5)));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 1, 0));
        assert!(s.bytes > 3 * 4, "admission bytes cover header + payload");
    }

    #[test]
    fn keys_are_content_addressed_on_both_halves() {
        let cache = FixCache::new(8);
        cache.insert_plane(1, 10, vec![1.0], false, 1);
        assert!(cache.lookup_plane(1, 11).is_none(), "different input plane");
        assert!(cache.lookup_plane(2, 10).is_none(), "different constraint network");
        assert!(cache.lookup_plane(1, 10).is_some());
    }

    #[test]
    fn lru_eviction_under_the_cap_and_recency_refresh() {
        let cache = FixCache::new(2);
        cache.insert_plane(0, 1, vec![1.0], false, 1);
        cache.insert_plane(0, 2, vec![0.0], false, 1);
        // touch key 1 so key 2 becomes the LRU
        assert!(cache.lookup_plane(0, 1).is_some());
        let (evicted, _) = cache.insert_plane(0, 3, vec![1.0], false, 1);
        assert!(evicted, "a third key under cap 2 must evict");
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup_plane(0, 2).is_none(), "the LRU key is gone");
        assert!(cache.lookup_plane(0, 1).is_some(), "the refreshed key survived");
        assert!(cache.lookup_plane(0, 3).is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn capacity_one_still_serves_back_to_back_repeats() {
        let cache = FixCache::new(1);
        cache.insert_plane(0, 1, vec![1.0], false, 2);
        assert!(cache.lookup_plane(0, 1).is_some());
        assert!(cache.lookup_plane(0, 1).is_some(), "repeat hits keep hitting");
        let (evicted, _) = cache.insert_plane(0, 2, vec![0.0], false, 2);
        assert!(evicted);
        assert!(cache.lookup_plane(0, 1).is_none());
        assert!(cache.lookup_plane(0, 2).is_some());
    }

    #[test]
    fn reinserting_a_resident_key_replaces_without_eviction() {
        let cache = FixCache::new(1);
        cache.insert_plane(0, 1, vec![1.0], false, 2);
        let (evicted, _) = cache.insert_plane(0, 1, vec![1.0], false, 2);
        assert!(!evicted, "a replace is not an eviction");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().evictions, 0);
    }

    #[test]
    fn verdict_entries_serve_verdicts_but_never_planes() {
        let cache = FixCache::new(4);
        cache.insert_verdict(3, 4, true, 7);
        assert!(cache.lookup_plane(3, 4).is_none(), "no plane payload to serve");
        assert_eq!(cache.lookup_verdict(3, 4), Some((true, 7)));
        // upgrading to a plane entry serves both views
        cache.insert_plane(3, 4, vec![0.0, 0.0], true, 7);
        assert_eq!(cache.len(), 1, "the upgrade replaced in place");
        assert!(cache.lookup_plane(3, 4).is_some());
        // a verdict re-insert must not downgrade the plane entry
        cache.insert_verdict(3, 4, true, 7);
        assert!(cache.lookup_plane(3, 4).is_some());
    }

    #[test]
    fn wipe_clears_residency_but_counts_no_evictions() {
        let cache = FixCache::new(4);
        cache.insert_plane(0, 1, vec![1.0], false, 1);
        cache.insert_verdict(0, 2, false, 1);
        assert_eq!(cache.wipe(), 2);
        assert!(cache.is_empty());
        assert_eq!(cache.stats().evictions, 0, "a wipe is a chaos event, not pressure");
        assert!(cache.lookup_plane(0, 1).is_none(), "wiped entries are gone");
    }

    #[test]
    fn poisoned_entry_is_detected_evicted_and_never_served() {
        let cache = FixCache::new(4);
        cache.insert_plane(5, 6, vec![1.0, 0.0, 1.0, 1.0], false, 3);
        assert!(cache.poison(5, 6), "the canary must corrupt a resident plane");
        // the fingerprint re-check fires: no hit, entry ejected
        assert!(cache.lookup_plane(5, 6).is_none(), "corruption must never be served");
        assert_eq!(cache.len(), 0, "the poisoned entry was evicted");
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.evictions, 1, "poison ejection is a counted eviction");
        // and the slot is usable again: a fresh insert serves cleanly
        cache.insert_plane(5, 6, vec![1.0, 0.0, 1.0, 1.0], false, 3);
        assert_eq!(cache.lookup_plane(5, 6).unwrap().plane, vec![1.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn round_entries_replay_verdicts_and_counter_delta() {
        let cache = FixCache::new(4);
        let delta = Counters { recurrences: 6, removals: 2, support_checks: 40, revisions: 0 };
        assert!(cache.lookup_round(1, 2).is_none(), "cold round consult");
        cache.insert_round(1, 2, &[true, false, true], &delta);
        let (verdicts, replayed) = cache.lookup_round(1, 2).expect("warm round");
        assert_eq!(verdicts, vec![true, false, true]);
        assert_eq!(replayed, delta, "the hit replays the full counter delta");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn poisoned_round_entry_is_detected_not_replayed() {
        let cache = FixCache::new(4);
        let delta = Counters { recurrences: 3, ..Counters::default() };
        cache.insert_round(8, 9, &[true, true], &delta);
        assert!(cache.poison(8, 9), "round payloads are poisonable planes");
        assert!(cache.lookup_round(8, 9).is_none(), "a corrupted verdict vector is never served");
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.is_empty());
    }

    #[test]
    fn shared_maps_zero_to_none() {
        assert!(FixCache::shared(0).is_none(), "--fixcache-entries 0 disables");
        let cache = FixCache::shared(16).expect("nonzero capacity");
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn stats_bytes_accumulate_monotonically() {
        let cache = FixCache::new(1);
        cache.insert_plane(0, 1, vec![1.0; 8], false, 1);
        let b1 = cache.stats().bytes;
        cache.insert_plane(0, 2, vec![1.0; 8], false, 1); // evicts, still admits
        let b2 = cache.stats().bytes;
        assert!(b2 > b1, "bytes is cumulative admitted volume, not residency");
    }
}
