//! The coordination service — the L3 system contribution.
//!
//! One [`Coordinator`] serves one CSP instance ("session").  Parallel
//! search workers (or remote callers via `rtac serve`) submit
//! arc-consistency requests — a domains plane at the session's shape
//! bucket — and the coordinator **dynamically batches** concurrent
//! requests into one fused `fixpoint_batched` XLA execution, exactly the
//! way a vLLM-style router fuses decode steps: the constraint tensor is
//! resident (uploaded once per session), only the small vars planes move
//! per request.
//!
//! Threading: `PjRtClient` is not `Send`, so a dedicated executor thread
//! owns the `Runtime`, the compiled executables and the cached constraint
//! tensor; an MPSC channel carries requests in, and each request carries
//! its own response sender.  Batching policy (size + deadline) is applied
//! on the executor thread between `recv`s — there is no separate batcher
//! thread to hand off through, which keeps p50 latency at one channel
//! hop.
//!
//! # Session contracts
//!
//! * **Startup fence.**  [`Coordinator::start`] returning `Ok` means the
//!   executor thread finished its *entire* init — runtime load, artifact
//!   compilation, and the constraint-tensor upload — because the single
//!   ready-send site (`send_ready`) fires strictly after init resolves.
//!   A broken artifact dir or a failed upload surfaces there as `Err`,
//!   never as a dead session whose every later submit mysteriously
//!   fails.
//! * **Occupancy.**  Every [`Response`] carries `batch_real` (real
//!   requests fused into the serving execution) and `batch_capacity`
//!   (the compiled slot count, padding included), so callers can compute
//!   [`Response::occupancy`] without manifest access.
//! * **Conservation.**  At quiescence, `requests == responses +
//!   dropped_requests` ([`crate::coordinator::MetricsSnapshot::conserved`]):
//!   each submitted plane is either answered or explicitly accounted as
//!   dropped by one of the counted causes — a failed fused execution, a
//!   stale delta (see below), an expired per-request deadline
//!   (`timed_out_requests`), or a moribund session drop
//!   (`restart_dropped_requests`).  The invariant holds **across
//!   executor restarts** (§Supervision below).  A graceful shutdown
//!   cannot strand requests (the executor's channel drains buffered
//!   messages before disconnecting); the only uncounted path is an
//!   executor panic, which aborts the session.
//! * **Supervision & recovery.**  A failed-execution streak (or
//!   [`Handle::force_restart`]) restarts the executor's XLA state up to
//!   [`BatchPolicy::max_restarts`] times with exponential backoff, then
//!   re-hydrates the session deterministically: the constraint tensor
//!   is re-uploaded, the host-resident (content-fingerprinted)
//!   base-slot map is replayed (`replayed_bases`), and in-flight
//!   requests are re-enqueued — except those past their deadline, which
//!   are dropped and counted.  An exhausted budget turns the session
//!   *moribund*: every further request is dropped and counted, so
//!   conservation survives even total executor loss, and clients
//!   degrade to CPU engines.
//! * **Deadlines.**  No `Handle` blocking call waits forever: every
//!   `enforce_*_blocking` wait is bounded by
//!   [`BatchPolicy::request_timeout`], and the executor drops (and
//!   counts) queued requests that outlive the same deadline, so the two
//!   sides agree on the accounting.
//!
//! # Delta planes and per-client base slots
//!
//! Two workloads re-ship planes that differ from a plane the executor
//! already holds in only a few rows: a batched-SAC probe round (K
//! planes = one launch plane with one row replaced each) and a MAC
//! search worker (consecutive nodes differ in the rows the last
//! assignment/backtrack touched).  Delta submission ships only the
//! changed rows ([`crate::runtime::PlaneDelta`]); the executor
//! reconstructs full planes against a cached base before fusing.
//!
//! The base cache is a **per-client slot map** (see `BaseSlots`).  A
//! client identity ([`ClientId`]) is issued by [`Handle::attach`] at
//! session attach; every delta-path call carries it:
//!
//! * [`Handle::upload_base`] caches a base in the *calling client's*
//!   slot, keyed by the base's content fingerprint
//!   ([`crate::runtime::plane_fingerprint`]).  Re-uploading replaces
//!   that slot only — other clients' slots are untouched, so several
//!   delta writers coexist on one session without cross-invalidating.
//! * [`Handle::submit_batch_delta`] ships a probe round (K deltas
//!   against the client's cached base; the slot is left unchanged).
//! * [`Handle::submit_delta`] ships one **chained** delta (a search
//!   node): after reconstruction the client's slot *advances* to the
//!   reconstructed plane, so the next node diffs against this one —
//!   base once, then row diffs for the rest of the search.
//! * A delta whose fingerprint misses its client's slot (never
//!   uploaded, evicted, or out of sync) is **dropped** (counted as
//!   `stale_deltas` *and* `dropped_requests`, per client and in
//!   aggregate, so conservation holds) rather than silently applied to
//!   the wrong base.  Clients fall back to re-uploading a full base.
//! * The slot map is bounded: `BatchPolicy::base_slots` caps resident
//!   bases (validated `>= 1` at startup, alongside `max_batch`); when a
//!   *new* client uploads into a full map the least-recently-used other
//!   slot is evicted (counted as `base_evictions`).  An evicted
//!   client's next delta drops as stale and the client re-uploads.
//!
//! ```
//! use rtac::coordinator::Response;
//! use std::time::Duration;
//!
//! // what a client sees back from a fused execution: 6 real probes
//! // served from an 8-slot compiled batch
//! let r = Response {
//!     plane: vec![1.0, 0.0],
//!     status: 0,
//!     iters: 3,
//!     batch_real: 6,
//!     batch_capacity: 8,
//!     queue_time: Duration::ZERO,
//!     total_time: Duration::ZERO,
//! };
//! assert!(!r.wiped());
//! assert_eq!(r.occupancy(), 0.75);
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::core::Problem;
use crate::runtime::{encode_cons, Bucket, Kind, Manifest, PlaneDelta, Runtime, STATUS_WIPEOUT};

/// Identity of one delta-writing client on a session, issued by
/// [`Handle::attach`].  Keys that client's base slot in the executor's
/// slot map and its per-client row in
/// [`crate::coordinator::MetricsSnapshot::clients`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ClientId(u64);

impl ClientId {
    /// The raw id (stable for the session's lifetime; also the
    /// per-client metrics key).
    pub fn id(&self) -> u64 {
        self.0
    }

    /// Test-only constructor — production ids come from
    /// [`Handle::attach`] so they are session-unique.
    #[cfg(test)]
    pub(crate) fn test(id: u64) -> ClientId {
        ClientId(id)
    }
}

impl std::fmt::Display for ClientId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "c{}", self.0)
    }
}

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Upper bound on fused requests.  Must be >= 1 (rejected at
    /// [`Coordinator::start`]); values above the largest compiled
    /// `fixb*` size are clamped by the executor.  User-facing callers
    /// (`rtac serve --max-batch`) run [`Coordinator::validate_policy`]
    /// first so an explicit out-of-range value fails fast at startup
    /// instead of being silently clamped.
    pub max_batch: usize,
    /// How long the executor waits for batch-mates after the first
    /// request arrives.  0 disables the wait — requests already sitting
    /// on the queue (e.g. a contiguous [`Handle::submit_batch`] probe
    /// batch) still fuse, because the executor drains the queue greedily
    /// before executing.
    pub max_wait: Duration,
    /// Derive the effective (max_batch, max_wait) from the observed
    /// queue demand instead of the fixed values above: solo traffic
    /// stops paying the coalescing wait, bursty traffic grows the batch
    /// cap toward the largest compiled size.  `max_batch` stays the hard
    /// upper bound; `max_wait` the longest wait.  (Implemented by the
    /// executor-internal `AdaptiveBatcher`, an EWMA over queue demand.)
    pub adaptive: bool,
    /// Cap on resident delta-base planes (one slot per delta-writing
    /// client; see the module docs).  Bounds executor memory at
    /// `base_slots × vars_len × 4` bytes.  Must be >= 1 — validated at
    /// [`Coordinator::start`] alongside `max_batch`; when a new client
    /// uploads into a full map, the least-recently-used other slot is
    /// evicted.
    pub base_slots: usize,
    /// Per-request deadline (`rtac serve --request-timeout`).  Every
    /// [`Handle`] blocking wait (`enforce_blocking`,
    /// `enforce_delta_blocking`, both batch variants) is bounded by it
    /// and returns a named timeout error when it expires; the executor
    /// independently drops — and counts as `timed_out_requests`, a
    /// counted drop cause — any queued request whose deadline passed
    /// (e.g. while a restart backoff ran), so `requests == responses +
    /// dropped` holds whichever side notices first.
    pub request_timeout: Duration,
    /// Executor restarts the supervisor may spend over the session's
    /// lifetime (`rtac serve --max-restarts`).  A failed-execution
    /// streak (or [`Handle::force_restart`]) triggers a restart with
    /// exponential backoff and a full session re-hydration (constraint
    /// tensor re-upload, base-slot replay, in-flight re-enqueue).  Once
    /// the budget is exhausted the session goes *moribund*: every
    /// remaining and future request is dropped and counted
    /// (`restart_dropped_requests`) so conservation still holds, and
    /// serve workers degrade to CPU engines.
    pub max_restarts: u32,
    /// Capacity of the session's content-addressed fixpoint cache
    /// ([`crate::coordinator::FixCache`], `rtac serve
    /// --fixcache-entries`): resident `(constraint fingerprint,
    /// input-plane fingerprint) → fixpoint` memo entries, LRU-evicted.
    /// The executor consults it before dispatching a fused execution —
    /// a hit answers the request as a normal response (counted
    /// `fixcache_hits`; conservation unchanged) without touching the
    /// tensor route.  **0 disables the cache** (the default: memo
    /// capacity is an opt-in serving knob, not a solver default).
    /// Sound because the AC closure is unique — see the `fixcache`
    /// module docs.
    pub fixcache_entries: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(300),
            adaptive: false,
            base_slots: 8,
            request_timeout: Duration::from_secs(30),
            max_restarts: 3,
            fixcache_entries: 0,
        }
    }
}

/// The executor's per-client delta-base cache: at most `cap` resident
/// `(client, fingerprint, plane)` slots, least-recently-used first.
/// One slot per client; an upload for a client that already holds a
/// slot *replaces* it (the invalidation rule), an upload for a new
/// client under a full map evicts the LRU slot of some other client.
/// Lookups and uploads both refresh recency.  `Vec`-based on purpose:
/// `cap` is single digits to low tens, where a scan beats a map.
pub(crate) struct BaseSlots {
    cap: usize,
    /// `(client, fingerprint, plane)`, most-recently-used LAST.
    slots: Vec<(ClientId, u64, Vec<f32>)>,
}

impl BaseSlots {
    pub(crate) fn new(cap: usize) -> BaseSlots {
        BaseSlots { cap: cap.max(1), slots: Vec::new() }
    }

    /// Cache `plane` as `client`'s base.  Returns `true` when another
    /// client's LRU slot was evicted to make room (the caller counts it
    /// as `base_evictions`).
    pub(crate) fn insert(&mut self, client: ClientId, fp: u64, plane: Vec<f32>) -> bool {
        if let Some(i) = self.slots.iter().position(|(c, _, _)| *c == client) {
            self.slots.remove(i);
            self.slots.push((client, fp, plane));
            return false;
        }
        let evicted = self.slots.len() >= self.cap;
        if evicted {
            self.slots.remove(0);
        }
        self.slots.push((client, fp, plane));
        evicted
    }

    /// Look up `client`'s slot and refresh its recency.  `None` when the
    /// client never uploaded a base or its slot was evicted.
    pub(crate) fn get(&mut self, client: ClientId) -> Option<&(ClientId, u64, Vec<f32>)> {
        let i = self.slots.iter().position(|(c, _, _)| *c == client)?;
        let slot = self.slots.remove(i);
        self.slots.push(slot);
        self.slots.last()
    }

    /// Resident slots (for tests and reporting).
    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    /// Drop every resident slot — the fault-injection "base-cache
    /// wipe".  After it, every client's next delta drops as stale and
    /// the client re-uploads (the same observable state as a restart
    /// that lost the cache).  Returns how many slots were wiped.
    pub(crate) fn wipe(&mut self) -> usize {
        let n = self.slots.len();
        self.slots.clear();
        n
    }
}

/// Supervision bookkeeping (§Supervision & recovery): restart budget,
/// failed-execution streak, and exponential backoff.  Pure state — no
/// clock, no channel — shared by the real executor thread and the
/// chaos-wrapped CPU-reference executor in the tests, so the fault
/// harness exercises the *same* restart decisions production takes.
pub(crate) struct Supervisor {
    max_restarts: u32,
    restarts: u32,
    failed_streak: u32,
}

impl Supervisor {
    /// Consecutive failed fused executions that trigger a restart.  One
    /// failure can be a transient input problem; a streak means the
    /// executor itself is sick.
    pub(crate) const FAILED_STREAK_LIMIT: u32 = 2;
    /// Backoff before the first restart; doubles per spent restart so a
    /// crash-looping executor backs off instead of thrashing.
    pub(crate) const BASE_BACKOFF: Duration = Duration::from_millis(10);

    pub(crate) fn new(max_restarts: u32) -> Supervisor {
        Supervisor { max_restarts, restarts: 0, failed_streak: 0 }
    }

    /// A fused execution succeeded: the streak resets (only
    /// *consecutive* failures indicate executor sickness).
    pub(crate) fn on_batch_ok(&mut self) {
        self.failed_streak = 0;
    }

    /// A fused execution failed.  True when the streak has reached the
    /// restart threshold.
    pub(crate) fn on_batch_failed(&mut self) -> bool {
        self.failed_streak += 1;
        self.failed_streak >= Self::FAILED_STREAK_LIMIT
    }

    /// Spend one restart from the budget: returns the backoff to sleep
    /// before re-initialising, or `None` when the budget is exhausted
    /// (the session goes moribund).
    pub(crate) fn begin_restart(&mut self) -> Option<Duration> {
        if self.restarts >= self.max_restarts {
            return None;
        }
        let backoff = Self::BASE_BACKOFF * 2u32.saturating_pow(self.restarts);
        self.restarts += 1;
        self.failed_streak = 0;
        Some(backoff)
    }

    /// Restarts spent so far.
    pub(crate) fn restarts(&self) -> u32 {
        self.restarts
    }
}

/// §Adaptive batching: derives the effective batching knobs from the
/// observed queue demand (an EWMA of how many requests were pending at
/// each execute decision) instead of a fixed policy.
///
/// * `max_wait` — when the demand says requests arrive alone, waiting
///   for batch-mates only adds latency, so the wait drops to zero; once
///   fusible traffic shows up the configured wait comes back.
/// * `max_batch` — aimed at [`AdaptiveBatcher::HEADROOM`]× the demand
///   (rounded up to a compiled batch size) so the executor stops
///   coalescing at a size traffic can actually fill, while bursts keep
///   enough headroom to grow the cap back within a few observations.
///
/// Pure bookkeeping (no clock, no channel) so the policy is unit-tested
/// independently of the executor loop.
pub(crate) struct AdaptiveBatcher {
    /// Hard caps from the configured policy.
    cap_batch: usize,
    cap_wait: Duration,
    /// EWMA of queue demand at execute decisions; `None` before the
    /// first observation (start wide open: largest batch, full wait).
    demand: Option<f64>,
}

impl AdaptiveBatcher {
    const ALPHA: f64 = 0.25;
    const HEADROOM: f64 = 2.0;
    /// Below this demand the traffic is effectively solo and the
    /// coalescing wait is pure latency.
    const SOLO_DEMAND: f64 = 1.5;

    pub(crate) fn new(policy: &BatchPolicy) -> AdaptiveBatcher {
        AdaptiveBatcher { cap_batch: policy.max_batch, cap_wait: policy.max_wait, demand: None }
    }

    /// Record the queue demand observed at one execute decision.
    pub(crate) fn observe(&mut self, demand: usize) {
        let d = demand as f64;
        self.demand = Some(match self.demand {
            None => d,
            Some(prev) => Self::ALPHA * d + (1.0 - Self::ALPHA) * prev,
        });
    }

    /// Effective batch cap given the compiled sizes (ascending, deduped).
    pub(crate) fn max_batch(&self, compiled: &[usize]) -> usize {
        let largest = compiled.last().copied().unwrap_or(1).min(self.cap_batch).max(1);
        let Some(demand) = self.demand else {
            return largest;
        };
        let want = (demand * Self::HEADROOM).ceil().max(1.0) as usize;
        compiled
            .iter()
            .copied()
            .find(|&b| b >= want)
            .unwrap_or(largest)
            .min(largest)
    }

    /// Effective coalescing wait.
    pub(crate) fn max_wait(&self) -> Duration {
        match self.demand {
            Some(d) if d < Self::SOLO_DEMAND => Duration::ZERO,
            _ => self.cap_wait,
        }
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            policy: BatchPolicy::default(),
        }
    }
}

/// Client→executor message.  `pub(crate)` so the offline chaos
/// reference executors ([`crate::coordinator::chaos`]) can serve the
/// exact wire protocol the production executor thread serves.
pub(crate) enum Msg {
    /// One enforcement request (full plane or delta).
    Req(Request),
    /// Cache `plane` as `client`'s delta base under fingerprint `fp`,
    /// replacing that client's previous slot (the invalidation rule of
    /// the delta protocol — see the module docs).  Produces no response
    /// of its own.
    Base { client: ClientId, fp: u64, plane: Vec<f32> },
    /// Restart and re-hydrate the session as if the executor had just
    /// crashed ([`Handle::force_restart`]) — the live measurement hook
    /// behind the `recovery_restart` bench cell.  Spends one unit of
    /// the restart budget; produces no response of its own.
    ForceRestart,
}

/// A request: one domains plane to enforce.
pub(crate) struct Request {
    pub(crate) payload: Payload,
    pub(crate) submitted: Instant,
    pub(crate) resp: mpsc::Sender<Response>,
}

/// The plane a request carries: materialised, or in delta form against
/// the submitting client's cached base plane.
pub(crate) enum Payload {
    Full(Vec<f32>),
    Delta {
        client: ClientId,
        delta: PlaneDelta,
        /// Chain semantics ([`Handle::submit_delta`]): after
        /// reconstruction the client's slot advances to the
        /// reconstructed plane, so the *next* delta diffs against this
        /// one.  Probe rounds ([`Handle::submit_batch_delta`]) leave
        /// the slot unchanged — every probe edits the same base.
        advance: bool,
    },
}

impl Payload {
    /// The submitting client, for per-client drop/response accounting
    /// (full planes are unattributed).
    pub(crate) fn client(&self) -> Option<ClientId> {
        match self {
            Payload::Full(_) => None,
            Payload::Delta { client, .. } => Some(*client),
        }
    }
}

/// Resolve a request payload into a full plane against the per-client
/// base slots.  `None` means the payload is a delta whose base
/// fingerprint misses its client's slot (stale, evicted, or never
/// uploaded) or is malformed — the request must be dropped, never
/// guessed at.  Shared by the executor thread and the offline protocol
/// tests, so both resolve identically.
///
/// The base was fingerprinted once at upload and the cached key is
/// compared here, so rows are spliced directly instead of going
/// through [`PlaneDelta::apply`] (which would re-hash the whole cached
/// plane per request — K redundant O(n·d) passes per probe round on
/// the executor's serving path).  An advancing delta re-fingerprints
/// only its *reconstructed* plane, once, to key the client's new slot.
pub(crate) fn resolve_payload(
    payload: Payload,
    slots: &mut BaseSlots,
    bucket: Bucket,
) -> Option<Vec<f32>> {
    match payload {
        Payload::Full(plane) => Some(plane),
        Payload::Delta { client, delta, advance } => {
            let (_, fp, base_plane) = slots.get(client)?;
            if *fp != delta.base_fp
                || delta.validate(bucket).is_err()
                || base_plane.len() != bucket.vars_len()
            {
                return None;
            }
            let mut plane = base_plane.clone();
            for (var, row) in &delta.rows {
                let start = var * bucket.d;
                plane[start..start + bucket.d].copy_from_slice(row);
            }
            if advance {
                let next_fp = crate::runtime::plane_fingerprint(&plane);
                slots.insert(client, next_fp, plane.clone());
            }
            Some(plane)
        }
    }
}

/// Client-side stale-drop watermark: mirrors one client's
/// `stale_deltas` metrics counter so the serving hot path never locks
/// the metrics on success.  [`StaleTracker::absorb_stale_drop`] is
/// read only in error branches and classifies a failed delta call as
/// "my slot went stale/evicted: re-upload and retry" vs "the session
/// failed: fatal".  The counter only advances when one of the owning
/// client's own deltas drops, and every such drop surfaces to that
/// client as an error, so the watermark stays exact — both delta
/// clients ([`crate::coordinator::TensorEngine`] and the SAC probe
/// backend) embed this one implementation.
pub struct StaleTracker {
    client: ClientId,
    seen: u64,
}

impl StaleTracker {
    /// Attach a fresh client on `handle` and track its drops.
    pub fn attach(handle: &Handle) -> StaleTracker {
        StaleTracker { client: handle.attach(), seen: 0 }
    }

    /// The tracked client id (what the delta-path [`Handle`] calls
    /// take).
    pub fn client(&self) -> ClientId {
        self.client
    }

    /// True iff the client's stale counter advanced past the watermark
    /// — i.e. the just-failed call (or a tail of the just-retried
    /// round) died to a stale/evicted base slot.  Absorbs the advance,
    /// so the next failure is classified against the new baseline.
    pub fn absorb_stale_drop(&mut self, handle: &Handle) -> bool {
        let now = handle.client_stale_deltas(self.client);
        if now > self.seen {
            self.seen = now;
            true
        } else {
            false
        }
    }
}

/// A response: the enforced plane plus run metadata.
#[derive(Clone, Debug)]
pub struct Response {
    pub plane: Vec<f32>,
    /// 0 = consistent, 1 = wipeout (see `runtime::STATUS_*`).
    pub status: i32,
    /// Joint sweep count of the batch that served this request.
    pub iters: i32,
    /// *Real* requests fused into the execution that served this request
    /// (padded slots excluded).
    pub batch_real: usize,
    /// Compiled capacity of that execution, padding included — so the
    /// call site can compute fused-batch occupancy
    /// ([`Response::occupancy`]) without access to the manifest.
    pub batch_capacity: usize,
    pub queue_time: Duration,
    pub total_time: Duration,
}

impl Response {
    pub fn wiped(&self) -> bool {
        self.status == STATUS_WIPEOUT
    }

    /// Fraction of the serving execution's slots holding real requests.
    pub fn occupancy(&self) -> f64 {
        self.batch_real as f64 / self.batch_capacity.max(1) as f64
    }
}

/// Cloneable client handle to a running coordinator.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Msg>,
    pub bucket: Bucket,
    pub metrics: Arc<Metrics>,
    /// Batch sizes the session's `fixb*` artifacts were compiled for
    /// (ascending, deduped) — the capacities a fused round can actually
    /// occupy.  Cost models (the mixed probe scheduler) read the largest
    /// entry as the tensor route's amortisation ceiling.
    pub compiled_batches: Vec<usize>,
    /// The session's resident delta-base cap
    /// ([`BatchPolicy::base_slots`]) — how many delta-writing clients
    /// can coexist without LRU eviction.  Multi-client callers
    /// (`search::parallel`) read this to decide between delta and
    /// full-plane shipping up front instead of thrashing the slot map.
    pub base_slots: usize,
    /// The session's per-request deadline
    /// ([`BatchPolicy::request_timeout`]): every blocking wait on this
    /// handle is bounded by it, and the executor drops (and counts)
    /// queued requests that outlive it — no `Handle` blocking call
    /// waits forever.
    pub request_timeout: Duration,
    /// Issues session-unique [`ClientId`]s ([`Handle::attach`]); shared
    /// by every clone of this handle.
    next_client: Arc<AtomicU64>,
}

impl Handle {
    /// Construct a handle wired to a raw message channel, with fresh
    /// metrics and the compiled-batch capacities of the offline
    /// reference executors.  The session side of the channel must be
    /// served by something speaking the [`Msg`] protocol — the chaos /
    /// CPU-reference executors ([`crate::coordinator::chaos`]), which
    /// the fleet tier and the protocol test batteries run where
    /// compiled artifacts are unavailable.  `Coordinator::start`
    /// remains the only constructor that spawns the production
    /// executor thread.
    pub(crate) fn for_reference_executor(
        bucket: Bucket,
        base_slots: usize,
        request_timeout: Duration,
    ) -> (Handle, mpsc::Receiver<Msg>) {
        let (tx, rx) = mpsc::channel();
        let handle = Handle {
            tx,
            bucket,
            metrics: Arc::new(Metrics::new()),
            compiled_batches: vec![1, 2, 4],
            base_slots,
            request_timeout,
            next_client: Arc::new(AtomicU64::new(0)),
        };
        (handle, rx)
    }

    /// Attach a delta-writing client to the session: issues a fresh,
    /// session-unique [`ClientId`] that keys the client's base slot and
    /// its per-client metrics row.  Attach once per logical writer (a
    /// probe backend, a search worker's engine) and pass the id to
    /// every [`Handle::upload_base`] / [`Handle::submit_delta`] /
    /// [`Handle::submit_batch_delta`] call.
    pub fn attach(&self) -> ClientId {
        ClientId(self.next_client.fetch_add(1, Ordering::Relaxed))
    }

    /// Submit a plane; returns a receiver for the response.
    pub fn submit(&self, plane: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if plane.len() != self.bucket.vars_len() {
            bail!(
                "plane has {} values, session bucket wants {}",
                plane.len(),
                self.bucket.vars_len()
            );
        }
        let shipped = plane.len();
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Req(Request {
                payload: Payload::Full(plane),
                submitted: Instant::now(),
                resp: rtx,
            }))
            .map_err(|_| self.executor_gone_err())?;
        // count only planes that reached the queue
        self.metrics.on_submit(None, shipped, false);
        Ok(rrx)
    }

    /// The executor's request channel is closed: it exited (or the
    /// session was shut down).  Diagnose *why* from the shared metrics
    /// so callers see more than a bare channel error.
    fn executor_gone_err(&self) -> anyhow::Error {
        let m = self.metrics.snapshot();
        if m.failed_batches > 0 {
            anyhow!(
                "coordinator executor is gone after {} failed fused execution(s) \
                 ({} request(s) dropped; see the rtac-executor log)",
                m.failed_batches,
                m.dropped_requests
            )
        } else {
            anyhow!("coordinator is shut down (executor thread exited)")
        }
    }

    /// A submitted request's responder was dropped without an answer:
    /// its fused execution failed, it was a delta probe against a stale
    /// base, it outlived its deadline executor-side, the session went
    /// moribund (restart budget exhausted), or the executor exited with
    /// the request in flight.  The
    /// counters are cumulative over the session, so when more than one
    /// cause has ever occurred the error lists every candidate instead
    /// of guessing which one claimed *this* request.
    pub(crate) fn dropped_err(&self) -> anyhow::Error {
        let m = self.metrics.snapshot();
        let mut causes = Vec::new();
        if m.failed_batches > 0 {
            causes.push(format!(
                "{} fused execution(s) failed on the executor (see the rtac-executor log)",
                m.failed_batches
            ));
        }
        if m.stale_deltas > 0 {
            causes.push(format!(
                "{} delta(s) referenced a stale/unknown base plane (slot evicted \
                 under the base_slots cap, or the client re-uploaded/advanced past \
                 it — re-upload the base and resubmit)",
                m.stale_deltas
            ));
        }
        if m.timed_out_requests > 0 {
            causes.push(format!(
                "{} request(s) outlived the {:?} request_timeout deadline on the \
                 executor (queued through a hang or a restart backoff)",
                m.timed_out_requests, self.request_timeout
            ));
        }
        if m.restart_dropped_requests > 0 {
            causes.push(format!(
                "{} request(s) dropped with the executor's restart budget exhausted \
                 after {} restart(s) — the session is moribund; degrade to a CPU \
                 engine or start a fresh session",
                m.restart_dropped_requests, m.executor_restarts
            ));
        }
        if causes.is_empty() {
            anyhow!(
                "coordinator executor exited before answering (session shut down with \
                 the request in flight)"
            )
        } else {
            anyhow!(
                "coordinator dropped the request ({} dropped so far this session): {}",
                m.dropped_requests,
                causes.join("; ")
            )
        }
    }

    /// Deadline-bounded response wait shared by every
    /// `enforce_*_blocking` call: no `Handle` blocking call may wait
    /// past the session's per-request deadline
    /// ([`BatchPolicy::request_timeout`]).  A disconnected responder is
    /// a *dropped* request (the executor accounted for it); an expired
    /// deadline is a *timed-out* wait — the executor will drop and
    /// count the request as `timed_out_requests` when it reaches it, or
    /// answer into the abandoned receiver, so conservation holds either
    /// way.
    fn recv_deadline(&self, rx: &mpsc::Receiver<Response>, deadline: Instant) -> Result<Response> {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(resp) => Ok(resp),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(self.dropped_err()),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(anyhow!(
                "request timed out after {:?} (BatchPolicy::request_timeout): the \
                 executor did not answer before the per-request deadline — it is \
                 hung, mid-restart, or the queue outgrew the deadline",
                self.request_timeout
            )),
        }
    }

    /// Ask the executor to restart and re-hydrate its session as if it
    /// had just crashed (§Supervision & recovery) — the live
    /// measurement hook behind the `recovery_restart` bench cell.
    /// Spends one unit of the session's restart budget
    /// ([`BatchPolicy::max_restarts`]).  Returns once the message is
    /// queued; the next enforcement blocks until the restarted session
    /// serves it, which is exactly what the bench times.
    pub fn force_restart(&self) -> Result<()> {
        self.tx.send(Msg::ForceRestart).map_err(|_| self.executor_gone_err())
    }

    /// Submit and block (deadline-bounded) for the result.
    pub fn enforce_blocking(&self, plane: Vec<f32>) -> Result<Response> {
        let deadline = Instant::now() + self.request_timeout;
        let rx = self.submit(plane)?;
        self.recv_deadline(&rx, deadline)
    }

    /// Submit several planes back-to-back — the batched-probe path.
    ///
    /// A SAC enforcement produces K independent singleton probes at
    /// once (see `ac/sac.rs`); submitting them through this path puts
    /// them on the executor queue contiguously, so the dynamic batcher
    /// coalesces them into as few fused executions as the compiled
    /// batch sizes allow instead of gambling each probe against the
    /// `max_wait` deadline separately.  Shape validation happens up
    /// front, before anything is enqueued; a coordinator shutdown
    /// mid-batch still returns `Err` with the earlier planes already
    /// on the (dead) queue — their responses are simply never sent.
    ///
    /// Returns one response receiver per plane, in submission order.
    pub fn submit_batch(&self, planes: Vec<Vec<f32>>) -> Result<Vec<mpsc::Receiver<Response>>> {
        for (i, plane) in planes.iter().enumerate() {
            if plane.len() != self.bucket.vars_len() {
                bail!(
                    "batch plane {i} has {} values, session bucket wants {}",
                    plane.len(),
                    self.bucket.vars_len()
                );
            }
        }
        let submitted = Instant::now();
        let mut receivers = Vec::with_capacity(planes.len());
        for plane in planes {
            let shipped = plane.len();
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .send(Msg::Req(Request { payload: Payload::Full(plane), submitted, resp: rtx }))
                .map_err(|_| self.executor_gone_err())?;
            // only planes that actually reached the queue
            self.metrics.on_submit(None, shipped, false);
            receivers.push(rrx);
        }
        Ok(receivers)
    }

    /// Upload (and cache) `client`'s delta base plane for its
    /// subsequent [`Handle::submit_delta`] /
    /// [`Handle::submit_batch_delta`] calls, replacing that client's
    /// previously cached base.  Returns the base's content fingerprint
    /// — the key every delta derived from this plane must carry.
    ///
    /// Slots are per client, so concurrent delta writers on one session
    /// do not invalidate each other; the slot map is bounded by
    /// [`BatchPolicy::base_slots`], and a new client's upload into a
    /// full map evicts the least-recently-used other slot (the evicted
    /// client's next delta drops as stale and it re-uploads).
    pub fn upload_base(&self, client: ClientId, plane: Vec<f32>) -> Result<u64> {
        if plane.len() != self.bucket.vars_len() {
            bail!(
                "base plane has {} values, session bucket wants {}",
                plane.len(),
                self.bucket.vars_len()
            );
        }
        let shipped = plane.len();
        let fp = crate::runtime::plane_fingerprint(&plane);
        self.tx.send(Msg::Base { client, fp, plane }).map_err(|_| self.executor_gone_err())?;
        self.metrics.on_base_upload(client, shipped);
        Ok(fp)
    }

    /// Submit a probe round in delta form: one [`PlaneDelta`] per
    /// probe, reconstructed executor-side against `client`'s cached
    /// base — which is left **unchanged** (every probe edits the same
    /// launch base).  Like [`Handle::submit_batch`], the round is
    /// enqueued contiguously so the dynamic batcher fuses it, and shape
    /// validation happens up front, before anything is enqueued.  A
    /// delta whose base fingerprint no longer matches the client's slot
    /// is dropped executor-side (its receiver errors with a stale-base
    /// explanation).
    ///
    /// Returns one response receiver per delta, in submission order.
    pub fn submit_batch_delta(
        &self,
        client: ClientId,
        deltas: Vec<PlaneDelta>,
    ) -> Result<Vec<mpsc::Receiver<Response>>> {
        for (i, delta) in deltas.iter().enumerate() {
            delta.validate(self.bucket).with_context(|| format!("delta probe {i}"))?;
        }
        let submitted = Instant::now();
        let mut receivers = Vec::with_capacity(deltas.len());
        for delta in deltas {
            let shipped = delta.shipped_f32();
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .send(Msg::Req(Request {
                    payload: Payload::Delta { client, delta, advance: false },
                    submitted,
                    resp: rtx,
                }))
                .map_err(|_| self.executor_gone_err())?;
            // a delta ships only its rows
            self.metrics.on_submit(Some(client), shipped, true);
            receivers.push(rrx);
        }
        Ok(receivers)
    }

    /// Submit one **chained** delta — the search-node shape: the plane
    /// to enforce is `client`'s cached base with `delta.rows` replaced,
    /// and after reconstruction the client's slot *advances* to that
    /// plane, so the next call diffs against it
    /// ([`PlaneDelta::diff`] between consecutive planes).  A search
    /// worker therefore ships its base once and row diffs per node.
    ///
    /// If the slot was evicted or is out of sync the delta drops as
    /// stale (the receiver errors); re-upload via
    /// [`Handle::upload_base`] and resubmit — [`TensorEngine`] does
    /// this fallback automatically.
    ///
    /// [`TensorEngine`]: crate::coordinator::TensorEngine
    pub fn submit_delta(
        &self,
        client: ClientId,
        delta: PlaneDelta,
    ) -> Result<mpsc::Receiver<Response>> {
        delta.validate(self.bucket).context("chained delta")?;
        let shipped = delta.shipped_f32();
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Req(Request {
                payload: Payload::Delta { client, delta, advance: true },
                submitted: Instant::now(),
                resp: rtx,
            }))
            .map_err(|_| self.executor_gone_err())?;
        self.metrics.on_submit(Some(client), shipped, true);
        Ok(rrx)
    }

    /// Submit one chained delta ([`Handle::submit_delta`]) and block
    /// (deadline-bounded) for the response.
    pub fn enforce_delta_blocking(&self, client: ClientId, delta: PlaneDelta) -> Result<Response> {
        let deadline = Instant::now() + self.request_timeout;
        let rx = self.submit_delta(client, delta)?;
        self.recv_deadline(&rx, deadline)
    }

    /// Submit a delta probe round and block for every response, in
    /// order.  The whole round shares one deadline anchored at
    /// submission — a round is one logical request, so its last probe
    /// must not extend the wait by K deadlines.
    pub fn enforce_batch_delta_blocking(
        &self,
        client: ClientId,
        deltas: Vec<PlaneDelta>,
    ) -> Result<Vec<Response>> {
        let deadline = Instant::now() + self.request_timeout;
        self.submit_batch_delta(client, deltas)?
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                self.recv_deadline(&rx, deadline)
                    .with_context(|| format!("delta probe {i}"))
            })
            .collect()
    }

    /// `client`'s cumulative stale-delta count so far — what the delta
    /// clients compare before/after a failed call to decide between
    /// "my base went stale: re-upload and retry" and "the session is
    /// gone: fail".  (A targeted counter read, not a full snapshot —
    /// this sits on the per-enforcement hot path.)
    pub fn client_stale_deltas(&self, client: ClientId) -> u64 {
        self.metrics.client_stale_deltas(client)
    }

    /// Submit a probe batch and block for every response, in order.
    /// Like the delta round, the batch shares one deadline anchored at
    /// submission.
    pub fn enforce_batch_blocking(&self, planes: Vec<Vec<f32>>) -> Result<Vec<Response>> {
        let deadline = Instant::now() + self.request_timeout;
        self.submit_batch(planes)?
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                self.recv_deadline(&rx, deadline)
                    .with_context(|| format!("batched probe {i}"))
            })
            .collect()
    }
}

/// A running coordinator session.
pub struct Coordinator {
    handle: Handle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a session for `problem`.  Blocks until the executor thread
    /// has loaded the runtime, compiled the artifacts AND uploaded the
    /// constraint tensor (so a broken artifact dir — or a failed upload —
    /// fails fast, here, not on first request).
    pub fn start(problem: &Problem, config: CoordinatorConfig) -> Result<Coordinator> {
        let fixcache = crate::coordinator::FixCache::shared(config.policy.fixcache_entries);
        Coordinator::start_with_cache(problem, config, fixcache)
    }

    /// [`Coordinator::start`] with an explicit — possibly **shared** —
    /// fixpoint cache instead of one derived from
    /// [`BatchPolicy::fixcache_entries`].  The fleet tier passes each
    /// shard's cache here so rendezvous-placed duplicate sessions on
    /// one shard share warm entries (and failover replacements inherit
    /// them); `None` disables caching for the session regardless of
    /// the policy knob.
    pub(crate) fn start_with_cache(
        problem: &Problem,
        config: CoordinatorConfig,
        fixcache: Option<Arc<crate::coordinator::FixCache>>,
    ) -> Result<Coordinator> {
        // pick the bucket from the manifest before spawning, so errors
        // (problem too large for any artifact, zero max_batch) surface
        // synchronously.  An *oversized* max_batch is clamped to the
        // largest compiled size by the executor (programmatic callers
        // with the default policy must keep working on reduced artifact
        // sets); callers with an explicit user-facing knob (`rtac serve
        // --max-batch`) use [`Coordinator::validate_policy`] to fail
        // fast instead.
        let (manifest, bucket) = pick_bucket(problem, &config)?;
        let compiled_batches = compiled_batch_sizes(&manifest, bucket);
        let cons = encode_cons(problem, bucket)?;

        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let cfg = config.clone();
        let metrics2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name("rtac-executor".into())
            .spawn(move || {
                executor_thread(cfg, bucket, cons, fixcache, rx, ready_tx, metrics2);
            })
            .context("spawning executor thread")?;

        await_ready(&ready_rx, STARTUP_FENCE_TIMEOUT)?;

        Ok(Coordinator {
            handle: Handle {
                tx,
                bucket,
                metrics,
                compiled_batches,
                base_slots: config.policy.base_slots,
                request_timeout: config.policy.request_timeout,
                next_client: Arc::new(AtomicU64::new(0)),
            },
            join: Some(join),
        })
    }

    /// Validate `config.policy` against the compiled artifacts for
    /// `problem` *without* starting a session: picks the shape bucket
    /// (the same way [`Coordinator::start`] will) and checks `max_batch`
    /// against the compiled `fixb*` batch sizes.  `rtac serve` calls
    /// this so an explicit `--max-batch` with no matching artifact fails
    /// at startup with a clear message — the old behavior surfaced it
    /// only on the first fused request, as a mid-run execution failure.
    /// (Without this check, oversized caps are silently clamped by the
    /// executor.)
    pub fn validate_policy(problem: &Problem, config: &CoordinatorConfig) -> Result<()> {
        let (manifest, bucket) = pick_bucket(problem, config)?;
        let compiled = compiled_batch_sizes(&manifest, bucket);
        let largest = compiled.last().copied().unwrap_or(1);
        if config.policy.max_batch > largest {
            bail!(
                "max_batch {} exceeds the compiled batch sizes {:?} for bucket {}x{} \
                 (largest fused executable is fixb{}_n{}_d{}; recompile the artifacts \
                 or lower --max-batch)",
                config.policy.max_batch,
                compiled,
                bucket.n,
                bucket.d,
                largest,
                bucket.n,
                bucket.d
            );
        }
        Ok(())
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    pub fn bucket(&self) -> Bucket {
        self.handle.bucket
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.handle.metrics.clone()
    }
}

impl Coordinator {
    /// Graceful shutdown: drop the session's sender and join the
    /// executor.  Callers must have dropped their `Handle` clones first
    /// or this blocks until they do.
    pub fn shutdown(mut self) {
        let (dead_tx, _) = mpsc::channel();
        self.handle.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Detach: the executor thread exits on its own once every Handle
        // (and our sender) is gone.  Joining here could deadlock against
        // user-held Handle clones.
        self.join.take();
    }
}

/// The shared session preamble of [`Coordinator::start`] and
/// [`Coordinator::validate_policy`]: load the manifest, pick the shape
/// bucket for `problem`, and reject a zero `max_batch` or zero
/// `base_slots` (neither could serve anything, for any caller).
/// Keeping this in one place guarantees validation and startup agree on
/// the bucket.
fn pick_bucket(problem: &Problem, config: &CoordinatorConfig) -> Result<(Manifest, Bucket)> {
    let manifest = Manifest::load(&config.artifact_dir)?;
    let n = problem.n_vars();
    let d = problem.max_dom_size();
    let entry = manifest
        .pick(Kind::Fixpoint, n, d, 1)
        .ok_or_else(|| anyhow!("no artifact bucket fits ({n} vars × {d} values)"))?;
    let bucket = Bucket { n: entry.n, d: entry.d };
    if config.policy.max_batch == 0 {
        bail!("max_batch must be >= 1");
    }
    if config.policy.base_slots == 0 {
        bail!("base_slots must be >= 1 (every delta client needs a resident base slot)");
    }
    Ok((manifest, bucket))
}

/// Compiled batch sizes (ascending, deduped) of the fixpoint family at
/// `bucket` — the capacities `executor_thread` can actually dispatch to.
fn compiled_batch_sizes(manifest: &Manifest, bucket: Bucket) -> Vec<usize> {
    let mut sizes: Vec<usize> = manifest
        .entries
        .iter()
        .filter(|e| e.n == bucket.n && e.d == bucket.d)
        .filter(|e| matches!(e.kind, Kind::Fixpoint | Kind::FixpointBatched))
        .map(|e| e.batch)
        .collect();
    sizes.sort();
    sizes.dedup();
    sizes
}

/// The startup fence: the ONE place the ready signal is sent.  `init` is
/// everything the executor needs before it can serve — runtime load,
/// artifact compilation, the constraint-tensor upload — and the ready
/// send happens strictly *after* it resolves.  `Coordinator::start`
/// returning `Ok` therefore guarantees a live, fully-initialised
/// executor; an upload failure surfaces there as `Err`, not as a dead
/// session whose every later `submit` fails with "shut down".
fn send_ready<T>(ready_tx: &mpsc::Sender<Result<()>>, init: Result<T>) -> Option<T> {
    match init {
        Ok(v) => {
            let _ = ready_tx.send(Ok(()));
            Some(v)
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            None
        }
    }
}

/// How long [`Coordinator::start`] waits on the startup fence.
/// Generous — the executor's init compiles every artifact of the
/// session's bucket, which is seconds, not minutes — but *bounded*: a
/// wedged init must surface as a named startup error, never as a
/// forever-blocked `start`.
pub(crate) const STARTUP_FENCE_TIMEOUT: Duration = Duration::from_secs(120);

/// Client half of the startup fence: wait (bounded) for the executor's
/// ready signal.  The deadline turns a *hung* executor init — a stuck
/// artifact compile, a wedged device — into a named startup error
/// instead of blocking [`Coordinator::start`] forever; a *dead* init
/// thread and a *failed* init keep their established error texts.
fn await_ready(ready_rx: &mpsc::Receiver<Result<()>>, timeout: Duration) -> Result<()> {
    match ready_rx.recv_timeout(timeout) {
        Ok(init) => init.context("executor startup failed"),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err(anyhow!("executor thread died during startup"))
        }
        Err(mpsc::RecvTimeoutError::Timeout) => Err(anyhow!(
            "executor startup timed out after {timeout:?} (startup fence deadline): \
             init hung mid runtime-load/compile/upload — the thread is detached, \
             not joined; fix the artifact dir or the device before retrying"
        )),
    }
}

/// Executor-side session state that dies with the runtime and is
/// rebuilt by a restart: the PJRT runtime (compiled artifacts included)
/// and the device-resident constraint tensor, plus the compiled batch
/// sizes re-read from the freshly loaded manifest.
type ExecState = (Runtime, crate::runtime::DeviceTensor, Vec<usize>);

/// Re-hydrate a restarted session (§Supervision & recovery): spend one
/// restart from the budget, sleep its backoff, re-run the full init
/// (runtime load + artifact compilation + constraint-tensor re-upload),
/// then replay the session state the runtime's death could not reach —
/// the base-slot map is host-resident and content-fingerprinted, so its
/// replay is a deterministic retention, counted per slot as
/// `replayed_bases` — and re-enqueue the in-flight requests, dropping
/// (and counting as timed-out) those whose deadline passed while the
/// executor was down, so conservation holds across the restart.  A
/// failed re-init spends further restarts until the budget runs out;
/// `None` means the budget is exhausted and the caller must go
/// moribund.
fn restart_session(
    init: &dyn Fn() -> Result<ExecState>,
    supervisor: &mut Supervisor,
    slots: &BaseSlots,
    pending: &mut Vec<Request>,
    request_timeout: Duration,
    metrics: &Metrics,
    why: &str,
) -> Option<ExecState> {
    loop {
        let backoff = supervisor.begin_restart()?;
        std::thread::sleep(backoff);
        match init() {
            Ok(state) => {
                metrics.on_executor_restart();
                for _ in 0..slots.len() {
                    metrics.on_base_replayed();
                }
                let before = pending.len();
                pending.retain(|r| {
                    if r.submitted.elapsed() > request_timeout {
                        metrics.on_request_timeout(r.payload.client());
                        false
                    } else {
                        true
                    }
                });
                eprintln!(
                    "rtac-executor: restart {} after {why}: session re-hydrated \
                     ({} base slot(s) replayed, {} in-flight request(s) re-enqueued, \
                     {} dropped past their deadline)",
                    supervisor.restarts(),
                    slots.len(),
                    pending.len(),
                    before - pending.len(),
                );
                return Some(state);
            }
            Err(e) => {
                eprintln!(
                    "rtac-executor: restart {} after {why} failed to re-init: {e:#}",
                    supervisor.restarts()
                );
            }
        }
    }
}

/// The restart budget is exhausted: the session can no longer execute,
/// but conservation must hold and [`Coordinator::shutdown`] must still
/// join — so stay on the channel, dropping (and counting as
/// `restart_dropped_requests`) every in-flight and future request until
/// all handles disconnect.  Clients see the moribund cause through
/// `Handle::dropped_err` and degrade to CPU engines
/// (`search::parallel`).
fn drain_moribund(rx: &mpsc::Receiver<Msg>, pending: &mut Vec<Request>, metrics: &Metrics) {
    eprintln!(
        "rtac-executor: restart budget exhausted — session is moribund; dropping \
         all in-flight and future requests (clients degrade to CPU engines)"
    );
    for r in pending.drain(..) {
        metrics.on_restart_dropped(r.payload.client());
    }
    loop {
        match rx.recv() {
            Ok(Msg::Req(r)) => metrics.on_restart_dropped(r.payload.client()),
            Ok(Msg::Base { .. }) | Ok(Msg::ForceRestart) => {}
            Err(_) => return, // all handles dropped
        }
    }
}

/// Executor main loop: owns all XLA state, plus the session's
/// per-client delta base slots (see the module docs for the cache
/// rules), the optional content-addressed fixpoint cache (consulted
/// between payload resolution and dispatch — a hit answers as a
/// normal response and skips the fused execution), and the
/// supervision state (§Supervision & recovery: restart budget,
/// failed-execution streak, per-request deadlines).
fn executor_thread(
    config: CoordinatorConfig,
    bucket: Bucket,
    cons: Vec<f32>,
    fixcache: Option<Arc<crate::coordinator::FixCache>>,
    rx: mpsc::Receiver<Msg>,
    ready_tx: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
) {
    let init = || -> Result<ExecState> {
        // Load only this session's bucket (all batch sizes + the
        // unbatched fixpoint), keeping startup proportional to what
        // we'll run.
        let runtime =
            Runtime::load_fixpoint_bucket(&config.artifact_dir, bucket.n, bucket.d)?;
        let batch_sizes = compiled_batch_sizes(runtime.manifest(), bucket);
        // §Perf L3: upload the session's constraint tensor ONCE; every
        // batch then moves only the small vars planes host→device.
        let cons_dev = runtime
            .upload(&cons, &[bucket.n, bucket.n, bucket.d, bucket.d])
            .context("uploading the session constraint tensor")?;
        Ok((runtime, cons_dev, batch_sizes))
    };
    let Some((mut runtime, mut cons_dev, mut batch_sizes)) = send_ready(&ready_tx, init())
    else {
        return;
    };
    // `cons` stays resident on this thread for the session's lifetime
    // (it is deliberately NOT dropped after the first upload): a
    // restart re-runs `init`, which re-uploads it — the re-hydration
    // half of §Supervision & recovery.

    let request_timeout = config.policy.request_timeout;
    // the cache key's constraint half: the session serves ONE network,
    // fingerprinted once from its encoded constraint tensor (content-
    // addressed, so identical networks key identical entries — which is
    // what lets a fleet shard share one cache across its sessions)
    let cons_fp = crate::runtime::plane_fingerprint(&cons);
    let mut supervisor = Supervisor::new(config.policy.max_restarts);
    let mut compiled_max = batch_sizes.last().copied().unwrap_or(1);
    let mut adaptive =
        if config.policy.adaptive { Some(AdaptiveBatcher::new(&config.policy)) } else { None };
    let mut pending: Vec<Request> = Vec::new();
    // the session's per-client delta base slots, LRU-bounded by the
    // policy cap (see the module docs)
    let mut slots = BaseSlots::new(config.policy.base_slots);
    let apply_base = |slots: &mut BaseSlots, client: ClientId, fp: u64, plane: Vec<f32>| {
        if slots.insert(client, fp, plane) {
            metrics.on_base_evicted();
        }
    };
    let mut force_restart = false;
    loop {
        // 0. a requested restart happens BETWEEN batches, never
        // mid-execution (a thread cannot preempt its own XLA call)
        if force_restart {
            force_restart = false;
            match restart_session(
                &init,
                &mut supervisor,
                &slots,
                &mut pending,
                request_timeout,
                &metrics,
                "a forced restart",
            ) {
                Some((r, c, b)) => {
                    runtime = r;
                    cons_dev = c;
                    batch_sizes = b;
                    compiled_max = batch_sizes.last().copied().unwrap_or(1);
                }
                None => return drain_moribund(&rx, &mut pending, &metrics),
            }
        }
        // 1. block for the first request (or shut down); base uploads
        // are applied inline — they never open a batching window
        while pending.is_empty() && !force_restart {
            match rx.recv() {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Base { client, fp, plane }) => {
                    apply_base(&mut slots, client, fp, plane)
                }
                Ok(Msg::ForceRestart) => force_restart = true,
                Err(_) => return, // all handles dropped
            }
        }
        if force_restart {
            continue;
        }
        let (max_batch, max_wait) = match &adaptive {
            Some(a) => (a.max_batch(&batch_sizes), a.max_wait()),
            None => (config.policy.max_batch.min(compiled_max), config.policy.max_wait),
        };
        // 2a. drain already-queued requests greedily (no waiting): a
        // contiguous `submit_batch` probe batch fuses even at
        // max_wait == 0 — only *absent* batch-mates cost wall time.
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Base { client, fp, plane }) => {
                    apply_base(&mut slots, client, fp, plane)
                }
                Ok(Msg::ForceRestart) => {
                    // serve what's already fused first, restart at the
                    // top of the next iteration
                    force_restart = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // 2b. coalesce further batch-mates until the deadline or capacity
        if !max_wait.is_zero() && !force_restart {
            let deadline = Instant::now() + max_wait;
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Req(r)) => pending.push(r),
                    Ok(Msg::Base { client, fp, plane }) => {
                        apply_base(&mut slots, client, fp, plane)
                    }
                    Ok(Msg::ForceRestart) => {
                        force_restart = true;
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        if let Some(a) = &mut adaptive {
            a.observe(pending.len());
        }
        // 3. take up to the largest compiled capacity off the queue and
        // resolve each payload (reconstructing deltas against the
        // submitting client's base slot).  A delta whose base is
        // stale/evicted/unknown is dropped here — its responder goes
        // away and the client sees a clear stale-base error backed by
        // the per-client metrics.
        let take = pending.len().min(compiled_max);
        let mut planes: Vec<Vec<f32>> = Vec::with_capacity(take);
        let mut served: Vec<(Instant, mpsc::Sender<Response>, Option<ClientId>)> =
            Vec::with_capacity(take);
        for r in pending.drain(..take) {
            let client = r.payload.client();
            // executor half of the per-request deadline: a request that
            // outlived `request_timeout` on the queue (a hang, a long
            // restart backoff, a queue that outgrew the deadline) is
            // dropped and *counted*, matching the client's already-fired
            // recv_timeout — conservation holds whichever side noticed.
            if r.submitted.elapsed() > request_timeout {
                metrics.on_request_timeout(client);
                eprintln!(
                    "rtac-executor: dropping request past its {request_timeout:?} \
                     deadline (client {client:?})"
                );
                continue;
            }
            match resolve_payload(r.payload, &mut slots, bucket) {
                Some(plane) => {
                    planes.push(plane);
                    served.push((r.submitted, r.resp, client));
                }
                None => {
                    let client = client.expect("only deltas can fail to resolve");
                    metrics.on_stale_delta(client);
                    eprintln!(
                        "rtac-executor: dropping delta from client {client} against a \
                         stale/evicted/unknown base plane ({} of {} slots resident)",
                        slots.len(),
                        config.policy.base_slots,
                    );
                }
            }
        }
        // 3b. consult the fixpoint cache between resolution and
        // dispatch: the AC closure of (cons, plane) is unique, so a
        // memoised fixpoint answers bit-identically to the execution
        // it skips.  A hit is served as a NORMAL response right here
        // (counted in `responses` — conservation unchanged — plus
        // `fixcache_hits`); only the misses go on to fuse.
        let mut input_fps: Vec<u64> = Vec::new();
        if let Some(cache) = &fixcache {
            input_fps =
                planes.iter().map(|p| crate::runtime::plane_fingerprint(p)).collect();
            let mut i = 0;
            while i < planes.len() {
                match cache.lookup_plane(cons_fp, input_fps[i]) {
                    Some(hit) => {
                        metrics.on_fixcache_hit();
                        planes.remove(i);
                        input_fps.remove(i);
                        let (submitted, resp_tx, client) = served.remove(i);
                        let total = submitted.elapsed();
                        let resp = Response {
                            plane: hit.plane,
                            status: if hit.wiped { STATUS_WIPEOUT } else { 0 },
                            iters: hit.iters,
                            batch_real: 1,
                            batch_capacity: 1,
                            queue_time: total,
                            total_time: total,
                        };
                        metrics.on_response(client, total, total, hit.iters, hit.wiped);
                        let _ = resp_tx.send(resp);
                    }
                    None => {
                        metrics.on_fixcache_miss();
                        i += 1;
                    }
                }
            }
        }
        if planes.is_empty() {
            continue; // the whole drain was stale deltas, expired, or cache hits
        }
        // 4. pick the smallest compiled batch that fits, pad, execute
        let real = planes.len();
        let capacity = batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= real)
            .unwrap_or(compiled_max);
        let plane_len = bucket.vars_len();
        let mut input = Vec::with_capacity(capacity * plane_len);
        for p in &planes {
            input.extend_from_slice(p);
        }
        // padding: replicate the first plane — it converges in the same
        // sweeps as its twin, adding no extra joint iterations.
        for _ in real..capacity {
            input.extend_from_slice(&planes[0]);
        }

        let name = artifact_name(capacity, bucket);
        let t_exec = Instant::now();
        let result = runtime.run_fixpoint_dev(&name, &cons_dev, &input);
        let exec = t_exec.elapsed();

        // Metrics are recorded only once the execution result is known:
        // a failed XLA run counts as a failed batch with dropped
        // requests, never as a served batch that would skew occupancy
        // and exec stats.
        match result {
            Ok(out) => {
                supervisor.on_batch_ok();
                metrics.on_batch(real, capacity, exec);
                // admit every served fixpoint so identical future
                // inputs (same client or another) hit instead of
                // re-running the recurrence
                if let Some(cache) = &fixcache {
                    for (i, fp) in input_fps.iter().enumerate() {
                        let (evicted, bytes) = cache.insert_plane(
                            cons_fp,
                            *fp,
                            out.vars[i * plane_len..(i + 1) * plane_len].to_vec(),
                            out.status[i] == STATUS_WIPEOUT,
                            out.iters,
                        );
                        metrics.on_fixcache_insert(bytes, evicted);
                    }
                }
                for (i, (submitted, resp_tx, client)) in served.into_iter().enumerate() {
                    let queue = t_exec.duration_since(submitted);
                    let total = submitted.elapsed();
                    let resp = Response {
                        plane: out.vars[i * plane_len..(i + 1) * plane_len].to_vec(),
                        status: out.status[i],
                        iters: out.iters,
                        batch_real: real,
                        batch_capacity: capacity,
                        queue_time: queue,
                        total_time: total,
                    };
                    metrics.on_response(client, queue, total, out.iters, resp.wiped());
                    let _ = resp_tx.send(resp); // receiver may have gone
                }
            }
            Err(e) => {
                // drop the responders: receivers see a clear dropped-
                // request error from `Handle` (backed by these counters);
                // log once on this side.
                let dropped: Vec<Option<ClientId>> =
                    served.iter().map(|(_, _, client)| *client).collect();
                metrics.on_batch_failed(&dropped);
                eprintln!(
                    "rtac-executor: fused execution {name} failed ({real} request(s) \
                     dropped): {e:#}"
                );
                // §Supervision: a failed-execution STREAK (not one
                // failure) means the executor itself is sick — restart
                // and re-hydrate, within the budget.
                if supervisor.on_batch_failed() {
                    match restart_session(
                        &init,
                        &mut supervisor,
                        &slots,
                        &mut pending,
                        request_timeout,
                        &metrics,
                        "a failed-execution streak",
                    ) {
                        Some((r, c, b)) => {
                            runtime = r;
                            cons_dev = c;
                            batch_sizes = b;
                            compiled_max = batch_sizes.last().copied().unwrap_or(1);
                        }
                        None => return drain_moribund(&rx, &mut pending, &metrics),
                    }
                }
            }
        }
    }
}

/// Artifact naming scheme shared with `python/compile/aot.py`.
fn artifact_name(capacity: usize, bucket: Bucket) -> String {
    if capacity == 1 {
        format!("fix_n{}_d{}", bucket.n, bucket.d)
    } else {
        format!("fixb{}_n{}_d{}", capacity, bucket.n, bucket.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_aot_scheme() {
        let b = Bucket { n: 16, d: 8 };
        assert_eq!(artifact_name(1, b), "fix_n16_d8");
        assert_eq!(artifact_name(4, b), "fixb4_n16_d8");
        assert_eq!(artifact_name(8, b), "fixb8_n16_d8");
    }

    #[test]
    fn default_policy_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait < Duration::from_millis(10));
        assert!(p.base_slots >= 1);
        assert!(p.request_timeout >= Duration::from_secs(1), "deadline must not strangle XLA");
        assert!(p.max_restarts >= 1, "a session should survive at least one crash");
    }

    fn handle_at(bucket: Bucket) -> (Handle, mpsc::Receiver<Msg>) {
        let policy = BatchPolicy::default();
        Handle::for_reference_executor(bucket, policy.base_slots, policy.request_timeout)
    }

    fn test_handle() -> (Handle, mpsc::Receiver<Msg>) {
        handle_at(Bucket { n: 2, d: 2 })
    }

    /// Unwrap a queue message as a request (panics on anything else).
    fn expect_req(msg: Msg) -> Request {
        match msg {
            Msg::Req(r) => r,
            Msg::Base { .. } => panic!("expected a request, got a base upload"),
            Msg::ForceRestart => panic!("expected a request, got a restart"),
        }
    }

    /// Unwrap a request payload as a full plane.
    fn full_plane(payload: Payload) -> Vec<f32> {
        match payload {
            Payload::Full(p) => p,
            Payload::Delta { .. } => panic!("expected a full plane, got a delta"),
        }
    }

    // ---- base-slot map (cap + LRU) --------------------------------------

    #[test]
    fn base_slots_replace_within_client_and_evict_lru_across_clients() {
        let (a, b, c) = (ClientId::test(0), ClientId::test(1), ClientId::test(2));
        let mut slots = BaseSlots::new(2);
        assert!(!slots.insert(a, 1, vec![1.0]));
        assert!(!slots.insert(b, 2, vec![2.0]));
        assert_eq!(slots.len(), 2);
        // same-client re-upload replaces in place: no eviction
        assert!(!slots.insert(a, 3, vec![3.0]));
        assert_eq!(slots.len(), 2);
        assert_eq!(slots.get(a).map(|(_, fp, _)| *fp), Some(3));
        assert_eq!(slots.get(b).map(|(_, fp, _)| *fp), Some(2));
        // a third client under cap 2 evicts the LRU (a: touched less
        // recently than b just above)
        assert!(slots.insert(c, 4, vec![4.0]));
        assert_eq!(slots.len(), 2);
        assert!(slots.get(a).is_none(), "LRU slot must be gone");
        assert_eq!(slots.get(b).map(|(_, fp, _)| *fp), Some(2));
        assert_eq!(slots.get(c).map(|(_, fp, _)| *fp), Some(4));
    }

    #[test]
    fn base_slots_get_refreshes_recency() {
        let (a, b, c) = (ClientId::test(0), ClientId::test(1), ClientId::test(2));
        let mut slots = BaseSlots::new(2);
        slots.insert(a, 1, vec![1.0]);
        slots.insert(b, 2, vec![2.0]);
        // touch a: b becomes the LRU
        assert!(slots.get(a).is_some());
        assert!(slots.insert(c, 3, vec![3.0]), "insert over a full map must evict");
        assert!(slots.get(b).is_none(), "the untouched slot is the one evicted");
        assert!(slots.get(a).is_some());
    }

    #[test]
    fn base_slots_zero_cap_clamps_to_one() {
        let a = ClientId::test(0);
        let mut slots = BaseSlots::new(0);
        slots.insert(a, 1, vec![1.0]);
        assert_eq!(slots.len(), 1);
        assert!(slots.get(a).is_some());
    }

    // ---- client-side submission paths -----------------------------------

    #[test]
    fn attach_issues_unique_ids_across_handle_clones() {
        let (h, _rx) = test_handle();
        let h2 = h.clone();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            assert!(seen.insert(h.attach()));
            assert!(seen.insert(h2.attach()));
        }
        assert_eq!(seen.len(), 8, "ids must be session-unique, not per-clone");
    }

    #[test]
    fn submit_batch_validates_before_enqueuing_anything() {
        let (h, rx) = test_handle();
        let bad = vec![vec![1.0; h.bucket.vars_len()], vec![0.0; 3]];
        assert!(h.submit_batch(bad).is_err());
        assert!(rx.try_recv().is_err(), "no plane may be enqueued on a rejected batch");
        assert_eq!(h.metrics.snapshot().requests, 0);
    }

    #[test]
    fn submit_batch_enqueues_in_order() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let planes = vec![vec![1.0; len], vec![0.5; len], vec![0.0; len]];
        let receivers = h.submit_batch(planes.clone()).unwrap();
        assert_eq!(receivers.len(), 3);
        for want in &planes {
            let got = expect_req(rx.try_recv().expect("plane enqueued"));
            assert_eq!(&full_plane(got.payload), want);
        }
        let m = h.metrics.snapshot();
        assert_eq!(m.requests, 3);
        assert_eq!(m.shipped_f32, 3 * len as u64);
        assert!(m.clients.is_empty(), "full planes are unattributed");
    }

    // ---- delta protocol (client side + payload resolution) -------------

    #[test]
    fn submit_batch_delta_validates_before_enqueuing_anything() {
        let (h, rx) = test_handle();
        let d = h.bucket.d;
        let client = h.attach();
        let base = vec![1.0; h.bucket.vars_len()];
        let fp = crate::runtime::plane_fingerprint(&base);
        let bad = vec![
            PlaneDelta::singleton(fp, 0, 0, h.bucket),
            PlaneDelta { base_fp: fp, rows: vec![(0, vec![1.0; d + 1])] },
        ];
        let err = h.submit_batch_delta(client, bad).unwrap_err();
        assert!(format!("{err:#}").contains("delta probe 1"), "{err:#}");
        assert!(rx.try_recv().is_err(), "no delta may be enqueued on a rejected batch");
        assert_eq!(h.metrics.snapshot().requests, 0);
    }

    #[test]
    fn upload_base_ships_once_and_deltas_ship_only_rows() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let client = h.attach();
        let base = vec![1.0; len];
        let fp = h.upload_base(client, base.clone()).unwrap();
        assert_eq!(fp, crate::runtime::plane_fingerprint(&base));
        let deltas = vec![
            PlaneDelta::singleton(fp, 0, 1, h.bucket),
            PlaneDelta::singleton(fp, 1, 0, h.bucket),
        ];
        let receivers = h.submit_batch_delta(client, deltas).unwrap();
        assert_eq!(receivers.len(), 2);
        // queue order: base first, then the deltas
        match rx.try_recv().unwrap() {
            Msg::Base { client: got_client, fp: got_fp, plane } => {
                assert_eq!(got_client, client);
                assert_eq!(got_fp, fp);
                assert_eq!(plane, base);
            }
            Msg::Req(_) | Msg::ForceRestart => panic!("base upload must precede the deltas"),
        }
        for _ in 0..2 {
            let req = expect_req(rx.try_recv().unwrap());
            match req.payload {
                Payload::Delta { client: c, advance, .. } => {
                    assert_eq!(c, client);
                    assert!(!advance, "probe rounds must not advance the slot");
                }
                Payload::Full(_) => panic!("expected deltas"),
            }
        }
        let m = h.metrics.snapshot();
        assert_eq!(m.base_uploads, 1);
        assert_eq!(m.requests, 2, "a base upload is not a request");
        assert_eq!(m.delta_requests, 2);
        // one full plane + two rows, instead of three full planes
        assert_eq!(m.shipped_f32, (len + 2 * h.bucket.d) as u64);
        // mirrored on the client's own row
        let c = m.client(client.id()).unwrap();
        assert_eq!(c.base_uploads, 1);
        assert_eq!(c.delta_requests, 2);
        assert_eq!(c.shipped_f32, (len + 2 * h.bucket.d) as u64);
    }

    #[test]
    fn submit_delta_marks_the_chain_advance() {
        let (h, rx) = test_handle();
        let client = h.attach();
        let base = vec![1.0; h.bucket.vars_len()];
        let fp = h.upload_base(client, base).unwrap();
        let _rx_resp = h.submit_delta(client, PlaneDelta::empty(fp)).unwrap();
        let _ = rx.try_recv().unwrap(); // the base
        let req = expect_req(rx.try_recv().unwrap());
        match req.payload {
            Payload::Delta { advance, .. } => assert!(advance, "search deltas must chain"),
            Payload::Full(_) => panic!("expected a delta"),
        }
        let m = h.metrics.snapshot();
        assert_eq!(m.delta_requests, 1);
        assert_eq!(m.shipped_f32, (h.bucket.vars_len()) as u64, "an empty delta ships 0 rows");
    }

    #[test]
    fn resolve_payload_reconstructs_per_client_and_refuses_stale_ones() {
        let bucket = Bucket { n: 2, d: 2 };
        let (a, b) = (ClientId::test(0), ClientId::test(1));
        let base_a = vec![1.0, 1.0, 1.0, 0.0];
        let base_b = vec![1.0, 0.0, 1.0, 1.0];
        let fp_a = crate::runtime::plane_fingerprint(&base_a);
        let fp_b = crate::runtime::plane_fingerprint(&base_b);
        let mut slots = BaseSlots::new(4);
        slots.insert(a, fp_a, base_a.clone());
        slots.insert(b, fp_b, base_b.clone());
        // full planes pass through untouched
        let full = resolve_payload(Payload::Full(vec![0.5; 4]), &mut slots, bucket);
        assert_eq!(full, Some(vec![0.5; 4]));
        // each client's delta resolves against ITS base
        let delta_a = PlaneDelta::singleton(fp_a, 0, 1, bucket);
        let got = resolve_payload(
            Payload::Delta { client: a, delta: delta_a.clone(), advance: false },
            &mut slots,
            bucket,
        );
        assert_eq!(got, Some(vec![0.0, 1.0, 1.0, 0.0]));
        let delta_b = PlaneDelta::singleton(fp_b, 1, 0, bucket);
        let got = resolve_payload(
            Payload::Delta { client: b, delta: delta_b, advance: false },
            &mut slots,
            bucket,
        );
        assert_eq!(got, Some(vec![1.0, 0.0, 1.0, 0.0]));
        // a's delta against b's slot (cross-client): refused
        let got = resolve_payload(
            Payload::Delta { client: b, delta: delta_a.clone(), advance: false },
            &mut slots,
            bucket,
        );
        assert_eq!(got, None, "a fingerprint must only match its own client's slot");
        // unknown client: refused
        let got = resolve_payload(
            Payload::Delta { client: ClientId::test(9), delta: delta_a, advance: false },
            &mut slots,
            bucket,
        );
        assert_eq!(got, None);
    }

    #[test]
    fn resolve_payload_advance_chains_the_slot() {
        let bucket = Bucket { n: 2, d: 2 };
        let a = ClientId::test(0);
        let base = vec![1.0, 1.0, 1.0, 1.0];
        let fp = crate::runtime::plane_fingerprint(&base);
        let mut slots = BaseSlots::new(2);
        slots.insert(a, fp, base.clone());
        // step 1: advance to base-with-row-0-assigned
        let step1 = PlaneDelta::singleton(fp, 0, 0, bucket);
        let plane1 = resolve_payload(
            Payload::Delta { client: a, delta: step1.clone(), advance: true },
            &mut slots,
            bucket,
        )
        .unwrap();
        assert_eq!(plane1, vec![1.0, 0.0, 1.0, 1.0]);
        // the slot advanced: the ORIGINAL fingerprint is now stale...
        let stale = resolve_payload(
            Payload::Delta { client: a, delta: step1, advance: true },
            &mut slots,
            bucket,
        );
        assert_eq!(stale, None, "after an advance the old fp must be stale");
        // ...and a delta against the advanced plane resolves
        let fp1 = crate::runtime::plane_fingerprint(&plane1);
        let step2 = PlaneDelta::singleton(fp1, 1, 1, bucket);
        let plane2 = resolve_payload(
            Payload::Delta { client: a, delta: step2, advance: true },
            &mut slots,
            bucket,
        )
        .unwrap();
        assert_eq!(plane2, vec![1.0, 0.0, 0.0, 1.0]);
        // non-advancing rounds leave the chain head in place
        let fp2 = crate::runtime::plane_fingerprint(&plane2);
        let probe = PlaneDelta::singleton(fp2, 0, 1, bucket);
        for _ in 0..2 {
            let got = resolve_payload(
                Payload::Delta { client: a, delta: probe.clone(), advance: false },
                &mut slots,
                bucket,
            );
            assert_eq!(got, Some(vec![0.0, 1.0, 0.0, 1.0]), "probes must not move the base");
        }
    }

    // ---- startup fence -------------------------------------------------

    #[test]
    fn startup_fence_failing_upload_reaches_start_not_a_dead_executor() {
        // Regression: the ready signal used to be sent after the runtime
        // load but BEFORE the constraint-tensor upload, so an upload
        // failure left `Coordinator::start` returning Ok with a dead
        // executor.  `send_ready` is the single send site, fed by the
        // FULL init result; a failing-upload stub must surface as Err on
        // the ready channel and abort the executor (None).
        let (tx, rx) = mpsc::channel::<Result<()>>();
        let init: Result<u32> = Err(anyhow!("xla: buffer_from_host_buffer failed"))
            .context("uploading the session constraint tensor");
        assert!(send_ready(&tx, init).is_none(), "a failed init must stop the executor");
        let err = rx.recv().unwrap().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("constraint tensor"), "unhelpful startup error: {msg}");
    }

    #[test]
    fn startup_fence_sends_ready_only_on_success() {
        let (tx, rx) = mpsc::channel::<Result<()>>();
        let got = send_ready(&tx, Ok(42u32));
        assert_eq!(got, Some(42));
        assert!(rx.recv().unwrap().is_ok());
    }

    // ---- executor-death error surface ---------------------------------

    #[test]
    fn submit_after_executor_exit_names_the_executor() {
        let (h, rx) = test_handle();
        drop(rx); // the "executor" is gone
        let err = h.submit(vec![1.0; h.bucket.vars_len()]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("executor"), "bare channel error leaked: {msg}");
    }

    #[test]
    fn dropped_request_error_blames_failed_batch_when_one_happened() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let metrics = h.metrics.clone();
        // lint:allow(thread-placement): test-only fake executor thread
        let executor = std::thread::spawn(move || {
            // fake executor: receive one request, fail its "execution",
            // drop the responder without answering, then exit.
            let req = expect_req(rx.recv().unwrap());
            metrics.on_batch_failed(&[None]);
            drop(req);
            drop(rx);
        });
        let err = h.enforce_blocking(vec![1.0; len]).unwrap_err();
        executor.join().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("failed"), "error must mention the failed execution: {msg}");
        let m = h.metrics.snapshot();
        assert_eq!(m.failed_batches, 1);
        assert!(m.conserved(), "requests == responses + dropped: {m:?}");
    }

    #[test]
    fn dropped_batched_request_error_is_clear_and_indexed() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let metrics = h.metrics.clone();
        // lint:allow(thread-placement): test-only fake executor thread
        let executor = std::thread::spawn(move || {
            // answer the first probe, then die with the second in flight
            let req = expect_req(rx.recv().unwrap());
            let resp = Response {
                plane: full_plane(req.payload),
                status: 0,
                iters: 1,
                batch_real: 1,
                batch_capacity: 4,
                queue_time: Duration::ZERO,
                total_time: Duration::ZERO,
            };
            metrics.on_batch(1, 4, Duration::from_micros(5));
            metrics.on_response(None, Duration::ZERO, Duration::ZERO, 1, false);
            let _ = req.resp.send(resp);
            let second = rx.recv().unwrap();
            metrics.on_batch_failed(&[None]);
            drop(second);
            drop(rx);
        });
        let err = h
            .enforce_batch_blocking(vec![vec![1.0; len], vec![0.5; len]])
            .unwrap_err();
        executor.join().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("batched probe 1"), "which probe died? {msg}");
        assert!(msg.contains("failed"), "why did it die? {msg}");
        let m = h.metrics.snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.responses, 1);
        assert_eq!(m.dropped_requests, 1);
        assert!(m.conserved());
    }

    #[test]
    fn metrics_conserved_across_mixed_single_and_batched_submissions() {
        // requests == responses + dropped once the queue drains, across
        // a mix of single submits, a fused probe batch, and a failure.
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let metrics = h.metrics.clone();
        let thread_metrics = metrics.clone();
        // lint:allow(thread-placement): test-only fake executor thread
        let executor = std::thread::spawn(move || {
            let mut served = 0usize;
            while let Ok(msg) = rx.recv() {
                let req = expect_req(msg);
                if served == 3 {
                    // fourth request: its fused execution "fails"
                    thread_metrics.on_batch_failed(&[None]);
                    drop(req);
                } else {
                    thread_metrics.on_batch(1, 1, Duration::from_micros(3));
                    thread_metrics.on_response(None, Duration::ZERO, Duration::ZERO, 1, false);
                    let resp = Response {
                        plane: full_plane(req.payload),
                        status: 0,
                        iters: 1,
                        batch_real: 1,
                        batch_capacity: 1,
                        queue_time: Duration::ZERO,
                        total_time: Duration::ZERO,
                    };
                    let _ = req.resp.send(resp);
                }
                served += 1;
            }
        });
        assert!(h.enforce_blocking(vec![1.0; len]).is_ok());
        let batch = h.enforce_batch_blocking(vec![vec![1.0; len], vec![0.5; len]]).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(h.enforce_blocking(vec![0.0; len]).is_err(), "dropped request must error");
        drop(h); // last sender gone: the fake executor drains and exits
        executor.join().unwrap();
        let m = metrics.snapshot();
        assert_eq!(m.requests, 4);
        assert_eq!(m.responses, 3);
        assert_eq!(m.dropped_requests, 1);
        assert_eq!(m.failed_batches, 1);
        assert!(m.conserved(), "requests == responses + dropped: {m:?}");
    }

    // ---- delta protocol end-to-end (offline CPU-reference executor) ----
    //
    // The executors themselves live in `coordinator::chaos` (promoted
    // out of this test module in the fleet PR so the fleet tier and the
    // load harness can run them at runtime); these tests keep driving
    // them through the same fixtures.

    use crate::coordinator::chaos::{
        chaos_session, dump_chaos_snapshot, reference_session, reference_session_with_slots,
        FaultPlan,
    };

    #[test]
    fn delta_round_matches_full_round_through_the_protocol() {
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 11));
        let s = crate::core::State::new(&p);
        let base = encode_vars(&p, &s, bucket).unwrap();
        let probes: Vec<(usize, usize)> = vec![(0, 1), (2, 0), (5, 3)];

        // full-plane round on one session
        let (h_full, j_full) = reference_session(&p, bucket);
        let planes: Vec<Vec<f32>> = probes
            .iter()
            .map(|&(x, a)| {
                let mut plane = base.clone();
                plane[x * bucket.d..(x + 1) * bucket.d].fill(0.0);
                plane[x * bucket.d + a] = 1.0;
                plane
            })
            .collect();
        let full = h_full.enforce_batch_blocking(planes).unwrap();

        // delta round on a second session (separate metrics)
        let (h_delta, j_delta) = reference_session(&p, bucket);
        let client = h_delta.attach();
        let fp = h_delta.upload_base(client, base.clone()).unwrap();
        let deltas: Vec<PlaneDelta> =
            probes.iter().map(|&(x, a)| PlaneDelta::singleton(fp, x, a, bucket)).collect();
        let delta = h_delta.enforce_batch_delta_blocking(client, deltas).unwrap();

        assert_eq!(full.len(), delta.len());
        for (i, (f, d)) in full.iter().zip(&delta).enumerate() {
            assert_eq!(f.status, d.status, "probe {i}");
            assert_eq!(f.plane, d.plane, "probe {i}: reconstruction must be exact");
        }
        // the delta round ships one plane + K rows
        let m_full = h_full.metrics.snapshot();
        let m_delta = h_delta.metrics.snapshot();
        assert_eq!(m_full.shipped_f32, (3 * bucket.vars_len()) as u64);
        assert_eq!(m_delta.shipped_f32, (bucket.vars_len() + 3 * bucket.d) as u64);
        assert!(m_delta.shipped_f32 < m_full.shipped_f32);
        assert!(m_full.conserved() && m_delta.conserved());
        assert!(m_delta.clients_conserved());
        drop(h_full);
        drop(h_delta);
        j_full.join().unwrap();
        j_delta.join().unwrap();
    }

    #[test]
    fn base_reupload_invalidates_own_slot_only() {
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(5, 4, 0.5, 0.3, 7));
        let (h, join) = reference_session(&p, bucket);
        let writer = h.attach();
        let bystander = h.attach();
        let s = crate::core::State::new(&p);
        let base_a = encode_vars(&p, &s, bucket).unwrap();
        let fp_a = h.upload_base(writer, base_a.clone()).unwrap();
        // the bystander caches the same content under ITS OWN slot
        let fp_by = h.upload_base(bystander, base_a.clone()).unwrap();
        assert_eq!(fp_a, fp_by, "fingerprints are content-keyed");
        // the writer re-uploads different content: only ITS slot moves
        let mut s_b = s.clone();
        s_b.remove(1, 1);
        let base_b = encode_vars(&p, &s_b, bucket).unwrap();
        let fp_b = h.upload_base(writer, base_b).unwrap();
        assert_ne!(fp_a, fp_b);
        // writer deltas against the OLD base must be dropped with a
        // clear error...
        let err = h
            .enforce_batch_delta_blocking(writer, vec![PlaneDelta::singleton(fp_a, 0, 0, bucket)])
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stale"), "unhelpful stale-delta error: {msg}");
        // ...while the bystander's same-fingerprint delta still serves
        // (per-client slots: no cross-invalidation)
        let ok = h
            .enforce_batch_delta_blocking(
                bystander,
                vec![PlaneDelta::singleton(fp_by, 0, 0, bucket)],
            )
            .unwrap();
        assert_eq!(ok.len(), 1);
        // and the writer's CURRENT base serves too
        let ok = h
            .enforce_batch_delta_blocking(writer, vec![PlaneDelta::singleton(fp_b, 0, 0, bucket)])
            .unwrap();
        assert_eq!(ok.len(), 1);
        let m = h.metrics.snapshot();
        assert_eq!(m.stale_deltas, 1);
        assert_eq!(m.base_uploads, 3);
        assert!(m.conserved(), "stale delta must be accounted as dropped: {m:?}");
        assert!(m.clients_conserved(), "{m:?}");
        let mw = m.client(writer.id()).unwrap();
        assert_eq!(mw.stale_deltas, 1);
        assert_eq!(mw.base_uploads, 2);
        let mb = m.client(bystander.id()).unwrap();
        assert_eq!(mb.stale_deltas, 0, "the bystander must never see a stale drop");
        drop(h);
        join.join().unwrap();
    }

    #[test]
    fn eviction_under_cap_drops_the_lru_writer_and_conserves() {
        use crate::gen::random::{random_csp, RandomSpec};
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(5, 4, 0.5, 0.3, 19));
        // ONE base slot: the second client's upload evicts the first's
        let (h, join) = reference_session_with_slots(&p, bucket, 1);
        let (a, b) = (h.attach(), h.attach());
        let s = crate::core::State::new(&p);
        let base = encode_vars(&p, &s, bucket).unwrap();
        let fp_a = h.upload_base(a, base.clone()).unwrap();
        // a's delta serves while its slot is resident
        assert_eq!(
            h.enforce_batch_delta_blocking(a, vec![PlaneDelta::singleton(fp_a, 0, 0, bucket)])
                .unwrap()
                .len(),
            1
        );
        // b's upload evicts a (cap 1)
        let fp_b = h.upload_base(b, base.clone()).unwrap();
        let err = h
            .enforce_batch_delta_blocking(a, vec![PlaneDelta::singleton(fp_a, 0, 0, bucket)])
            .unwrap_err();
        assert!(format!("{err:#}").contains("stale"), "{err:#}");
        // b serves; a re-uploads and serves again — degradation, not a
        // dead end
        assert_eq!(
            h.enforce_batch_delta_blocking(b, vec![PlaneDelta::singleton(fp_b, 1, 0, bucket)])
                .unwrap()
                .len(),
            1
        );
        let fp_a2 = h.upload_base(a, base).unwrap();
        assert_eq!(
            h.enforce_batch_delta_blocking(a, vec![PlaneDelta::singleton(fp_a2, 2, 0, bucket)])
                .unwrap()
                .len(),
            1
        );
        let m = h.metrics.snapshot();
        assert!(m.base_evictions >= 2, "evictions must be counted: {m:?}");
        assert_eq!(m.stale_deltas, 1);
        assert!(m.conserved() && m.clients_conserved(), "{m:?}");
        let ma = m.client(a.id()).unwrap();
        assert_eq!(ma.stale_deltas, 1);
        assert_eq!(ma.base_uploads, 2, "the evicted writer re-uploaded once");
        assert!(ma.delta_hit_rate() < 1.0);
        let mb = m.client(b.id()).unwrap();
        assert_eq!(mb.stale_deltas, 0);
        drop(h);
        join.join().unwrap();
    }

    #[test]
    fn two_concurrent_delta_clients_never_cross_invalidate() {
        // the tentpole's multi-writer e2e, offline: two threads each
        // drive their own delta-shipping TensorEngine over ONE session
        // (interleaved uploads + chained deltas at the executor queue),
        // and every enforcement must equal the native closure computed
        // on the same states — with zero stale drops, because the slots
        // are per client.
        use crate::ac::{rtac::RtacNative, Counters, Propagator};
        use crate::coordinator::TensorEngine;
        use crate::gen::random::{random_csp, RandomSpec};
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 31));
        let (h, join) = reference_session(&p, bucket);
        // lint:allow(thread-placement): test clients hammering one session
        std::thread::scope(|scope| {
            for t in 0..2u64 {
                let handle = h.clone();
                let problem = &p;
                scope.spawn(move || {
                    let mut engine = TensorEngine::new(handle);
                    for round in 0..4u64 {
                        // per-thread, per-round launch states (distinct
                        // across threads so the chains diverge)
                        let mut s = crate::core::State::new(problem);
                        let x = ((t + round) % problem.n_vars() as u64) as usize;
                        let a = (t % problem.dom_size(x) as u64) as usize;
                        s.assign(x, a);
                        let mut c = Counters::default();
                        let out = engine.enforce(problem, &mut s, &[], &mut c);
                        assert!(engine.failed.is_none(), "t{t} r{round}: {:?}", engine.failed);
                        // native reference on the same launch state
                        let mut s_ref = crate::core::State::new(problem);
                        s_ref.assign(x, a);
                        let mut c_ref = Counters::default();
                        let out_ref =
                            RtacNative::dense().enforce(problem, &mut s_ref, &[], &mut c_ref);
                        assert_eq!(
                            out.is_consistent(),
                            out_ref.is_consistent(),
                            "t{t} r{round}"
                        );
                        if out.is_consistent() {
                            assert_eq!(s.snapshot(), s_ref.snapshot(), "t{t} r{round}");
                        }
                    }
                });
            }
        });
        let m = h.metrics.snapshot();
        assert_eq!(m.stale_deltas, 0, "per-client slots must not cross-invalidate: {m:?}");
        assert_eq!(m.base_evictions, 0);
        assert_eq!(m.clients.len(), 2, "each engine attached its own client");
        assert!(m.conserved() && m.clients_conserved(), "{m:?}");
        for c in &m.clients {
            assert!(c.base_uploads >= 1, "every writer ships its base once: {c:?}");
            assert_eq!(c.delta_hit_rate(), 1.0, "{c:?}");
        }
        drop(h);
        join.join().unwrap();
    }

    #[test]
    fn search_run_ships_one_base_then_row_diffs() {
        // the acceptance criterion, offline: a K-node MAC search over a
        // delta-shipping tensor worker moves 1 base plane + per-node row
        // diffs — strictly less f32 volume than the full-plane baseline
        // on the same search, with identical results.
        use crate::search::parallel::{solve_parallel_with, WorkerEngine};
        use crate::search::solver::{SolveResult, SolverConfig};
        let bucket = Bucket { n: 8, d: 8 };
        let p = crate::gen::queens(6);

        // ONE worker: the search is deterministic, so both modes visit
        // the same nodes and the volumes compare like for like (the
        // multi-writer side is covered by the two-client tests)
        let run = |engine: WorkerEngine| {
            let (h, join) = reference_session(&p, bucket);
            let out =
                solve_parallel_with(&p, &h, &SolverConfig::default(), 0, 1, engine).unwrap();
            let m = h.metrics.snapshot();
            drop(h);
            join.join().unwrap();
            (out, m)
        };

        let (out_full, m_full) = run(WorkerEngine::TensorFull);
        let (out_delta, m_delta) = run(WorkerEngine::Tensor);
        match (&out_full.result, &out_delta.result) {
            (SolveResult::Sat(a), SolveResult::Sat(b)) => {
                assert!(p.satisfies(a) && p.satisfies(b));
            }
            (f, d) => panic!("queens(6) must be SAT on both modes: {f:?} vs {d:?}"),
        }
        // the same searched planes, radically less volume
        assert!(m_delta.requests >= 4, "a real multi-node search ran: {m_delta:?}");
        assert!(
            m_delta.shipped_f32 < m_full.shipped_f32,
            "delta search must ship strictly less ({} vs {} f32)",
            m_delta.shipped_f32,
            m_full.shipped_f32
        );
        assert_eq!(m_full.base_uploads, 0);
        assert_eq!(m_full.delta_requests, 0);
        // same deterministic search in both modes
        assert_eq!(m_delta.requests, m_full.requests, "modes must visit the same nodes");
        assert_eq!(m_delta.clients.len(), 1, "one client for the one worker");
        for c in &m_delta.clients {
            assert_eq!(c.base_uploads, 1, "base once, then diffs: {c:?}");
            assert_eq!(c.stale_deltas, 0, "{c:?}");
            assert_eq!(c.delta_hit_rate(), 1.0);
        }
        assert_eq!(m_delta.stale_deltas, 0);
        assert!(m_full.conserved() && m_delta.conserved());
        assert!(m_delta.clients_conserved(), "{m_delta:?}");
    }

    #[test]
    fn tensor_engine_recovers_from_eviction_via_full_reupload() {
        // two delta-shipping engines on a ONE-slot session: every
        // enforcement evicts the other's chain, so the engines must
        // transparently fall back to re-uploading a fresh base — wrong
        // answers or poisoned engines are not acceptable degradations.
        use crate::ac::{rtac::RtacNative, Counters, Propagator};
        use crate::coordinator::TensorEngine;
        use crate::gen::random::{random_csp, RandomSpec};
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.6, 0.35, 43));
        let (h, join) = reference_session_with_slots(&p, bucket, 1);
        let mut engines = [TensorEngine::new(h.clone()), TensorEngine::new(h.clone())];
        for round in 0..3 {
            for (i, engine) in engines.iter_mut().enumerate() {
                let mut s = crate::core::State::new(&p);
                let x = (round + i) % p.n_vars();
                s.assign(x, 0);
                let mut c = Counters::default();
                let out = engine.enforce(&p, &mut s, &[], &mut c);
                assert!(engine.failed.is_none(), "e{i} r{round}: {:?}", engine.failed);
                let mut s_ref = crate::core::State::new(&p);
                s_ref.assign(x, 0);
                let mut c_ref = Counters::default();
                let out_ref = RtacNative::dense().enforce(&p, &mut s_ref, &[], &mut c_ref);
                assert_eq!(out.is_consistent(), out_ref.is_consistent(), "e{i} r{round}");
                if out.is_consistent() {
                    assert_eq!(s.snapshot(), s_ref.snapshot(), "e{i} r{round}");
                }
            }
        }
        let m = h.metrics.snapshot();
        assert!(m.base_evictions > 0, "the 1-slot session must have evicted: {m:?}");
        assert!(m.stale_deltas > 0, "evictions must surface as counted stale drops");
        assert!(m.conserved() && m.clients_conserved(), "{m:?}");
        drop(engines);
        drop(h);
        join.join().unwrap();
    }

    #[test]
    fn mixed_backend_reaches_sac1_fixpoint_under_all_forced_splits() {
        // the mixed-splits leg of the satellite property test, offline:
        // the tensor half speaks the real session protocol (delta mode
        // included) to the CPU-reference executor, so forced CPU-only,
        // forced tensor-only, AND auto splits all run end-to-end and
        // must reach the unique SAC closure of sequential SAC-1.
        use crate::ac::sac::{MixedProbeBackend, MixedSplit, Sac1, SacParallel};
        use crate::ac::{rtac::RtacNative, Counters};
        use crate::gen::random::{random_csp, RandomSpec};
        let bucket = Bucket { n: 16, d: 8 };
        for seed in [3u64, 14, 41] {
            let p = random_csp(&RandomSpec::new(8, 5, 0.75, 0.4, seed));
            let mut s_ref = crate::core::State::new(&p);
            let mut c_ref = Counters::default();
            let o_ref =
                Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_ref, &mut c_ref);
            for (label, split, delta) in [
                ("cpu-only", MixedSplit::CpuOnly, true),
                ("tensor-only-delta", MixedSplit::TensorOnly, true),
                ("tensor-only-full", MixedSplit::TensorOnly, false),
                ("auto", MixedSplit::Auto, true),
            ] {
                let (h, join) = reference_session(&p, bucket);
                let backend = if delta {
                    MixedProbeBackend::with_tensor_delta(2, h.clone(), 4)
                } else {
                    MixedProbeBackend::with_tensor(2, h.clone(), 4)
                }
                .with_split(split);
                let stats = backend.stats();
                let mut engine = SacParallel::with_backend(Box::new(backend));
                let mut s = crate::core::State::new(&p);
                let mut c = Counters::default();
                let o = engine.enforce_sac(&p, &mut s, &mut c);
                assert!(
                    engine.failed.is_none(),
                    "seed {seed} {label}: {:?}",
                    engine.failed
                );
                assert_eq!(
                    o.is_consistent(),
                    o_ref.is_consistent(),
                    "seed {seed} {label}: outcome"
                );
                if o_ref.is_consistent() {
                    assert_eq!(
                        s.snapshot(),
                        s_ref.snapshot(),
                        "seed {seed} {label}: the SAC closure is unique"
                    );
                }
                match split {
                    MixedSplit::CpuOnly => {
                        assert_eq!(stats.tensor_probes(), 0, "seed {seed} {label}")
                    }
                    MixedSplit::TensorOnly => {
                        assert_eq!(stats.cpu_probes(), 0, "seed {seed} {label}");
                        assert!(stats.tensor_probes() > 0, "seed {seed} {label}");
                    }
                    MixedSplit::Auto => {
                        assert!(
                            stats.cpu_probes() + stats.tensor_probes() > 0,
                            "seed {seed} {label}"
                        );
                    }
                }
                assert_eq!(stats.tensor_fallbacks(), 0, "seed {seed} {label}");
                let m = h.metrics.snapshot();
                assert!(m.conserved(), "seed {seed} {label}: {m:?}");
                assert!(m.clients_conserved(), "seed {seed} {label}: {m:?}");
                assert_eq!(m.stale_deltas, 0, "seed {seed} {label}");
                drop(engine); // drops the backend's Handle clone
                drop(h);
                join.join().unwrap();
            }
        }
    }

    #[test]
    fn mixed_backend_degrades_to_cpu_when_the_executor_dies() {
        // kill the "session" mid-run: the tensor share must fall back
        // to the CPU (same verdicts) and the engine must NOT poison —
        // the degradation contract of sac-mixed.
        use crate::ac::sac::{MixedProbeBackend, MixedSplit, Sac1, SacParallel};
        use crate::ac::{rtac::RtacNative, Counters};
        let bucket = Bucket { n: 8, d: 4 };
        let p = crate::gen::pigeonhole(3, 2);
        let (h, rx) = handle_at(bucket);
        drop(rx); // executor gone before the first round
        let backend =
            MixedProbeBackend::with_tensor_delta(2, h, 4).with_split(MixedSplit::TensorOnly);
        let stats = backend.stats();
        let mut engine = SacParallel::with_backend(Box::new(backend));
        let mut s = crate::core::State::new(&p);
        let mut c = Counters::default();
        let o = engine.enforce_sac(&p, &mut s, &mut c);
        assert!(engine.failed.is_none(), "degradation must not poison: {:?}", engine.failed);
        assert!(stats.tensor_fallbacks() >= 1, "the fallback must be recorded");
        assert!(stats.cpu_probes() > 0, "the tensor share must have re-run on the CPU");
        // and the result still matches sequential SAC-1
        let mut s_ref = crate::core::State::new(&p);
        let o_ref = Sac1::new(RtacNative::incremental()).enforce_sac(&p, &mut s_ref, &mut c);
        assert_eq!(o.is_consistent(), o_ref.is_consistent());
    }

    // ---- supervisor (restart budget + backoff) ------------------------

    #[test]
    fn supervisor_restarts_on_streaks_not_single_failures() {
        let mut s = Supervisor::new(3);
        assert!(!s.on_batch_failed(), "one failure is not a streak");
        s.on_batch_ok(); // recovery resets the streak
        assert!(!s.on_batch_failed());
        assert!(s.on_batch_failed(), "FAILED_STREAK_LIMIT consecutive failures restart");
    }

    #[test]
    fn supervisor_backoff_doubles_and_budget_exhausts() {
        let mut s = Supervisor::new(2);
        assert_eq!(s.begin_restart(), Some(Supervisor::BASE_BACKOFF));
        assert_eq!(s.begin_restart(), Some(Supervisor::BASE_BACKOFF * 2));
        assert_eq!(s.begin_restart(), None, "the third restart exceeds the budget");
        assert_eq!(s.restarts(), 2, "a refused restart spends nothing");
    }

    #[test]
    fn supervisor_restart_resets_the_streak() {
        let mut s = Supervisor::new(4);
        s.on_batch_failed();
        assert!(s.on_batch_failed());
        s.begin_restart().expect("budget available");
        assert!(!s.on_batch_failed(), "the streak must not survive a restart");
    }

    // ---- startup fence deadline (satellite: bounded ready-wait) -------

    #[test]
    fn await_ready_timeout_names_the_startup_fence() {
        let (tx, rx) = mpsc::channel::<Result<()>>();
        let e = await_ready(&rx, Duration::from_millis(20)).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("startup"), "must name startup: {msg}");
        assert!(msg.contains("timed out"), "must name the deadline: {msg}");
        drop(tx); // the init was merely hung, not dead, until here
    }

    #[test]
    fn await_ready_disconnect_names_the_dead_thread() {
        let (tx, rx) = mpsc::channel::<Result<()>>();
        drop(tx);
        let e = await_ready(&rx, Duration::from_secs(1)).unwrap_err();
        assert!(format!("{e:#}").contains("executor thread died during startup"));
    }

    #[test]
    fn await_ready_surfaces_the_init_error() {
        let (tx, rx) = mpsc::channel::<Result<()>>();
        tx.send(Err(anyhow!("boom"))).unwrap();
        let e = await_ready(&rx, Duration::from_secs(1)).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("executor startup failed"), "{msg}");
        assert!(msg.contains("boom"), "the root cause must survive: {msg}");
    }

    #[test]
    fn await_ready_passes_a_successful_init() {
        let (tx, rx) = mpsc::channel::<Result<()>>();
        tx.send(Ok(())).unwrap();
        await_ready(&rx, Duration::from_secs(1)).unwrap();
    }

    // ---- request deadlines --------------------------------------------

    #[test]
    fn blocking_calls_respect_the_request_deadline() {
        // an executor that never answers (we hold rx but don't serve):
        // every blocking wait must return a named timeout, bounded by
        // the handle's request_timeout — never block forever.
        let (mut h, rx) = test_handle();
        h.request_timeout = Duration::from_millis(30);
        let plane = vec![1.0; h.bucket.vars_len()];
        let start = Instant::now();
        let e = h.enforce_blocking(plane.clone()).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("request_timeout"), "must name the knob: {msg}");
        // a batch shares ONE deadline across its waits: two unanswered
        // planes return in ~one timeout, not a timeout per plane
        let e = h.enforce_batch_blocking(vec![plane.clone(), plane]).unwrap_err();
        assert!(format!("{e:#}").contains("timed out"));
        assert!(
            start.elapsed() < Duration::from_millis(500),
            "deadlines must bound the waits, elapsed {:?}",
            start.elapsed()
        );
        drop(rx);
    }

    // ---- fault injection: supervised recovery e2e ---------------------

    #[test]
    fn chaos_plans_conserve_and_reach_the_native_fixpoint() {
        // the tentpole e2e: for every seeded FaultPlan — crashes, hangs,
        // failed-execution streaks, base-cache wipes — a delta-shipping
        // TensorEngine retried under the shared RetryPolicy must reach
        // the SAME fixpoints as the native CPU propagator, and the
        // session metrics must conserve across all restarts.
        use crate::ac::{rtac::RtacNative, Counters, Propagator};
        use crate::coordinator::{Retry, RetryPolicy, TensorEngine};
        use crate::gen::random::{random_csp, RandomSpec};
        let bucket = Bucket { n: 8, d: 4 };
        let p = random_csp(&RandomSpec::new(6, 4, 0.7, 0.4, 11));
        let timeout = Duration::from_millis(250);
        for seed in 1..=8u64 {
            let plan = FaultPlan::seeded(seed);
            eprintln!("chaos seed {seed}: {plan:?}");
            let (h, join) = chaos_session(&p, bucket, plan, timeout, 8);
            let metrics = h.metrics.clone();
            // client-side driver: the same bounded-retry discipline a
            // degrading caller uses — a poisoned engine is reset and
            // retried, never trusted for a verdict
            let retry = RetryPolicy {
                max_attempts: 6,
                base_backoff: Duration::from_millis(5),
                max_backoff: Duration::from_millis(40),
            };
            let mut engine = TensorEngine::new(h.clone());
            for round in 0..6usize {
                let x = round % p.n_vars();
                // the native reference fixpoint for this node
                let mut want = crate::core::State::new(&p);
                want.assign(x, 0);
                let mut reference = RtacNative::dense();
                reference.reset(&p);
                let out_ref =
                    reference.enforce(&p, &mut want, &[], &mut Counters::default());
                // the session path, retried through the injected faults
                let (out, got) = retry
                    .run("chaos round kept dying", |_| {
                        let mut s = crate::core::State::new(&p);
                        s.assign(x, 0);
                        engine.reset(&p);
                        let o = engine.enforce(&p, &mut s, &[], &mut Counters::default());
                        if let Some(e) = engine.failure() {
                            return Err(Retry::Transient(anyhow!(
                                "seed {seed} round {round}: {e}"
                            )));
                        }
                        Ok((o, s))
                    })
                    .unwrap_or_else(|e| panic!("seed {seed} round {round}: {e:#}"));
                assert_eq!(
                    out.is_consistent(),
                    out_ref.is_consistent(),
                    "seed {seed} round {round}: verdicts must agree"
                );
                if out.is_consistent() {
                    assert_eq!(
                        got.snapshot(),
                        want.snapshot(),
                        "seed {seed} round {round}: fixpoints must be bit-identical"
                    );
                }
            }
            drop(engine);
            drop(h);
            join.join().unwrap();
            let m = metrics.snapshot();
            assert!(m.conserved(), "seed {seed}: {}", m.summary());
            assert!(m.clients_conserved(), "seed {seed}: {m:?}");
            assert!(m.executor_restarts <= 8, "seed {seed}: {}", m.summary());
            dump_chaos_snapshot(&format!("chaos_seed_{seed}"), &m);
        }
    }

    #[test]
    fn exhausted_restart_budget_turns_the_session_moribund_not_wrong() {
        // crash on every request with a budget of 1: the first request
        // survives (one restart), every later one is dropped AND
        // counted — the moribund contract, conservation included.
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = crate::gen::random::random_csp(&crate::gen::random::RandomSpec::new(
            6, 4, 0.7, 0.4, 11,
        ));
        let plan = FaultPlan { crash_at: (0..8).collect(), ..FaultPlan::default() };
        let (h, join) = chaos_session(&p, bucket, plan, Duration::from_secs(5), 1);
        let metrics = h.metrics.clone();
        let s = crate::core::State::new(&p);
        let plane = encode_vars(&p, &s, bucket).unwrap();
        h.enforce_blocking(plane.clone())
            .expect("the first crash is inside the restart budget");
        let e = h.enforce_blocking(plane.clone()).unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("moribund") || msg.contains("restart budget"),
            "the drop causes must name the moribund session: {msg}"
        );
        let e = h.enforce_blocking(plane).unwrap_err();
        assert!(format!("{e:#}").contains("dropped"), "{e:#}");
        drop(h);
        join.join().unwrap();
        let m = metrics.snapshot();
        assert_eq!(m.executor_restarts, 1, "{}", m.summary());
        assert_eq!(m.restart_dropped_requests, 2, "{}", m.summary());
        assert!(m.conserved(), "{}", m.summary());
    }

    #[test]
    fn forced_restart_replays_the_base_slots() {
        // Handle::force_restart: the session restarts on demand and the
        // re-hydration replays every resident base slot, so a delta
        // client's chain survives WITHOUT a stale drop.
        use crate::runtime::encode_vars;
        let bucket = Bucket { n: 8, d: 4 };
        let p = crate::gen::random::random_csp(&crate::gen::random::RandomSpec::new(
            6, 4, 0.7, 0.4, 11,
        ));
        let (h, join) =
            chaos_session(&p, bucket, FaultPlan::default(), Duration::from_secs(5), 2);
        let metrics = h.metrics.clone();
        let client = h.attach();
        let s = crate::core::State::new(&p);
        let fp = h.upload_base(client, encode_vars(&p, &s, bucket).unwrap()).unwrap();
        h.enforce_delta_blocking(client, PlaneDelta::empty(fp)).unwrap();
        h.force_restart().unwrap();
        // the SAME fingerprint still resolves: the slot was replayed
        h.enforce_delta_blocking(client, PlaneDelta::empty(fp))
            .expect("a replayed base slot must serve post-restart deltas");
        drop(h);
        join.join().unwrap();
        let m = metrics.snapshot();
        assert_eq!(m.executor_restarts, 1, "{}", m.summary());
        assert_eq!(m.replayed_bases, 1, "{}", m.summary());
        assert_eq!(m.stale_deltas, 0, "replay must prevent the stale drop");
        assert!(m.conserved() && m.clients_conserved(), "{}", m.summary());
    }

    #[test]
    fn exhausted_reupload_retry_surfaces_an_error_not_a_wrong_verdict() {
        // satellite: wipe the base cache before EVERY request, so each
        // fresh-base re-upload goes stale before its delta resolves.
        // The bounded RetryPolicy must exhaust into a NAMED engine
        // failure — and a whole search over the same pathology must
        // still end SAT via the CPU degradation, never a wrong UNSAT.
        use crate::ac::{Counters, Propagator};
        use crate::coordinator::TensorEngine;
        use crate::search::parallel::{solve_parallel_with, WorkerEngine};
        use crate::search::solver::{SolveResult, SolverConfig};
        let wipe_everything =
            || FaultPlan { wipe_bases_at: (0..512).collect(), ..FaultPlan::default() };
        let bucket = Bucket { n: 8, d: 8 };
        let p = crate::gen::queens(6);
        let (h, join) =
            chaos_session(&p, bucket, wipe_everything(), Duration::from_secs(5), 3);
        let mut engine = TensorEngine::new(h.clone());
        let mut s = crate::core::State::new(&p);
        let out = engine.enforce(&p, &mut s, &[], &mut Counters::default());
        assert!(!out.is_consistent(), "a failed engine must not report consistency");
        let failure = engine.failure().expect("the exhausted retry must poison");
        assert!(
            failure.contains("retry budget exhausted"),
            "the failure must name the exhausted budget: {failure}"
        );
        drop(engine);
        drop(h);
        join.join().unwrap();
        // the search layer on the same pathology: worker degrades to
        // the CPU propagator and still proves 6-queens SAT
        let (h2, join2) =
            chaos_session(&p, bucket, wipe_everything(), Duration::from_secs(5), 3);
        let outcome = solve_parallel_with(
            &p,
            &h2,
            &SolverConfig::default(),
            0,
            1,
            WorkerEngine::Tensor,
        )
        .expect("degradation must keep the verdict available");
        match outcome.result {
            SolveResult::Sat(sol) => {
                assert!(p.satisfies(&sol), "the degraded solution must be real")
            }
            other => panic!("6-queens is SAT; degraded search said {other:?}"),
        }
        drop(h2);
        join2.join().unwrap();
    }

    // ---- adaptive batching --------------------------------------------

    #[test]
    fn adaptive_starts_wide_open() {
        let a = AdaptiveBatcher::new(&BatchPolicy::default());
        assert_eq!(a.max_batch(&[1, 4, 8]), 8);
        assert_eq!(a.max_wait(), BatchPolicy::default().max_wait);
    }

    #[test]
    fn adaptive_solo_traffic_stops_waiting() {
        let mut a = AdaptiveBatcher::new(&BatchPolicy::default());
        for _ in 0..16 {
            a.observe(1);
        }
        assert_eq!(a.max_wait(), Duration::ZERO, "solo traffic must not pay the wait");
        // demand ~1 → aim at the smallest compiled size covering 2×demand
        assert_eq!(a.max_batch(&[1, 4, 8]), 4);
    }

    #[test]
    fn adaptive_bursty_traffic_keeps_the_window_and_grows_back() {
        let mut a = AdaptiveBatcher::new(&BatchPolicy::default());
        for _ in 0..16 {
            a.observe(1);
        }
        assert_eq!(a.max_wait(), Duration::ZERO);
        for _ in 0..16 {
            a.observe(8);
        }
        assert_eq!(a.max_wait(), BatchPolicy::default().max_wait);
        assert_eq!(a.max_batch(&[1, 4, 8]), 8, "bursts must grow the cap back");
    }

    #[test]
    fn adaptive_never_exceeds_the_policy_cap() {
        let mut a = AdaptiveBatcher::new(&BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            adaptive: true,
            ..Default::default()
        });
        for _ in 0..8 {
            a.observe(8);
        }
        assert_eq!(a.max_batch(&[1, 4, 8]), 4, "policy.max_batch is a hard cap");
    }
}
