//! The coordination service — the L3 system contribution.
//!
//! One `Coordinator` serves one CSP instance ("session").  Parallel
//! search workers (or remote callers via `rtac serve`) submit
//! arc-consistency requests — a domains plane at the session's shape
//! bucket — and the coordinator **dynamically batches** concurrent
//! requests into one fused `fixpoint_batched` XLA execution, exactly the
//! way a vLLM-style router fuses decode steps: the constraint tensor is
//! resident (uploaded once per session), only the small vars planes move
//! per request.
//!
//! Threading: `PjRtClient` is not `Send`, so a dedicated executor thread
//! owns the `Runtime`, the compiled executables and the cached constraint
//! tensor; an MPSC channel carries requests in, and each request carries
//! its own response sender.  Batching policy (size + deadline) is applied
//! on the executor thread between `recv`s — there is no separate batcher
//! thread to hand off through, which keeps p50 latency at one channel
//! hop.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::core::Problem;
use crate::runtime::{encode_cons, Bucket, Kind, Manifest, Runtime, STATUS_WIPEOUT};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Upper bound on fused requests.  Must be >= 1 (rejected at
    /// [`Coordinator::start`]); values above the largest compiled
    /// `fixb*` size are clamped by the executor.  User-facing callers
    /// (`rtac serve --max-batch`) run [`Coordinator::validate_policy`]
    /// first so an explicit out-of-range value fails fast at startup
    /// instead of being silently clamped.
    pub max_batch: usize,
    /// How long the executor waits for batch-mates after the first
    /// request arrives.  0 disables the wait — requests already sitting
    /// on the queue (e.g. a contiguous [`Handle::submit_batch`] probe
    /// batch) still fuse, because the executor drains the queue greedily
    /// before executing.
    pub max_wait: Duration,
    /// Derive the effective (max_batch, max_wait) from the observed
    /// queue demand instead of the fixed values above: solo traffic
    /// stops paying the coalescing wait, bursty traffic grows the batch
    /// cap toward the largest compiled size.  `max_batch` stays the hard
    /// upper bound; `max_wait` the longest wait.  See [`AdaptiveBatcher`].
    pub adaptive: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(300), adaptive: false }
    }
}

/// §Adaptive batching: derives the effective batching knobs from the
/// observed queue demand (an EWMA of how many requests were pending at
/// each execute decision) instead of a fixed policy.
///
/// * `max_wait` — when the demand says requests arrive alone, waiting
///   for batch-mates only adds latency, so the wait drops to zero; once
///   fusible traffic shows up the configured wait comes back.
/// * `max_batch` — aimed at [`AdaptiveBatcher::HEADROOM`]× the demand
///   (rounded up to a compiled batch size) so the executor stops
///   coalescing at a size traffic can actually fill, while bursts keep
///   enough headroom to grow the cap back within a few observations.
///
/// Pure bookkeeping (no clock, no channel) so the policy is unit-tested
/// independently of the executor loop.
pub(crate) struct AdaptiveBatcher {
    /// Hard caps from the configured policy.
    cap_batch: usize,
    cap_wait: Duration,
    /// EWMA of queue demand at execute decisions; `None` before the
    /// first observation (start wide open: largest batch, full wait).
    demand: Option<f64>,
}

impl AdaptiveBatcher {
    const ALPHA: f64 = 0.25;
    const HEADROOM: f64 = 2.0;
    /// Below this demand the traffic is effectively solo and the
    /// coalescing wait is pure latency.
    const SOLO_DEMAND: f64 = 1.5;

    pub(crate) fn new(policy: &BatchPolicy) -> AdaptiveBatcher {
        AdaptiveBatcher { cap_batch: policy.max_batch, cap_wait: policy.max_wait, demand: None }
    }

    /// Record the queue demand observed at one execute decision.
    pub(crate) fn observe(&mut self, demand: usize) {
        let d = demand as f64;
        self.demand = Some(match self.demand {
            None => d,
            Some(prev) => Self::ALPHA * d + (1.0 - Self::ALPHA) * prev,
        });
    }

    /// Effective batch cap given the compiled sizes (ascending, deduped).
    pub(crate) fn max_batch(&self, compiled: &[usize]) -> usize {
        let largest = compiled.last().copied().unwrap_or(1).min(self.cap_batch).max(1);
        let Some(demand) = self.demand else {
            return largest;
        };
        let want = (demand * Self::HEADROOM).ceil().max(1.0) as usize;
        compiled
            .iter()
            .copied()
            .find(|&b| b >= want)
            .unwrap_or(largest)
            .min(largest)
    }

    /// Effective coalescing wait.
    pub(crate) fn max_wait(&self) -> Duration {
        match self.demand {
            Some(d) if d < Self::SOLO_DEMAND => Duration::ZERO,
            _ => self.cap_wait,
        }
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            policy: BatchPolicy::default(),
        }
    }
}

/// A request: one domains plane to enforce.
struct Request {
    plane: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// A response: the enforced plane plus run metadata.
#[derive(Clone, Debug)]
pub struct Response {
    pub plane: Vec<f32>,
    /// 0 = consistent, 1 = wipeout (see `runtime::STATUS_*`).
    pub status: i32,
    /// Joint sweep count of the batch that served this request.
    pub iters: i32,
    /// *Real* requests fused into the execution that served this request
    /// (padded slots excluded).
    pub batch_real: usize,
    /// Compiled capacity of that execution, padding included — so the
    /// call site can compute fused-batch occupancy
    /// ([`Response::occupancy`]) without access to the manifest.
    pub batch_capacity: usize,
    pub queue_time: Duration,
    pub total_time: Duration,
}

impl Response {
    pub fn wiped(&self) -> bool {
        self.status == STATUS_WIPEOUT
    }

    /// Fraction of the serving execution's slots holding real requests.
    pub fn occupancy(&self) -> f64 {
        self.batch_real as f64 / self.batch_capacity.max(1) as f64
    }
}

/// Cloneable client handle to a running coordinator.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Request>,
    pub bucket: Bucket,
    pub metrics: Arc<Metrics>,
}

impl Handle {
    /// Submit a plane; returns a receiver for the response.
    pub fn submit(&self, plane: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if plane.len() != self.bucket.vars_len() {
            bail!(
                "plane has {} values, session bucket wants {}",
                plane.len(),
                self.bucket.vars_len()
            );
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request { plane, submitted: Instant::now(), resp: rtx })
            .map_err(|_| self.executor_gone_err())?;
        self.metrics.on_submit(); // count only planes that reached the queue
        Ok(rrx)
    }

    /// The executor's request channel is closed: it exited (or the
    /// session was shut down).  Diagnose *why* from the shared metrics
    /// so callers see more than a bare channel error.
    fn executor_gone_err(&self) -> anyhow::Error {
        let m = self.metrics.snapshot();
        if m.failed_batches > 0 {
            anyhow!(
                "coordinator executor is gone after {} failed fused execution(s) \
                 ({} request(s) dropped; see the rtac-executor log)",
                m.failed_batches,
                m.dropped_requests
            )
        } else {
            anyhow!("coordinator is shut down (executor thread exited)")
        }
    }

    /// A submitted request's responder was dropped without an answer:
    /// its fused execution failed, or the executor exited with the
    /// request in flight.
    fn dropped_err(&self) -> anyhow::Error {
        let m = self.metrics.snapshot();
        if m.failed_batches > 0 {
            anyhow!(
                "coordinator dropped the request: {} fused execution(s) failed on the \
                 executor ({} request(s) dropped; see the rtac-executor log)",
                m.failed_batches,
                m.dropped_requests
            )
        } else {
            anyhow!(
                "coordinator executor exited before answering (session shut down with \
                 the request in flight)"
            )
        }
    }

    /// Submit and block for the result.
    pub fn enforce_blocking(&self, plane: Vec<f32>) -> Result<Response> {
        let rx = self.submit(plane)?;
        rx.recv().map_err(|_| self.dropped_err())
    }

    /// Submit several planes back-to-back — the batched-probe path.
    ///
    /// A SAC enforcement produces K independent singleton probes at
    /// once (see `ac/sac.rs`); submitting them through this path puts
    /// them on the executor queue contiguously, so the dynamic batcher
    /// coalesces them into as few fused executions as the compiled
    /// batch sizes allow instead of gambling each probe against the
    /// `max_wait` deadline separately.  Shape validation happens up
    /// front, before anything is enqueued; a coordinator shutdown
    /// mid-batch still returns `Err` with the earlier planes already
    /// on the (dead) queue — their responses are simply never sent.
    ///
    /// Returns one response receiver per plane, in submission order.
    pub fn submit_batch(&self, planes: Vec<Vec<f32>>) -> Result<Vec<mpsc::Receiver<Response>>> {
        for (i, plane) in planes.iter().enumerate() {
            if plane.len() != self.bucket.vars_len() {
                bail!(
                    "batch plane {i} has {} values, session bucket wants {}",
                    plane.len(),
                    self.bucket.vars_len()
                );
            }
        }
        let submitted = Instant::now();
        let mut receivers = Vec::with_capacity(planes.len());
        for plane in planes {
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .send(Request { plane, submitted, resp: rtx })
                .map_err(|_| self.executor_gone_err())?;
            self.metrics.on_submit(); // only planes that actually reached the queue
            receivers.push(rrx);
        }
        Ok(receivers)
    }

    /// Submit a probe batch and block for every response, in order.
    pub fn enforce_batch_blocking(&self, planes: Vec<Vec<f32>>) -> Result<Vec<Response>> {
        self.submit_batch(planes)?
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                rx.recv()
                    .map_err(|_| self.dropped_err())
                    .with_context(|| format!("batched probe {i}"))
            })
            .collect()
    }
}

/// A running coordinator session.
pub struct Coordinator {
    handle: Handle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a session for `problem`.  Blocks until the executor thread
    /// has loaded the runtime, compiled the artifacts AND uploaded the
    /// constraint tensor (so a broken artifact dir — or a failed upload —
    /// fails fast, here, not on first request).
    pub fn start(problem: &Problem, config: CoordinatorConfig) -> Result<Coordinator> {
        // pick the bucket from the manifest before spawning, so errors
        // (problem too large for any artifact, zero max_batch) surface
        // synchronously.  An *oversized* max_batch is clamped to the
        // largest compiled size by the executor (programmatic callers
        // with the default policy must keep working on reduced artifact
        // sets); callers with an explicit user-facing knob (`rtac serve
        // --max-batch`) use [`Coordinator::validate_policy`] to fail
        // fast instead.
        let (_, bucket) = pick_bucket(problem, &config)?;
        let cons = encode_cons(problem, bucket)?;

        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let cfg = config.clone();
        let metrics2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name("rtac-executor".into())
            .spawn(move || {
                executor_thread(cfg, bucket, cons, rx, ready_tx, metrics2);
            })
            .context("spawning executor thread")?;

        ready_rx
            .recv()
            .context("executor thread died during startup")?
            .context("executor startup failed")?;

        Ok(Coordinator { handle: Handle { tx, bucket, metrics }, join: Some(join) })
    }

    /// Validate `config.policy` against the compiled artifacts for
    /// `problem` *without* starting a session: picks the shape bucket
    /// (the same way [`Coordinator::start`] will) and checks `max_batch`
    /// against the compiled `fixb*` batch sizes.  `rtac serve` calls
    /// this so an explicit `--max-batch` with no matching artifact fails
    /// at startup with a clear message — the old behavior surfaced it
    /// only on the first fused request, as a mid-run execution failure.
    /// (Without this check, oversized caps are silently clamped by the
    /// executor.)
    pub fn validate_policy(problem: &Problem, config: &CoordinatorConfig) -> Result<()> {
        let (manifest, bucket) = pick_bucket(problem, config)?;
        let compiled = compiled_batch_sizes(&manifest, bucket);
        let largest = compiled.last().copied().unwrap_or(1);
        if config.policy.max_batch > largest {
            bail!(
                "max_batch {} exceeds the compiled batch sizes {:?} for bucket {}x{} \
                 (largest fused executable is fixb{}_n{}_d{}; recompile the artifacts \
                 or lower --max-batch)",
                config.policy.max_batch,
                compiled,
                bucket.n,
                bucket.d,
                largest,
                bucket.n,
                bucket.d
            );
        }
        Ok(())
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    pub fn bucket(&self) -> Bucket {
        self.handle.bucket
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.handle.metrics.clone()
    }
}

impl Coordinator {
    /// Graceful shutdown: drop the session's sender and join the
    /// executor.  Callers must have dropped their `Handle` clones first
    /// or this blocks until they do.
    pub fn shutdown(mut self) {
        let (dead_tx, _) = mpsc::channel();
        self.handle.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Detach: the executor thread exits on its own once every Handle
        // (and our sender) is gone.  Joining here could deadlock against
        // user-held Handle clones.
        self.join.take();
    }
}

/// The shared session preamble of [`Coordinator::start`] and
/// [`Coordinator::validate_policy`]: load the manifest, pick the shape
/// bucket for `problem`, and reject a zero `max_batch` (which could
/// never execute anything, for any caller).  Keeping this in one place
/// guarantees validation and startup agree on the bucket.
fn pick_bucket(problem: &Problem, config: &CoordinatorConfig) -> Result<(Manifest, Bucket)> {
    let manifest = Manifest::load(&config.artifact_dir)?;
    let n = problem.n_vars();
    let d = problem.max_dom_size();
    let entry = manifest
        .pick(Kind::Fixpoint, n, d, 1)
        .ok_or_else(|| anyhow!("no artifact bucket fits ({n} vars × {d} values)"))?;
    let bucket = Bucket { n: entry.n, d: entry.d };
    if config.policy.max_batch == 0 {
        bail!("max_batch must be >= 1");
    }
    Ok((manifest, bucket))
}

/// Compiled batch sizes (ascending, deduped) of the fixpoint family at
/// `bucket` — the capacities `executor_thread` can actually dispatch to.
fn compiled_batch_sizes(manifest: &Manifest, bucket: Bucket) -> Vec<usize> {
    let mut sizes: Vec<usize> = manifest
        .entries
        .iter()
        .filter(|e| e.n == bucket.n && e.d == bucket.d)
        .filter(|e| matches!(e.kind, Kind::Fixpoint | Kind::FixpointBatched))
        .map(|e| e.batch)
        .collect();
    sizes.sort();
    sizes.dedup();
    sizes
}

/// The startup fence: the ONE place the ready signal is sent.  `init` is
/// everything the executor needs before it can serve — runtime load,
/// artifact compilation, the constraint-tensor upload — and the ready
/// send happens strictly *after* it resolves.  `Coordinator::start`
/// returning `Ok` therefore guarantees a live, fully-initialised
/// executor; an upload failure surfaces there as `Err`, not as a dead
/// session whose every later `submit` fails with "shut down".
fn send_ready<T>(ready_tx: &mpsc::Sender<Result<()>>, init: Result<T>) -> Option<T> {
    match init {
        Ok(v) => {
            let _ = ready_tx.send(Ok(()));
            Some(v)
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            None
        }
    }
}

/// Executor main loop: owns all XLA state.
fn executor_thread(
    config: CoordinatorConfig,
    bucket: Bucket,
    cons: Vec<f32>,
    rx: mpsc::Receiver<Request>,
    ready_tx: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
) {
    let init = (|| -> Result<(Runtime, crate::runtime::DeviceTensor, Vec<usize>)> {
        // Load only this session's bucket (all batch sizes + the
        // unbatched fixpoint), keeping startup proportional to what
        // we'll run.
        let runtime = Runtime::load_filtered(&config.artifact_dir, |e| {
            e.n == bucket.n
                && e.d == bucket.d
                && matches!(e.kind, Kind::Fixpoint | Kind::FixpointBatched)
        })?;
        let batch_sizes = compiled_batch_sizes(runtime.manifest(), bucket);
        // §Perf L3: upload the session's constraint tensor ONCE; every
        // batch then moves only the small vars planes host→device.
        let cons_dev = runtime
            .upload(&cons, &[bucket.n, bucket.n, bucket.d, bucket.d])
            .context("uploading the session constraint tensor")?;
        Ok((runtime, cons_dev, batch_sizes))
    })();
    let Some((runtime, cons_dev, batch_sizes)) = send_ready(&ready_tx, init) else {
        return;
    };
    drop(cons);

    let compiled_max = batch_sizes.last().copied().unwrap_or(1);
    let mut adaptive =
        if config.policy.adaptive { Some(AdaptiveBatcher::new(&config.policy)) } else { None };
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // 1. block for the first request (or shut down)
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => return, // all handles dropped
            }
        }
        let (max_batch, max_wait) = match &adaptive {
            Some(a) => (a.max_batch(&batch_sizes), a.max_wait()),
            None => (config.policy.max_batch.min(compiled_max), config.policy.max_wait),
        };
        // 2a. drain already-queued requests greedily (no waiting): a
        // contiguous `submit_batch` probe batch fuses even at
        // max_wait == 0 — only *absent* batch-mates cost wall time.
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // 2b. coalesce further batch-mates until the deadline or capacity
        if !max_wait.is_zero() {
            let deadline = Instant::now() + max_wait;
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        if let Some(a) = &mut adaptive {
            a.observe(pending.len());
        }
        // 3. pick the smallest compiled batch that fits, pad, execute
        let real = pending.len();
        let capacity = batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= real)
            .unwrap_or_else(|| *batch_sizes.last().unwrap());
        let (capacity, take) = if capacity >= real {
            (capacity, real)
        } else {
            (capacity, capacity) // more pending than largest batch: split
        };
        let batch: Vec<Request> = pending.drain(..take).collect();
        let plane_len = bucket.vars_len();
        let mut input = Vec::with_capacity(capacity * plane_len);
        for r in &batch {
            input.extend_from_slice(&r.plane);
        }
        // padding: replicate the first plane — it converges in the same
        // sweeps as its twin, adding no extra joint iterations.
        for _ in take..capacity {
            input.extend_from_slice(&batch[0].plane);
        }

        let name = artifact_name(capacity, bucket);
        let t_exec = Instant::now();
        let result = runtime.run_fixpoint_dev(&name, &cons_dev, &input);
        let exec = t_exec.elapsed();

        // Metrics are recorded only once the execution result is known:
        // a failed XLA run counts as a failed batch with dropped
        // requests, never as a served batch that would skew occupancy
        // and exec stats.
        match result {
            Ok(out) => {
                metrics.on_batch(take, capacity, exec);
                for (i, req) in batch.into_iter().enumerate() {
                    let queue = t_exec.duration_since(req.submitted);
                    let total = req.submitted.elapsed();
                    let resp = Response {
                        plane: out.vars[i * plane_len..(i + 1) * plane_len].to_vec(),
                        status: out.status[i],
                        iters: out.iters,
                        batch_real: take,
                        batch_capacity: capacity,
                        queue_time: queue,
                        total_time: total,
                    };
                    metrics.on_response(queue, total, out.iters, resp.wiped());
                    let _ = req.resp.send(resp); // receiver may have gone
                }
            }
            Err(e) => {
                // drop the responders: receivers see a clear dropped-
                // request error from `Handle` (backed by these counters);
                // log once on this side.
                metrics.on_batch_failed(take);
                eprintln!(
                    "rtac-executor: fused execution {name} failed ({take} request(s) \
                     dropped): {e:#}"
                );
            }
        }
    }
}

/// Artifact naming scheme shared with `python/compile/aot.py`.
fn artifact_name(capacity: usize, bucket: Bucket) -> String {
    if capacity == 1 {
        format!("fix_n{}_d{}", bucket.n, bucket.d)
    } else {
        format!("fixb{}_n{}_d{}", capacity, bucket.n, bucket.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_aot_scheme() {
        let b = Bucket { n: 16, d: 8 };
        assert_eq!(artifact_name(1, b), "fix_n16_d8");
        assert_eq!(artifact_name(4, b), "fixb4_n16_d8");
        assert_eq!(artifact_name(8, b), "fixb8_n16_d8");
    }

    #[test]
    fn default_policy_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait < Duration::from_millis(10));
    }

    fn test_handle() -> (Handle, mpsc::Receiver<Request>) {
        let (tx, rx) = mpsc::channel();
        let handle = Handle {
            tx,
            bucket: Bucket { n: 2, d: 2 },
            metrics: Arc::new(Metrics::new()),
        };
        (handle, rx)
    }

    #[test]
    fn submit_batch_validates_before_enqueuing_anything() {
        let (h, rx) = test_handle();
        let bad = vec![vec![1.0; h.bucket.vars_len()], vec![0.0; 3]];
        assert!(h.submit_batch(bad).is_err());
        assert!(rx.try_recv().is_err(), "no plane may be enqueued on a rejected batch");
        assert_eq!(h.metrics.snapshot().requests, 0);
    }

    #[test]
    fn submit_batch_enqueues_in_order() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let planes = vec![vec![1.0; len], vec![0.5; len], vec![0.0; len]];
        let receivers = h.submit_batch(planes.clone()).unwrap();
        assert_eq!(receivers.len(), 3);
        for want in &planes {
            let got = rx.try_recv().expect("plane enqueued");
            assert_eq!(&got.plane, want);
        }
        assert_eq!(h.metrics.snapshot().requests, 3);
    }

    // ---- startup fence -------------------------------------------------

    #[test]
    fn startup_fence_failing_upload_reaches_start_not_a_dead_executor() {
        // Regression: the ready signal used to be sent after the runtime
        // load but BEFORE the constraint-tensor upload, so an upload
        // failure left `Coordinator::start` returning Ok with a dead
        // executor.  `send_ready` is the single send site, fed by the
        // FULL init result; a failing-upload stub must surface as Err on
        // the ready channel and abort the executor (None).
        let (tx, rx) = mpsc::channel::<Result<()>>();
        let init: Result<u32> = Err(anyhow!("xla: buffer_from_host_buffer failed"))
            .context("uploading the session constraint tensor");
        assert!(send_ready(&tx, init).is_none(), "a failed init must stop the executor");
        let err = rx.recv().unwrap().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("constraint tensor"), "unhelpful startup error: {msg}");
    }

    #[test]
    fn startup_fence_sends_ready_only_on_success() {
        let (tx, rx) = mpsc::channel::<Result<()>>();
        let got = send_ready(&tx, Ok(42u32));
        assert_eq!(got, Some(42));
        assert!(rx.recv().unwrap().is_ok());
    }

    // ---- executor-death error surface ---------------------------------

    #[test]
    fn submit_after_executor_exit_names_the_executor() {
        let (h, rx) = test_handle();
        drop(rx); // the "executor" is gone
        let err = h.submit(vec![1.0; h.bucket.vars_len()]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("executor"), "bare channel error leaked: {msg}");
    }

    #[test]
    fn dropped_request_error_blames_failed_batch_when_one_happened() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let metrics = h.metrics.clone();
        let executor = std::thread::spawn(move || {
            // fake executor: receive one request, fail its "execution",
            // drop the responder without answering, then exit.
            let req = rx.recv().unwrap();
            metrics.on_batch_failed(1);
            drop(req);
            drop(rx);
        });
        let err = h.enforce_blocking(vec![1.0; len]).unwrap_err();
        executor.join().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("failed"), "error must mention the failed execution: {msg}");
        let m = h.metrics.snapshot();
        assert_eq!(m.failed_batches, 1);
        assert!(m.conserved(), "requests == responses + dropped: {m:?}");
    }

    #[test]
    fn dropped_batched_request_error_is_clear_and_indexed() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let metrics = h.metrics.clone();
        let executor = std::thread::spawn(move || {
            // answer the first probe, then die with the second in flight
            let req = rx.recv().unwrap();
            let resp = Response {
                plane: req.plane.clone(),
                status: 0,
                iters: 1,
                batch_real: 1,
                batch_capacity: 4,
                queue_time: Duration::ZERO,
                total_time: Duration::ZERO,
            };
            metrics.on_batch(1, 4, Duration::from_micros(5));
            metrics.on_response(Duration::ZERO, Duration::ZERO, 1, false);
            let _ = req.resp.send(resp);
            let second = rx.recv().unwrap();
            metrics.on_batch_failed(1);
            drop(second);
            drop(rx);
        });
        let err = h
            .enforce_batch_blocking(vec![vec![1.0; len], vec![0.5; len]])
            .unwrap_err();
        executor.join().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("batched probe 1"), "which probe died? {msg}");
        assert!(msg.contains("failed"), "why did it die? {msg}");
        let m = h.metrics.snapshot();
        assert_eq!(m.requests, 2);
        assert_eq!(m.responses, 1);
        assert_eq!(m.dropped_requests, 1);
        assert!(m.conserved());
    }

    #[test]
    fn metrics_conserved_across_mixed_single_and_batched_submissions() {
        // requests == responses + dropped once the queue drains, across
        // a mix of single submits, a fused probe batch, and a failure.
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let metrics = h.metrics.clone();
        let thread_metrics = metrics.clone();
        let executor = std::thread::spawn(move || {
            let mut served = 0usize;
            while let Ok(req) = rx.recv() {
                if served == 3 {
                    // fourth request: its fused execution "fails"
                    thread_metrics.on_batch_failed(1);
                    drop(req);
                } else {
                    thread_metrics.on_batch(1, 1, Duration::from_micros(3));
                    thread_metrics.on_response(Duration::ZERO, Duration::ZERO, 1, false);
                    let resp = Response {
                        plane: req.plane.clone(),
                        status: 0,
                        iters: 1,
                        batch_real: 1,
                        batch_capacity: 1,
                        queue_time: Duration::ZERO,
                        total_time: Duration::ZERO,
                    };
                    let _ = req.resp.send(resp);
                }
                served += 1;
            }
        });
        assert!(h.enforce_blocking(vec![1.0; len]).is_ok());
        let batch = h.enforce_batch_blocking(vec![vec![1.0; len], vec![0.5; len]]).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(h.enforce_blocking(vec![0.0; len]).is_err(), "dropped request must error");
        drop(h); // last sender gone: the fake executor drains and exits
        executor.join().unwrap();
        let m = metrics.snapshot();
        assert_eq!(m.requests, 4);
        assert_eq!(m.responses, 3);
        assert_eq!(m.dropped_requests, 1);
        assert_eq!(m.failed_batches, 1);
        assert!(m.conserved(), "requests == responses + dropped: {m:?}");
    }

    // ---- adaptive batching --------------------------------------------

    #[test]
    fn adaptive_starts_wide_open() {
        let a = AdaptiveBatcher::new(&BatchPolicy::default());
        assert_eq!(a.max_batch(&[1, 4, 8]), 8);
        assert_eq!(a.max_wait(), BatchPolicy::default().max_wait);
    }

    #[test]
    fn adaptive_solo_traffic_stops_waiting() {
        let mut a = AdaptiveBatcher::new(&BatchPolicy::default());
        for _ in 0..16 {
            a.observe(1);
        }
        assert_eq!(a.max_wait(), Duration::ZERO, "solo traffic must not pay the wait");
        // demand ~1 → aim at the smallest compiled size covering 2×demand
        assert_eq!(a.max_batch(&[1, 4, 8]), 4);
    }

    #[test]
    fn adaptive_bursty_traffic_keeps_the_window_and_grows_back() {
        let mut a = AdaptiveBatcher::new(&BatchPolicy::default());
        for _ in 0..16 {
            a.observe(1);
        }
        assert_eq!(a.max_wait(), Duration::ZERO);
        for _ in 0..16 {
            a.observe(8);
        }
        assert_eq!(a.max_wait(), BatchPolicy::default().max_wait);
        assert_eq!(a.max_batch(&[1, 4, 8]), 8, "bursts must grow the cap back");
    }

    #[test]
    fn adaptive_never_exceeds_the_policy_cap() {
        let mut a = AdaptiveBatcher::new(&BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            adaptive: true,
        });
        for _ in 0..8 {
            a.observe(8);
        }
        assert_eq!(a.max_batch(&[1, 4, 8]), 4, "policy.max_batch is a hard cap");
    }
}
