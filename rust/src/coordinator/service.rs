//! The coordination service — the L3 system contribution.
//!
//! One `Coordinator` serves one CSP instance ("session").  Parallel
//! search workers (or remote callers via `rtac serve`) submit
//! arc-consistency requests — a domains plane at the session's shape
//! bucket — and the coordinator **dynamically batches** concurrent
//! requests into one fused `fixpoint_batched` XLA execution, exactly the
//! way a vLLM-style router fuses decode steps: the constraint tensor is
//! resident (uploaded once per session), only the small vars planes move
//! per request.
//!
//! Threading: `PjRtClient` is not `Send`, so a dedicated executor thread
//! owns the `Runtime`, the compiled executables and the cached constraint
//! tensor; an MPSC channel carries requests in, and each request carries
//! its own response sender.  Batching policy (size + deadline) is applied
//! on the executor thread between `recv`s — there is no separate batcher
//! thread to hand off through, which keeps p50 latency at one channel
//! hop.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::core::Problem;
use crate::runtime::{encode_cons, Bucket, Kind, Manifest, Runtime, STATUS_WIPEOUT};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Upper bound on fused requests (must be a compiled batch size).
    pub max_batch: usize,
    /// How long the executor waits for batch-mates after the first
    /// request arrives.  0 disables coalescing (batch == 1 always).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(300) }
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            policy: BatchPolicy::default(),
        }
    }
}

/// A request: one domains plane to enforce.
struct Request {
    plane: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// A response: the enforced plane plus run metadata.
#[derive(Clone, Debug)]
pub struct Response {
    pub plane: Vec<f32>,
    /// 0 = consistent, 1 = wipeout (see `runtime::STATUS_*`).
    pub status: i32,
    /// Joint sweep count of the batch that served this request.
    pub iters: i32,
    /// Requests fused into the same execution.
    pub batch_size: usize,
    pub queue_time: Duration,
    pub total_time: Duration,
}

impl Response {
    pub fn wiped(&self) -> bool {
        self.status == STATUS_WIPEOUT
    }
}

/// Cloneable client handle to a running coordinator.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Request>,
    pub bucket: Bucket,
    pub metrics: Arc<Metrics>,
}

impl Handle {
    /// Submit a plane; returns a receiver for the response.
    pub fn submit(&self, plane: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if plane.len() != self.bucket.vars_len() {
            bail!(
                "plane has {} values, session bucket wants {}",
                plane.len(),
                self.bucket.vars_len()
            );
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request { plane, submitted: Instant::now(), resp: rtx })
            .map_err(|_| anyhow!("coordinator is shut down"))?;
        self.metrics.on_submit(); // count only planes that reached the queue
        Ok(rrx)
    }

    /// Submit and block for the result.
    pub fn enforce_blocking(&self, plane: Vec<f32>) -> Result<Response> {
        let rx = self.submit(plane)?;
        rx.recv().context("coordinator dropped the request (executor died?)")
    }

    /// Submit several planes back-to-back — the batched-probe path.
    ///
    /// A SAC enforcement produces K independent singleton probes at
    /// once (see `ac/sac.rs`); submitting them through this path puts
    /// them on the executor queue contiguously, so the dynamic batcher
    /// coalesces them into as few fused executions as the compiled
    /// batch sizes allow instead of gambling each probe against the
    /// `max_wait` deadline separately.  Shape validation happens up
    /// front, before anything is enqueued; a coordinator shutdown
    /// mid-batch still returns `Err` with the earlier planes already
    /// on the (dead) queue — their responses are simply never sent.
    ///
    /// Returns one response receiver per plane, in submission order.
    pub fn submit_batch(&self, planes: Vec<Vec<f32>>) -> Result<Vec<mpsc::Receiver<Response>>> {
        for (i, plane) in planes.iter().enumerate() {
            if plane.len() != self.bucket.vars_len() {
                bail!(
                    "batch plane {i} has {} values, session bucket wants {}",
                    plane.len(),
                    self.bucket.vars_len()
                );
            }
        }
        let submitted = Instant::now();
        let mut receivers = Vec::with_capacity(planes.len());
        for plane in planes {
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .send(Request { plane, submitted, resp: rtx })
                .map_err(|_| anyhow!("coordinator is shut down"))?;
            self.metrics.on_submit(); // only planes that actually reached the queue
            receivers.push(rrx);
        }
        Ok(receivers)
    }

    /// Submit a probe batch and block for every response, in order.
    pub fn enforce_batch_blocking(&self, planes: Vec<Vec<f32>>) -> Result<Vec<Response>> {
        self.submit_batch(planes)?
            .into_iter()
            .map(|rx| rx.recv().context("coordinator dropped a batched request (executor died?)"))
            .collect()
    }
}

/// A running coordinator session.
pub struct Coordinator {
    handle: Handle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a session for `problem`.  Blocks until the executor thread
    /// has loaded the runtime and encoded the constraint tensor (so a
    /// broken artifact dir fails fast, here, not on first request).
    pub fn start(problem: &Problem, config: CoordinatorConfig) -> Result<Coordinator> {
        // pick the bucket from the manifest before spawning, so errors
        // (problem too large for any artifact) surface synchronously.
        let manifest = Manifest::load(&config.artifact_dir)?;
        let n = problem.n_vars();
        let d = problem.max_dom_size();
        let entry = manifest
            .pick(Kind::Fixpoint, n, d, 1)
            .ok_or_else(|| anyhow!("no artifact bucket fits ({n} vars × {d} values)"))?;
        let bucket = Bucket { n: entry.n, d: entry.d };
        let cons = encode_cons(problem, bucket)?;

        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let cfg = config.clone();
        let metrics2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name("rtac-executor".into())
            .spawn(move || {
                executor_thread(cfg, bucket, cons, rx, ready_tx, metrics2);
            })
            .context("spawning executor thread")?;

        ready_rx
            .recv()
            .context("executor thread died during startup")?
            .context("executor startup failed")?;

        Ok(Coordinator { handle: Handle { tx, bucket, metrics }, join: Some(join) })
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    pub fn bucket(&self) -> Bucket {
        self.handle.bucket
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.handle.metrics.clone()
    }
}

impl Coordinator {
    /// Graceful shutdown: drop the session's sender and join the
    /// executor.  Callers must have dropped their `Handle` clones first
    /// or this blocks until they do.
    pub fn shutdown(mut self) {
        let (dead_tx, _) = mpsc::channel();
        self.handle.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Detach: the executor thread exits on its own once every Handle
        // (and our sender) is gone.  Joining here could deadlock against
        // user-held Handle clones.
        self.join.take();
    }
}

/// Executor main loop: owns all XLA state.
fn executor_thread(
    config: CoordinatorConfig,
    bucket: Bucket,
    cons: Vec<f32>,
    rx: mpsc::Receiver<Request>,
    ready_tx: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
) {
    // Load only this session's bucket (all batch sizes + the unbatched
    // fixpoint), keeping startup proportional to what we'll run.
    let runtime = match Runtime::load_filtered(&config.artifact_dir, |e| {
        e.n == bucket.n
            && e.d == bucket.d
            && matches!(e.kind, Kind::Fixpoint | Kind::FixpointBatched)
    }) {
        Ok(rt) => {
            let _ = ready_tx.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let mut batch_sizes: Vec<usize> = runtime
        .manifest()
        .entries
        .iter()
        .filter(|e| e.n == bucket.n && e.d == bucket.d)
        .filter(|e| matches!(e.kind, Kind::Fixpoint | Kind::FixpointBatched))
        .map(|e| e.batch)
        .collect();
    batch_sizes.sort();
    batch_sizes.dedup();
    let max_batch = config
        .policy
        .max_batch
        .min(batch_sizes.last().copied().unwrap_or(1));

    // §Perf L3: upload the session's constraint tensor ONCE; every batch
    // then moves only the small vars planes host→device.
    let cons_dev = match runtime.upload(&cons, &[bucket.n, bucket.n, bucket.d, bucket.d]) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("rtac-executor: cons upload failed: {e:#}");
            return;
        }
    };
    drop(cons);

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // 1. block for the first request (or shut down)
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => return, // all handles dropped
            }
        }
        // 2. coalesce batch-mates until the deadline or capacity
        let deadline = Instant::now() + config.policy.max_wait;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // 3. pick the smallest compiled batch that fits, pad, execute
        let real = pending.len();
        let capacity = batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= real)
            .unwrap_or_else(|| *batch_sizes.last().unwrap());
        let (capacity, take) = if capacity >= real {
            (capacity, real)
        } else {
            (capacity, capacity) // more pending than largest batch: split
        };
        let batch: Vec<Request> = pending.drain(..take).collect();
        let plane_len = bucket.vars_len();
        let mut input = Vec::with_capacity(capacity * plane_len);
        for r in &batch {
            input.extend_from_slice(&r.plane);
        }
        // padding: replicate the first plane — it converges in the same
        // sweeps as its twin, adding no extra joint iterations.
        for _ in take..capacity {
            input.extend_from_slice(&batch[0].plane);
        }

        let name = artifact_name(capacity, bucket);
        let t_exec = Instant::now();
        let result = runtime.run_fixpoint_dev(&name, &cons_dev, &input);
        let exec = t_exec.elapsed();
        metrics.on_batch(take, capacity, exec);

        match result {
            Ok(out) => {
                for (i, req) in batch.into_iter().enumerate() {
                    let queue = t_exec.duration_since(req.submitted);
                    let total = req.submitted.elapsed();
                    let resp = Response {
                        plane: out.vars[i * plane_len..(i + 1) * plane_len].to_vec(),
                        status: out.status[i],
                        iters: out.iters,
                        batch_size: take,
                        queue_time: queue,
                        total_time: total,
                    };
                    metrics.on_response(queue, total, out.iters, resp.wiped());
                    let _ = req.resp.send(resp); // receiver may have gone
                }
            }
            Err(e) => {
                // drop the responders: receivers see RecvError and surface
                // a coordinator failure; log once on this side.
                eprintln!("rtac-executor: batch execution failed: {e:#}");
            }
        }
    }
}

/// Artifact naming scheme shared with `python/compile/aot.py`.
fn artifact_name(capacity: usize, bucket: Bucket) -> String {
    if capacity == 1 {
        format!("fix_n{}_d{}", bucket.n, bucket.d)
    } else {
        format!("fixb{}_n{}_d{}", capacity, bucket.n, bucket.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_aot_scheme() {
        let b = Bucket { n: 16, d: 8 };
        assert_eq!(artifact_name(1, b), "fix_n16_d8");
        assert_eq!(artifact_name(4, b), "fixb4_n16_d8");
        assert_eq!(artifact_name(8, b), "fixb8_n16_d8");
    }

    #[test]
    fn default_policy_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait < Duration::from_millis(10));
    }

    fn test_handle() -> (Handle, mpsc::Receiver<Request>) {
        let (tx, rx) = mpsc::channel();
        let handle = Handle {
            tx,
            bucket: Bucket { n: 2, d: 2 },
            metrics: Arc::new(Metrics::new()),
        };
        (handle, rx)
    }

    #[test]
    fn submit_batch_validates_before_enqueuing_anything() {
        let (h, rx) = test_handle();
        let bad = vec![vec![1.0; h.bucket.vars_len()], vec![0.0; 3]];
        assert!(h.submit_batch(bad).is_err());
        assert!(rx.try_recv().is_err(), "no plane may be enqueued on a rejected batch");
        assert_eq!(h.metrics.snapshot().requests, 0);
    }

    #[test]
    fn submit_batch_enqueues_in_order() {
        let (h, rx) = test_handle();
        let len = h.bucket.vars_len();
        let planes = vec![vec![1.0; len], vec![0.5; len], vec![0.0; len]];
        let receivers = h.submit_batch(planes.clone()).unwrap();
        assert_eq!(receivers.len(), 3);
        for want in &planes {
            let got = rx.try_recv().expect("plane enqueued");
            assert_eq!(&got.plane, want);
        }
        assert_eq!(h.metrics.snapshot().requests, 3);
    }
}
