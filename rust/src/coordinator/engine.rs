//! `TensorEngine` — a [`Propagator`] whose enforcement runs on the XLA
//! artifacts *through the coordinator*.  This is what lets the existing
//! MAC solver (search/solver.rs) run unchanged on the tensor path: each
//! AC call encodes the current domains, submits them to the session, and
//! decodes the enforced plane back through the trail.
//!
//! When several search workers share one coordinator session, their AC
//! calls coalesce into batched executions — the end-to-end system the
//! paper's GPU experiments point at (DESIGN.md §3, examples/serve_demo).

use crate::ac::{Counters, Outcome, Propagator};
use crate::coordinator::service::Handle;
use crate::core::{Problem, State, VarId};
use crate::runtime::{decode_vars, encode_vars};

/// Propagator that routes enforcement through a coordinator session.
pub struct TensorEngine {
    handle: Handle,
    /// Set on coordinator failure: the engine is then poisoned and
    /// reports wipeouts to force search termination.
    pub failed: Option<String>,
}

impl TensorEngine {
    pub fn new(handle: Handle) -> TensorEngine {
        TensorEngine { handle, failed: None }
    }
}

impl Propagator for TensorEngine {
    fn name(&self) -> &'static str {
        "tensor-xla"
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId], // dense artifact: the whole plane each time
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        let bucket = self.handle.bucket;
        let plane = match encode_vars(problem, state, bucket) {
            Ok(p) => p,
            Err(e) => {
                self.failed = Some(format!("encode: {e:#}"));
                return Outcome::Wipeout(0);
            }
        };
        let resp = match self.handle.enforce_blocking(plane) {
            Ok(r) => r,
            Err(e) => {
                self.failed = Some(format!("submit: {e:#}"));
                return Outcome::Wipeout(0);
            }
        };
        counters.recurrences += resp.iters.max(0) as u64;
        if resp.wiped() {
            // the artifact reports status only; find a wiped/nearly-wiped
            // variable for the wdeg heuristic by decoding into a scratch
            // copy (the real state must stay untouched on wipeout so the
            // search pops a clean level).
            let mut probe = state.clone();
            let _ = decode_vars(problem, &mut probe, &resp.plane, bucket);
            let victim = (0..problem.n_vars()).find(|&v| probe.wiped(v)).unwrap_or(0);
            return Outcome::Wipeout(victim);
        }
        let trail_before = state.trail_len();
        match decode_vars(problem, state, &resp.plane, bucket) {
            Ok(_changed) => {
                counters.removals += (state.trail_len() - trail_before) as u64;
                Outcome::Consistent
            }
            Err(e) => {
                self.failed = Some(format!("decode: {e:#}"));
                Outcome::Wipeout(0)
            }
        }
    }
}
