//! `TensorEngine` — a [`Propagator`] whose enforcement runs on the XLA
//! artifacts *through the coordinator*.  This is what lets the existing
//! MAC solver (search/solver.rs) run unchanged on the tensor path: each
//! AC call encodes the current domains, submits them to the session, and
//! decodes the enforced plane back through the trail.
//!
//! By default the engine ships **search-plane deltas**: it attaches a
//! [`ClientId`] to the session, uploads its first encoded plane as that
//! client's base, and from then on ships only the rows that changed
//! since the previous node ([`PlaneDelta::diff`] +
//! [`Handle::submit_delta`], which advances the client's base slot to
//! the reconstructed plane).  Consecutive MAC nodes differ in the few
//! rows the last assignment/backtrack/propagation touched, so a K-node
//! run moves one base plane plus per-node row diffs instead of K full
//! planes.  If the client's slot goes stale (evicted under the
//! `base_slots` cap by other writers), the engine falls back to
//! re-uploading the current plane as a fresh base and continues —
//! deltas degrade to full planes, never to wrong answers.
//! [`TensorEngine::full_plane`] keeps the ship-everything baseline
//! (what the upload-volume bench cells compare against).
//!
//! When several search workers share one coordinator session, their AC
//! calls coalesce into batched executions — the end-to-end system the
//! paper's GPU experiments point at (DESIGN.md §3, examples/serve_demo).

use crate::ac::{Counters, Outcome, Propagator};
use crate::coordinator::retry::{Retry, RetryPolicy};
use crate::coordinator::service::{Handle, Response, StaleTracker};
use crate::core::{Problem, State, VarId};
use crate::runtime::{decode_vars, encode_vars, plane_fingerprint, PlaneDelta};

/// The delta-shipping state of one engine (one session client).
struct DeltaState {
    /// The session client + its stale-drop watermark (the shared
    /// stale-vs-fatal classifier — see [`StaleTracker`]).
    tracker: StaleTracker,
    /// The full plane this client last chained onto the session — the
    /// mirror of the executor's base slot.  `None` until the first
    /// upload (or after a reset).
    last: Option<Vec<f32>>,
}

/// Propagator that routes enforcement through a coordinator session.
pub struct TensorEngine {
    handle: Handle,
    /// `Some` = delta shipping (the default); `None` = full planes.
    delta: Option<DeltaState>,
    /// The shared session retry policy (see `coordinator::retry`)
    /// behind the fresh-base fallback: bounded re-upload attempts,
    /// stale drops classified transient, everything else fatal.
    retry: RetryPolicy,
    /// Set on coordinator failure: the engine is then poisoned and
    /// reports wipeouts to force search termination.
    pub failed: Option<String>,
}

impl TensorEngine {
    /// Delta-shipping engine (the default): base once, then per-node
    /// row diffs, with automatic full-plane fallback on slot
    /// invalidation.
    pub fn new(handle: Handle) -> TensorEngine {
        let tracker = StaleTracker::attach(&handle);
        TensorEngine {
            handle,
            delta: Some(DeltaState { tracker, last: None }),
            retry: RetryPolicy::no_backoff(3),
            failed: None,
        }
    }

    /// Full-plane engine: every AC call ships the whole encoded plane.
    /// The upload-volume baseline (`bench-rtac`'s search-delta cell,
    /// `rtac serve --worker-engine tensor-full`).
    pub fn full_plane(handle: Handle) -> TensorEngine {
        TensorEngine {
            handle,
            delta: None,
            retry: RetryPolicy::no_backoff(3),
            failed: None,
        }
    }

    /// Ship `plane` and block for its enforcement response, in whatever
    /// mode this engine runs.
    ///
    /// Delta mode: diff against the previous node's plane and chain
    /// ([`Handle::submit_delta`] advances the client's slot to `plane`).
    /// When there is no previous plane — first call, after `reset`, or
    /// after the executor reported our slot stale (evicted) — upload
    /// `plane` as a fresh base and chase it with an empty delta, which
    /// reconstructs to the base itself and carries the enforcement
    /// request.  A stale drop is detected by the client's `stale_deltas`
    /// metric ticking during the failed call, and retried with a fresh
    /// base a bounded number of times.
    fn enforce_plane(&mut self, plane: Vec<f32>) -> anyhow::Result<Response> {
        let bucket = self.handle.bucket;
        let Some(ds) = &mut self.delta else {
            return self.handle.enforce_blocking(plane);
        };
        let client = ds.tracker.client();
        if let Some(last) = &ds.last {
            let delta = PlaneDelta::diff(last, &plane, bucket)?;
            match self.handle.enforce_delta_blocking(client, delta) {
                Ok(resp) => {
                    ds.last = Some(plane);
                    return Ok(resp);
                }
                // a stale drop means our slot was evicted/invalidated:
                // fall through to a fresh base upload (the full-plane
                // fallback); any other failure is fatal
                Err(e) => {
                    if !ds.tracker.absorb_stale_drop(&self.handle) {
                        return Err(e);
                    }
                }
            }
        }
        // fresh-base fallback: under heavy slot churn (more concurrent
        // writers than base_slots) even a just-uploaded base can be
        // evicted before its first delta resolves.  The shared session
        // RetryPolicy bounds the re-upload attempts; a stale drop is
        // Transient (re-upload and go again), anything else — session
        // dead, moribund, deadline expired — is Fatal.
        let retry = self.retry;
        let handle = &self.handle;
        let delta = &mut self.delta;
        let resp = retry.run(
            "fresh-base re-upload kept dying to eviction — the session's base_slots \
             cap looks too small for the number of concurrent delta writers (raise \
             --base-slots or use the full-plane worker engine)",
            |_| {
                let fp =
                    handle.upload_base(client, plane.clone()).map_err(Retry::Fatal)?;
                debug_assert_eq!(fp, plane_fingerprint(&plane));
                match handle.enforce_delta_blocking(client, PlaneDelta::empty(fp)) {
                    Ok(resp) => Ok(resp),
                    Err(e) => {
                        let ds = delta.as_mut().expect("delta mode");
                        if ds.tracker.absorb_stale_drop(handle) {
                            // evicted again: the next attempt re-uploads
                            Err(Retry::Transient(e))
                        } else {
                            Err(Retry::Fatal(e))
                        }
                    }
                }
            },
        )?;
        if let Some(ds) = delta.as_mut() {
            ds.last = Some(plane);
        }
        Ok(resp)
    }
}

impl Propagator for TensorEngine {
    fn name(&self) -> &'static str {
        "tensor-xla"
    }

    fn reset(&mut self, _problem: &Problem) {
        // the delta chain SURVIVES resets on purpose: a diff is purely
        // content-based (diff(last, next) applied to last is next,
        // whatever search produced either plane), so the next solve's
        // first plane diffs against the previous solve's head and a
        // whole portfolio run ships one base per worker.  Only the
        // poison is cleared; a stale slot is recovered by the fallback
        // in `enforce_plane`, not here.
        self.failed = None;
    }

    fn failure(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    fn enforce(
        &mut self,
        problem: &Problem,
        state: &mut State,
        _touched: &[VarId], // dense artifact: the whole plane each time
        counters: &mut Counters,
    ) -> Outcome {
        if self.failed.is_some() {
            return Outcome::Wipeout(0);
        }
        let bucket = self.handle.bucket;
        let plane = match encode_vars(problem, state, bucket) {
            Ok(p) => p,
            Err(e) => {
                self.failed = Some(format!("encode: {e:#}"));
                return Outcome::Wipeout(0);
            }
        };
        let resp = match self.enforce_plane(plane) {
            Ok(r) => r,
            Err(e) => {
                self.failed = Some(format!("submit: {e:#}"));
                return Outcome::Wipeout(0);
            }
        };
        counters.recurrences += resp.iters.max(0) as u64;
        if resp.wiped() {
            // the artifact reports status only; find a wiped/nearly-wiped
            // variable for the wdeg heuristic by decoding into a scratch
            // copy (the real state must stay untouched on wipeout so the
            // search pops a clean level).
            let mut probe = state.clone();
            let _ = decode_vars(problem, &mut probe, &resp.plane, bucket);
            let victim = (0..problem.n_vars()).find(|&v| probe.wiped(v)).unwrap_or(0);
            return Outcome::Wipeout(victim);
        }
        let trail_before = state.trail_len();
        match decode_vars(problem, state, &resp.plane, bucket) {
            Ok(_changed) => {
                counters.removals += (state.trail_len() - trail_before) as u64;
                Outcome::Consistent
            }
            Err(e) => {
                self.failed = Some(format!("decode: {e:#}"));
                Outcome::Wipeout(0)
            }
        }
    }
}
