//! Coordinator metrics: request/batch counters, latency decomposition
//! (queue wait vs execution), batch-occupancy histogram, padding waste.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::Online;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    responses: u64,
    batches: u64,
    batch_occupancy_sum: u64,
    padded_slots: u64,
    wipeouts: u64,
    queue_us: Online,
    exec_us: Online,
    total_us: Online,
    iters: Online,
}

/// A snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub mean_batch_occupancy: f64,
    pub padded_slots: u64,
    pub wipeouts: u64,
    pub mean_queue_us: f64,
    pub mean_exec_us: f64,
    pub mean_total_us: f64,
    pub max_total_us: f64,
    pub mean_iters: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    /// Record one executed batch: `real` occupied slots of `capacity`.
    pub fn on_batch(&self, real: usize, capacity: usize, exec: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_occupancy_sum += real as u64;
        m.padded_slots += (capacity - real) as u64;
        m.exec_us.push(exec.as_secs_f64() * 1e6);
    }

    /// Record one completed request.
    pub fn on_response(&self, queue: Duration, total: Duration, iters: i32, wiped: bool) {
        let mut m = self.inner.lock().unwrap();
        m.responses += 1;
        m.queue_us.push(queue.as_secs_f64() * 1e6);
        m.total_us.push(total.as_secs_f64() * 1e6);
        m.iters.push(iters as f64);
        if wiped {
            m.wipeouts += 1;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: m.requests,
            responses: m.responses,
            batches: m.batches,
            mean_batch_occupancy: if m.batches == 0 {
                0.0
            } else {
                m.batch_occupancy_sum as f64 / m.batches as f64
            },
            padded_slots: m.padded_slots,
            wipeouts: m.wipeouts,
            mean_queue_us: m.queue_us.mean(),
            mean_exec_us: m.exec_us.mean(),
            mean_total_us: m.total_us.mean(),
            max_total_us: m.total_us.max(),
            mean_iters: m.iters.mean(),
        }
    }
}

impl MetricsSnapshot {
    /// One-line human summary (served by `rtac serve` and the examples).
    pub fn summary(&self) -> String {
        format!(
            "req={} resp={} batches={} occ={:.2} padded={} wipeouts={} \
             queue={:.0}µs exec={:.0}µs total={:.0}µs iters={:.2}",
            self.requests,
            self.responses,
            self.batches,
            self.mean_batch_occupancy,
            self.padded_slots,
            self.wipeouts,
            self.mean_queue_us,
            self.mean_exec_us,
            self.mean_total_us,
            self.mean_iters,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_batch(2, 4, Duration::from_micros(100));
        m.on_response(Duration::from_micros(10), Duration::from_micros(110), 4, false);
        m.on_response(Duration::from_micros(20), Duration::from_micros(120), 5, true);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.padded_slots, 2);
        assert_eq!(s.wipeouts, 1);
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!((s.mean_iters - 4.5).abs() < 1e-9);
        assert!(s.mean_total_us > s.mean_queue_us);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn snapshot_of_empty_metrics() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_batch_occupancy, 0.0);
    }
}
