//! Coordinator metrics: request/batch counters, latency decomposition
//! (queue wait vs execution), batch-occupancy histogram, padding waste,
//! upload volume (f32 values shipped client→executor, the quantity the
//! delta-plane encoding shrinks), and failure accounting (failed fused
//! executions, dropped requests, stale deltas, base-slot evictions) —
//! in aggregate *and* per delta client
//! ([`MetricsSnapshot::clients`]).
//!
//! The conservation invariant, checked by every quiescent-state test at
//! the session level and per client: `requests == responses +
//! dropped_requests`.  Every plane that reached the queue is either
//! answered or explicitly accounted as dropped — nothing vanishes.
//!
//! ```
//! use rtac::coordinator::Metrics;
//! use std::time::Duration;
//!
//! let m = Metrics::new();
//! m.on_submit(None, 128, false); // a full plane: 128 f32 values shipped
//! m.on_batch(1, 4, Duration::from_micros(50));
//! m.on_response(None, Duration::ZERO, Duration::from_micros(60), 3, false);
//! let s = m.snapshot();
//! assert_eq!(s.shipped_f32, 128);
//! assert!(s.conserved(), "requests == responses + dropped");
//! ```

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::service::ClientId;
use crate::util::stats::Online;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    delta_requests: u64,
    responses: u64,
    batches: u64,
    failed_batches: u64,
    dropped_requests: u64,
    stale_deltas: u64,
    timed_out_requests: u64,
    restart_dropped_requests: u64,
    executor_restarts: u64,
    replayed_bases: u64,
    shipped_f32: u64,
    base_uploads: u64,
    base_evictions: u64,
    batch_occupancy_sum: u64, // lint:allow(metrics-ledger): surfaced as mean_batch_occupancy
    padded_slots: u64,
    wipeouts: u64,
    rejected_requests: u64,
    failovers: u64,
    replaced_sessions: u64,
    shards: u64,
    fixcache_hits: u64,
    fixcache_misses: u64,
    fixcache_evictions: u64,
    fixcache_bytes: u64,
    queue_us: Online,
    exec_us: Online,
    total_us: Online,
    iters: Online,
    clients: HashMap<u64, ClientMetrics>,
}

impl Inner {
    fn client(&mut self, client: ClientId) -> &mut ClientMetrics {
        self.clients
            .entry(client.id())
            .or_insert_with(|| ClientMetrics { client: client.id(), ..Default::default() })
    }
}

/// Per-client upload-volume and conservation accounting: one row per
/// [`ClientId`] that ever touched the delta path.  Full-plane
/// submissions are unattributed (aggregate only); everything a delta
/// client ships — bases, delta rows — and every response/drop it
/// receives is recorded here, so `requests == responses +
/// dropped_requests` holds per client too.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClientMetrics {
    /// The [`ClientId::id`] this row belongs to.
    pub client: u64,
    /// Requests this client enqueued (all delta-path; the client id is
    /// only carried by delta submissions).
    pub requests: u64,
    /// The subset of `requests` that shipped in delta form (currently
    /// all of them — kept separate so a future client-attributed full
    /// path keeps the hit-rate denominator honest).
    pub delta_requests: u64,
    pub responses: u64,
    pub dropped_requests: u64,
    /// Deltas dropped because this client's base slot was stale,
    /// evicted, or never uploaded (a subset of `dropped_requests`).
    pub stale_deltas: u64,
    /// Requests dropped past the per-request deadline
    /// (`BatchPolicy::request_timeout`) — a subset of
    /// `dropped_requests`.
    pub timed_out_requests: u64,
    /// Requests dropped by a moribund session (restart budget
    /// exhausted) — a subset of `dropped_requests`.
    pub restart_dropped_requests: u64,
    /// f32 values this client shipped (bases + delta rows).
    pub shipped_f32: u64,
    /// Base planes this client uploaded (first attach + every
    /// invalidation/eviction fallback).
    pub base_uploads: u64,
}

/// Fraction of `deltas` submissions that applied against a live base
/// slot (1.0 with no delta traffic) — the ONE definition of the hit
/// rate, shared by the per-client and session-aggregate views.
fn hit_rate(deltas: u64, stale: u64) -> f64 {
    if deltas == 0 {
        return 1.0;
    }
    (deltas - stale.min(deltas)) as f64 / deltas as f64
}

impl ClientMetrics {
    /// Per-client conservation at quiescence.
    pub fn conserved(&self) -> bool {
        self.requests == self.responses + self.dropped_requests
    }

    /// Fraction of this client's delta submissions that applied against
    /// a live base slot (1.0 = no stale drops).  The per-worker number
    /// `rtac serve` reports.
    pub fn delta_hit_rate(&self) -> f64 {
        hit_rate(self.delta_requests, self.stale_deltas)
    }

    /// One-line per-client summary (the `rtac serve` delta report).
    pub fn summary(&self) -> String {
        format!(
            "client c{}: deltas={} hit={:.0}% bases={} stale={} shipped={}f32 \
             req={} resp={} dropped={}",
            self.client,
            self.delta_requests,
            self.delta_hit_rate() * 100.0,
            self.base_uploads,
            self.stale_deltas,
            self.shipped_f32,
            self.requests,
            self.responses,
            self.dropped_requests,
        )
    }
}

/// A snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    /// The subset of `requests` submitted in delta form.
    pub delta_requests: u64,
    pub responses: u64,
    /// Successfully executed fused batches only — a failed XLA execution
    /// counts in `failed_batches`, not here, so occupancy and exec stats
    /// describe work that actually produced responses.
    pub batches: u64,
    /// Fused executions that returned an error from the runtime.
    pub failed_batches: u64,
    /// Requests whose responders were dropped without a response (their
    /// batch failed, the executor shut down with them in flight, or a
    /// delta referenced a stale base — see `stale_deltas`).
    pub dropped_requests: u64,
    /// Deltas rejected because their base fingerprint missed the
    /// submitting client's base slot (counted in `dropped_requests`
    /// too, so conservation holds).
    pub stale_deltas: u64,
    /// Requests dropped executor-side past the per-request deadline
    /// (`BatchPolicy::request_timeout`) — queued through a hang or a
    /// restart backoff.  Counted in `dropped_requests` too.
    pub timed_out_requests: u64,
    /// Requests dropped by a *moribund* session: the restart budget
    /// (`BatchPolicy::max_restarts`) was exhausted, so every further
    /// request is dropped and counted here (and in `dropped_requests`)
    /// to keep conservation exact through total executor loss.
    pub restart_dropped_requests: u64,
    /// Executor restarts performed by the supervisor (§Supervision &
    /// recovery), each followed by a full session re-hydration.
    pub executor_restarts: u64,
    /// Base slots replayed across restarts (the host-resident,
    /// content-fingerprinted slot map survives the runtime's death;
    /// each retained slot counts once per restart).
    pub replayed_bases: u64,
    /// Total f32 values shipped client→executor: full planes, delta
    /// rows, and base uploads.  The delta-vs-full bench cells compare
    /// this across submission modes.
    pub shipped_f32: u64,
    /// Delta base planes uploaded (each re-upload replaces the
    /// uploading client's slot).
    pub base_uploads: u64,
    /// Base slots evicted under the `base_slots` cap to admit a new
    /// client's upload (the evicted client's next delta drops as
    /// stale).
    pub base_evictions: u64,
    pub mean_batch_occupancy: f64,
    pub padded_slots: u64,
    pub wipeouts: u64,
    /// Requests rejected by fleet admission control before reaching any
    /// shard queue (the projected latency would have blown the
    /// `--latency-budget`, or the client exceeded its fairness share on
    /// the batch path).  Every rejection is counted here AND in
    /// `requests`/`dropped_requests`, so the fleet ledger conserves —
    /// nothing is silently shed.  Zero for single-session ledgers.
    pub rejected_requests: u64,
    /// Shard failovers performed by the fleet tier: a shard died (a
    /// chaos kill, or restart-budget exhaustion turned it moribund) and
    /// its sessions were re-placed onto survivors.
    pub failovers: u64,
    /// Sessions re-placed (and re-hydrated via base replay) onto a
    /// surviving shard across all failovers.
    pub replaced_sessions: u64,
    /// Shard count of the fleet this ledger describes (0 for plain
    /// single-session ledgers; on an aggregate snapshot, the fleet's
    /// `--shards`).
    pub shards: u64,
    /// Fixpoint-cache hits: requests answered straight from the
    /// content-addressed memo layer
    /// ([`crate::coordinator::FixCache`]) without running the
    /// recurrence.  Every hit is a *normal response* — it counts in
    /// `responses`, so conservation is unchanged by caching.  0 when
    /// the cache is disabled (`--fixcache-entries 0`).
    pub fixcache_hits: u64,
    /// Fixpoint-cache lookups that found no usable entry (the request
    /// then ran normally and its result was admitted).
    pub fixcache_misses: u64,
    /// Fixpoint-cache entries evicted: LRU displacement under the
    /// `--fixcache-entries` cap plus poisoned entries ejected by the
    /// admission-fingerprint re-check.
    pub fixcache_evictions: u64,
    /// Bytes admitted into the fixpoint cache, cumulative (a monotonic
    /// volume counter like `shipped_f32`, not a residency gauge — so
    /// per-shard ledgers aggregate by summation).
    pub fixcache_bytes: u64,
    /// Per-shard conservation: for a single-shard snapshot, this shard's
    /// `requests == responses + dropped_requests`; for a fleet aggregate
    /// ([`MetricsSnapshot::aggregate`]), true only when EVERY merged
    /// part conserved individually — strictly stronger than
    /// [`MetricsSnapshot::conserved`] on the summed counters.
    pub shard_conserved: bool,
    pub mean_queue_us: f64,
    pub mean_exec_us: f64,
    pub mean_total_us: f64,
    pub max_total_us: f64,
    pub mean_iters: f64,
    /// Per-client rows, ascending by client id (empty when no client
    /// ever attached to the delta path).
    pub clients: Vec<ClientMetrics>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one request reaching the executor queue, shipping `f32s`
    /// values (a full plane's `vars_len`, or just the replaced rows for
    /// a delta).  `client` attributes the request to a delta client's
    /// per-client row (`None` for the unattributed full-plane paths);
    /// `delta` marks delta-form submissions for hit-rate accounting.
    pub fn on_submit(&self, client: Option<ClientId>, f32s: usize, delta: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.shipped_f32 += f32s as u64;
        if delta {
            m.delta_requests += 1;
        }
        if let Some(client) = client {
            let c = m.client(client);
            c.requests += 1;
            c.shipped_f32 += f32s as u64;
            if delta {
                c.delta_requests += 1;
            }
        }
    }

    /// Record one delta-base upload of `f32s` values by `client`.  Not
    /// a request — the base produces no response of its own; it only
    /// feeds later delta reconstructions.
    pub fn on_base_upload(&self, client: ClientId, f32s: usize) {
        let mut m = self.inner.lock().unwrap();
        m.base_uploads += 1;
        m.shipped_f32 += f32s as u64;
        let c = m.client(client);
        c.base_uploads += 1;
        c.shipped_f32 += f32s as u64;
    }

    /// Record one base slot evicted under the cap (executor-side).
    pub fn on_base_evicted(&self) {
        self.inner.lock().unwrap().base_evictions += 1;
    }

    /// Record one delta from `client` rejected for referencing a
    /// stale/evicted/unknown base slot: its responder is dropped, so it
    /// also counts as a dropped request — per client and in aggregate
    /// (conservation).
    pub fn on_stale_delta(&self, client: ClientId) {
        let mut m = self.inner.lock().unwrap();
        m.stale_deltas += 1;
        m.dropped_requests += 1;
        let c = m.client(client);
        c.stale_deltas += 1;
        c.dropped_requests += 1;
    }

    /// Record one request dropped past the per-request deadline
    /// (executor-side expiry: the client's `recv_timeout` fired — or
    /// will fire — against the same deadline).  A counted drop cause,
    /// per client and in aggregate, so conservation holds.
    pub fn on_request_timeout(&self, client: Option<ClientId>) {
        let mut m = self.inner.lock().unwrap();
        m.timed_out_requests += 1;
        m.dropped_requests += 1;
        if let Some(client) = client {
            let c = m.client(client);
            c.timed_out_requests += 1;
            c.dropped_requests += 1;
        }
    }

    /// Record one request dropped by a moribund session (restart budget
    /// exhausted) — the last counted drop cause, so conservation holds
    /// even through total executor loss.
    pub fn on_restart_dropped(&self, client: Option<ClientId>) {
        let mut m = self.inner.lock().unwrap();
        m.restart_dropped_requests += 1;
        m.dropped_requests += 1;
        if let Some(client) = client {
            let c = m.client(client);
            c.restart_dropped_requests += 1;
            c.dropped_requests += 1;
        }
    }

    /// Record one supervised executor restart (after re-init succeeded).
    pub fn on_executor_restart(&self) {
        self.inner.lock().unwrap().executor_restarts += 1;
    }

    /// Record one request rejected by fleet admission control (latency
    /// budget or fairness share).  The request never reached a shard
    /// queue, so this ledger is the only place it can be accounted: it
    /// counts as a request AND a drop here, keeping `requests ==
    /// responses + dropped_requests` exact for the fleet ledger.
    pub fn on_rejected(&self) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.dropped_requests += 1;
        m.rejected_requests += 1;
    }

    /// Record one shard failover (the fleet re-placed a dead shard's
    /// sessions onto survivors).
    pub fn on_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    /// Record one session re-placed onto a surviving shard during a
    /// failover (its bases replay through [`Metrics::on_base_replayed`]).
    pub fn on_session_replaced(&self) {
        self.inner.lock().unwrap().replaced_sessions += 1;
    }

    /// Record the shard count of the fleet this ledger describes.
    pub fn set_shards(&self, shards: u64) {
        self.inner.lock().unwrap().shards = shards;
    }

    /// Record one fixpoint-cache hit: the request was answered from
    /// the memo layer without running the recurrence.  The response
    /// itself is recorded separately via [`Metrics::on_response`] —
    /// a hit is a normal response, so conservation is untouched.
    pub fn on_fixcache_hit(&self) {
        self.inner.lock().unwrap().fixcache_hits += 1;
    }

    /// Record one fixpoint-cache miss (the request ran normally).
    pub fn on_fixcache_miss(&self) {
        self.inner.lock().unwrap().fixcache_misses += 1;
    }

    /// Record one fixpoint admitted into the cache: `bytes` of
    /// cumulative admission volume, `evicted` when the insert
    /// displaced the LRU entry under the capacity bound (also used for
    /// poison ejections, with `bytes == 0`).
    pub fn on_fixcache_insert(&self, bytes: u64, evicted: bool) {
        let mut m = self.inner.lock().unwrap();
        m.fixcache_bytes += bytes;
        if evicted {
            m.fixcache_evictions += 1;
        }
    }

    /// Record one base slot replayed through a restart's re-hydration.
    pub fn on_base_replayed(&self) {
        self.inner.lock().unwrap().replayed_bases += 1;
    }

    /// Record one *successfully executed* batch: `real` occupied slots of
    /// `capacity`.  Must be called only after the runtime returned `Ok` —
    /// failed executions go through [`Metrics::on_batch_failed`] so they
    /// cannot skew occupancy or exec-latency stats.
    pub fn on_batch(&self, real: usize, capacity: usize, exec: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_occupancy_sum += real as u64;
        m.padded_slots += (capacity - real) as u64;
        m.exec_us.push(exec.as_secs_f64() * 1e6);
    }

    /// Record one failed fused execution: every request it carried is
    /// dropped (the responders never fire), attributed per client where
    /// the request was client-submitted.
    pub fn on_batch_failed(&self, dropped: &[Option<ClientId>]) {
        let mut m = self.inner.lock().unwrap();
        m.failed_batches += 1;
        m.dropped_requests += dropped.len() as u64;
        for client in dropped.iter().flatten() {
            m.client(*client).dropped_requests += 1;
        }
    }

    /// Record one completed request (`client` for delta-path requests).
    pub fn on_response(
        &self,
        client: Option<ClientId>,
        queue: Duration,
        total: Duration,
        iters: i32,
        wiped: bool,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.responses += 1;
        m.queue_us.push(queue.as_secs_f64() * 1e6);
        m.total_us.push(total.as_secs_f64() * 1e6);
        m.iters.push(iters as f64);
        if wiped {
            m.wipeouts += 1;
        }
        if let Some(client) = client {
            m.client(client).responses += 1;
        }
    }

    /// `client`'s cumulative stale-delta count — a targeted read for
    /// the serving hot path (the delta clients poll this around every
    /// submission to distinguish "slot evicted: re-upload" from
    /// "session dead: fail"), so it must not pay
    /// [`Metrics::snapshot`]'s full clone of every counter.
    pub fn client_stale_deltas(&self, client: ClientId) -> u64 {
        let m = self.inner.lock().unwrap();
        m.clients.get(&client.id()).map_or(0, |c| c.stale_deltas)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut clients: Vec<ClientMetrics> = m.clients.values().cloned().collect();
        clients.sort_by_key(|c| c.client);
        MetricsSnapshot {
            requests: m.requests,
            delta_requests: m.delta_requests,
            responses: m.responses,
            batches: m.batches,
            failed_batches: m.failed_batches,
            dropped_requests: m.dropped_requests,
            stale_deltas: m.stale_deltas,
            timed_out_requests: m.timed_out_requests,
            restart_dropped_requests: m.restart_dropped_requests,
            executor_restarts: m.executor_restarts,
            replayed_bases: m.replayed_bases,
            shipped_f32: m.shipped_f32,
            base_uploads: m.base_uploads,
            base_evictions: m.base_evictions,
            mean_batch_occupancy: if m.batches == 0 {
                0.0
            } else {
                m.batch_occupancy_sum as f64 / m.batches as f64
            },
            padded_slots: m.padded_slots,
            wipeouts: m.wipeouts,
            rejected_requests: m.rejected_requests,
            failovers: m.failovers,
            replaced_sessions: m.replaced_sessions,
            shards: m.shards,
            fixcache_hits: m.fixcache_hits,
            fixcache_misses: m.fixcache_misses,
            fixcache_evictions: m.fixcache_evictions,
            fixcache_bytes: m.fixcache_bytes,
            shard_conserved: m.requests == m.responses + m.dropped_requests,
            mean_queue_us: m.queue_us.mean(),
            mean_exec_us: m.exec_us.mean(),
            mean_total_us: m.total_us.mean(),
            max_total_us: m.total_us.max(),
            mean_iters: m.iters.mean(),
            clients,
        }
    }
}

impl MetricsSnapshot {
    /// One-line human summary (served by `rtac serve` and the examples).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "req={} (delta={}) resp={} batches={} failed={} dropped={} stale_deltas={} \
             timed_out={} restart_dropped={} rejected={} restarts={} replayed_bases={} \
             shipped={}f32 bases={} evicted={} occ={:.2} padded={} \
             wipeouts={} queue={:.0}µs exec={:.0}µs total={:.0}µs iters={:.2}",
            self.requests,
            self.delta_requests,
            self.responses,
            self.batches,
            self.failed_batches,
            self.dropped_requests,
            self.stale_deltas,
            self.timed_out_requests,
            self.restart_dropped_requests,
            self.rejected_requests,
            self.executor_restarts,
            self.replayed_bases,
            self.shipped_f32,
            self.base_uploads,
            self.base_evictions,
            self.mean_batch_occupancy,
            self.padded_slots,
            self.wipeouts,
            self.mean_queue_us,
            self.mean_exec_us,
            self.mean_total_us,
            self.mean_iters,
        );
        if self.shards > 0 {
            s.push_str(&format!(
                " shards={} shard_conserved={} failovers={} replaced_sessions={}",
                self.shards, self.shard_conserved, self.failovers, self.replaced_sessions,
            ));
        }
        if self.fixcache_hits + self.fixcache_misses + self.fixcache_evictions > 0 {
            s.push_str(&format!(
                " fixcache_hits={} fixcache_misses={} fixcache_evictions={} \
                 fixcache_bytes={}",
                self.fixcache_hits,
                self.fixcache_misses,
                self.fixcache_evictions,
                self.fixcache_bytes,
            ));
        }
        s
    }

    /// Conservation invariant at quiescence: every request that reached
    /// the queue was either answered or explicitly dropped.  (Transiently
    /// false while requests are in flight.)
    pub fn conserved(&self) -> bool {
        self.requests == self.responses + self.dropped_requests
    }

    /// Per-client conservation at quiescence: [`conserved`] for every
    /// client row (vacuously true with no delta clients).
    ///
    /// [`conserved`]: ClientMetrics::conserved
    pub fn clients_conserved(&self) -> bool {
        self.clients.iter().all(|c| c.conserved())
    }

    /// Session-wide delta hit rate — the aggregate twin of
    /// [`ClientMetrics::delta_hit_rate`] (same definition, session
    /// counters).
    pub fn delta_hit_rate(&self) -> f64 {
        hit_rate(self.delta_requests, self.stale_deltas)
    }

    /// The per-client row for `client` ([`ClientId::id`]), if that
    /// client ever touched the delta path.
    pub fn client(&self, client: u64) -> Option<&ClientMetrics> {
        self.clients.iter().find(|c| c.client == client)
    }

    /// Merge per-shard (or per-incarnation) snapshots into one fleet
    /// ledger: counters sum, latency/iteration means are weighted by
    /// the count they were computed over (`responses` for the
    /// request-path means, `batches` for occupancy and exec time),
    /// `max_total_us` is the max over parts, and `shard_conserved`
    /// holds only when every merged part conserved individually.
    ///
    /// Client rows merge by [`ClientMetrics::client`].  Ids are minted
    /// per session, so rows from *different* sessions can collide on an
    /// id; the merged rows are a best-effort roll-up (the fleet load
    /// harness keeps its authoritative per-client ledger client-side).
    pub fn aggregate(parts: &[MetricsSnapshot]) -> MetricsSnapshot {
        fn weighted(
            parts: &[MetricsSnapshot],
            value: impl Fn(&MetricsSnapshot) -> f64,
            weight: impl Fn(&MetricsSnapshot) -> u64,
        ) -> f64 {
            let total: u64 = parts.iter().map(&weight).sum();
            if total == 0 {
                return 0.0;
            }
            parts.iter().map(|p| value(p) * weight(p) as f64).sum::<f64>() / total as f64
        }
        let mut out = Metrics::new().snapshot();
        for p in parts {
            out.requests += p.requests;
            out.delta_requests += p.delta_requests;
            out.responses += p.responses;
            out.batches += p.batches;
            out.failed_batches += p.failed_batches;
            out.dropped_requests += p.dropped_requests;
            out.stale_deltas += p.stale_deltas;
            out.timed_out_requests += p.timed_out_requests;
            out.restart_dropped_requests += p.restart_dropped_requests;
            out.executor_restarts += p.executor_restarts;
            out.replayed_bases += p.replayed_bases;
            out.shipped_f32 += p.shipped_f32;
            out.base_uploads += p.base_uploads;
            out.base_evictions += p.base_evictions;
            out.padded_slots += p.padded_slots;
            out.wipeouts += p.wipeouts;
            out.rejected_requests += p.rejected_requests;
            out.failovers += p.failovers;
            out.replaced_sessions += p.replaced_sessions;
            out.shards += p.shards;
            out.fixcache_hits += p.fixcache_hits;
            out.fixcache_misses += p.fixcache_misses;
            out.fixcache_evictions += p.fixcache_evictions;
            out.fixcache_bytes += p.fixcache_bytes;
        }
        out.shard_conserved = parts.iter().all(|p| p.shard_conserved);
        out.mean_batch_occupancy = weighted(parts, |p| p.mean_batch_occupancy, |p| p.batches);
        out.mean_exec_us = weighted(parts, |p| p.mean_exec_us, |p| p.batches);
        out.mean_queue_us = weighted(parts, |p| p.mean_queue_us, |p| p.responses);
        out.mean_total_us = weighted(parts, |p| p.mean_total_us, |p| p.responses);
        out.mean_iters = weighted(parts, |p| p.mean_iters, |p| p.responses);
        out.max_total_us = parts.iter().map(|p| p.max_total_us).fold(0.0, f64::max);
        let mut by_id: HashMap<u64, ClientMetrics> = HashMap::new();
        for p in parts {
            for c in &p.clients {
                let row = by_id
                    .entry(c.client)
                    .or_insert_with(|| ClientMetrics { client: c.client, ..Default::default() });
                row.requests += c.requests;
                row.delta_requests += c.delta_requests;
                row.responses += c.responses;
                row.dropped_requests += c.dropped_requests;
                row.stale_deltas += c.stale_deltas;
                row.timed_out_requests += c.timed_out_requests;
                row.restart_dropped_requests += c.restart_dropped_requests;
                row.shipped_f32 += c.shipped_f32;
                row.base_uploads += c.base_uploads;
            }
        }
        out.clients = by_id.into_values().collect();
        out.clients.sort_by_key(|c| c.client);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fabricate client ids (sessions mint them via `Handle::attach`).
    fn two_clients() -> (ClientId, ClientId) {
        (ClientId::test(0), ClientId::test(1))
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit(None, 16, false);
        m.on_submit(None, 16, false);
        m.on_batch(2, 4, Duration::from_micros(100));
        m.on_response(None, Duration::from_micros(10), Duration::from_micros(110), 4, false);
        m.on_response(None, Duration::from_micros(20), Duration::from_micros(120), 5, true);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.delta_requests, 0);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.failed_batches, 0);
        assert_eq!(s.dropped_requests, 0);
        assert_eq!(s.padded_slots, 2);
        assert_eq!(s.wipeouts, 1);
        assert_eq!(s.shipped_f32, 32);
        assert_eq!(s.base_uploads, 0);
        assert_eq!(s.stale_deltas, 0);
        assert!(s.clients.is_empty(), "unattributed traffic opens no client rows");
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!((s.mean_iters - 4.5).abs() < 1e-9);
        assert!(s.mean_total_us > s.mean_queue_us);
        assert!(s.conserved());
        assert!(s.clients_conserved());
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn snapshot_of_empty_metrics() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_batch_occupancy, 0.0);
        assert!(s.conserved());
        assert!(s.clients_conserved());
    }

    #[test]
    fn delta_accounting_preserves_conservation_and_tracks_volume() {
        let m = Metrics::new();
        let (a, _) = two_clients();
        // a delta round from one client: a base upload + 3 delta rows
        m.on_base_upload(a, 128);
        for _ in 0..3 {
            m.on_submit(Some(a), 8, true);
        }
        // two served, one stale-rejected
        m.on_batch(2, 4, Duration::from_micros(50));
        m.on_response(Some(a), Duration::ZERO, Duration::from_micros(60), 2, false);
        m.on_response(Some(a), Duration::ZERO, Duration::from_micros(60), 2, false);
        m.on_stale_delta(a);
        let s = m.snapshot();
        assert_eq!(s.requests, 3, "a base upload is not a request");
        assert_eq!(s.delta_requests, 3);
        assert_eq!(s.base_uploads, 1);
        assert_eq!(s.shipped_f32, 128 + 3 * 8);
        assert_eq!(s.stale_deltas, 1);
        assert_eq!(s.dropped_requests, 1);
        assert!(s.conserved(), "stale deltas must count as dropped: {s:?}");
        // ...and the same numbers per client
        let c = s.client(a.id()).expect("client row opened");
        assert_eq!(c.requests, 3);
        assert_eq!(c.delta_requests, 3);
        assert_eq!(c.responses, 2);
        assert_eq!(c.dropped_requests, 1);
        assert_eq!(c.stale_deltas, 1);
        assert_eq!(c.base_uploads, 1);
        assert_eq!(c.shipped_f32, 128 + 3 * 8);
        assert!(c.conserved());
        assert!((c.delta_hit_rate() - 2.0 / 3.0).abs() < 1e-9);
        assert!(s.clients_conserved());
        assert!(s.summary().contains("stale_deltas=1"));
        assert!(s.summary().contains("bases=1"));
        assert!(c.summary().contains("bases=1"));
    }

    #[test]
    fn per_client_rows_stay_isolated() {
        let m = Metrics::new();
        let (a, b) = two_clients();
        m.on_base_upload(a, 64);
        m.on_base_upload(b, 64);
        m.on_submit(Some(a), 4, true);
        m.on_submit(Some(b), 4, true);
        m.on_batch(2, 2, Duration::from_micros(10));
        m.on_response(Some(a), Duration::ZERO, Duration::ZERO, 1, false);
        // b's request is dropped by a failed batch — a must not see it
        m.on_batch_failed(&[Some(b)]);
        let s = m.snapshot();
        assert_eq!(s.clients.len(), 2);
        let ca = s.client(a.id()).unwrap();
        let cb = s.client(b.id()).unwrap();
        assert_eq!(ca.responses, 1);
        assert_eq!(ca.dropped_requests, 0);
        assert_eq!(cb.responses, 0);
        assert_eq!(cb.dropped_requests, 1);
        assert!(ca.conserved() && cb.conserved(), "{s:?}");
        assert_eq!(ca.delta_hit_rate(), 1.0);
        assert!(s.conserved());
    }

    #[test]
    fn timeout_and_moribund_drops_preserve_conservation() {
        let m = Metrics::new();
        let (a, _) = two_clients();
        // three client requests: one served, one expired past the
        // deadline, one dropped by the moribund session
        for _ in 0..3 {
            m.on_submit(Some(a), 8, true);
        }
        m.on_batch(1, 1, Duration::from_micros(10));
        m.on_response(Some(a), Duration::ZERO, Duration::from_micros(20), 2, false);
        m.on_request_timeout(Some(a));
        m.on_restart_dropped(Some(a));
        // plus one unattributed full-plane request expiring
        m.on_submit(None, 64, false);
        m.on_request_timeout(None);
        let s = m.snapshot();
        assert_eq!(s.timed_out_requests, 2);
        assert_eq!(s.restart_dropped_requests, 1);
        assert_eq!(s.dropped_requests, 3, "both causes count into dropped");
        assert!(s.conserved(), "{s:?}");
        assert!(s.clients_conserved(), "{s:?}");
        let c = s.client(a.id()).unwrap();
        assert_eq!(c.timed_out_requests, 1);
        assert_eq!(c.restart_dropped_requests, 1);
        assert_eq!(c.dropped_requests, 2);
        assert!(s.summary().contains("timed_out=2"));
        assert!(s.summary().contains("restart_dropped=1"));
    }

    #[test]
    fn restart_and_replay_counters_accumulate() {
        let m = Metrics::new();
        m.on_executor_restart();
        m.on_base_replayed();
        m.on_base_replayed();
        let s = m.snapshot();
        assert_eq!(s.executor_restarts, 1);
        assert_eq!(s.replayed_bases, 2);
        assert!(s.summary().contains("restarts=1"));
        assert!(s.summary().contains("replayed_bases=2"));
        assert!(s.conserved(), "restarts/replays are not requests");
    }

    #[test]
    fn eviction_counter_accumulates() {
        let m = Metrics::new();
        m.on_base_evicted();
        m.on_base_evicted();
        let s = m.snapshot();
        assert_eq!(s.base_evictions, 2);
        assert!(s.summary().contains("evicted=2"));
    }

    #[test]
    fn rejections_are_counted_drops_and_conserve() {
        let m = Metrics::new();
        m.set_shards(3);
        m.on_submit(None, 8, false);
        m.on_batch(1, 1, Duration::from_micros(10));
        m.on_response(None, Duration::ZERO, Duration::from_micros(20), 2, false);
        m.on_rejected();
        m.on_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 3, "a rejection is a counted request");
        assert_eq!(s.rejected_requests, 2);
        assert_eq!(s.dropped_requests, 2, "every rejection is a counted drop");
        assert!(s.conserved(), "rejected-and-counted, never silently shed: {s:?}");
        assert!(s.shard_conserved);
        assert_eq!(s.shards, 3);
        assert!(s.summary().contains("rejected=2"));
        assert!(s.summary().contains("shards=3"));
        assert!(s.summary().contains("shard_conserved=true"));
    }

    #[test]
    fn failover_counters_accumulate_without_breaking_conservation() {
        let m = Metrics::new();
        m.on_failover();
        m.on_session_replaced();
        m.on_session_replaced();
        let s = m.snapshot();
        assert_eq!(s.failovers, 1);
        assert_eq!(s.replaced_sessions, 2);
        assert!(s.conserved(), "failovers move sessions, not requests");
        assert_eq!(s.shards, 0, "single-session ledgers carry no shard count");
        assert!(
            !s.summary().contains("failovers="),
            "fleet columns only print for fleet ledgers"
        );
    }

    #[test]
    fn aggregate_sums_counters_and_weights_means() {
        let (a, b) = two_clients();
        let shard1 = {
            let m = Metrics::new();
            m.on_submit(Some(a), 8, true);
            m.on_submit(Some(a), 8, true);
            m.on_batch(2, 4, Duration::from_micros(100));
            m.on_response(Some(a), Duration::ZERO, Duration::from_micros(30), 2, false);
            m.on_response(Some(a), Duration::ZERO, Duration::from_micros(30), 2, false);
            m.snapshot()
        };
        let shard2 = {
            let m = Metrics::new();
            m.on_submit(Some(b), 16, true);
            m.on_batch(1, 1, Duration::from_micros(40));
            m.on_response(Some(b), Duration::ZERO, Duration::from_micros(90), 8, true);
            m.snapshot()
        };
        let fleet = {
            let m = Metrics::new();
            m.set_shards(2);
            m.on_rejected();
            m.on_failover();
            m.on_session_replaced();
            m.snapshot()
        };
        let agg = MetricsSnapshot::aggregate(&[shard1.clone(), shard2.clone(), fleet]);
        assert_eq!(agg.requests, 4, "2 + 1 served + 1 rejected");
        assert_eq!(agg.responses, 3);
        assert_eq!(agg.dropped_requests, 1);
        assert_eq!(agg.rejected_requests, 1);
        assert_eq!(agg.failovers, 1);
        assert_eq!(agg.replaced_sessions, 1);
        assert_eq!(agg.shards, 2);
        assert_eq!(agg.batches, 3);
        assert_eq!(agg.shipped_f32, 32);
        assert!(agg.conserved() && agg.shard_conserved, "{agg:?}");
        // occupancy weighted by batches: (2.0*1 + 1.0*1) / 2
        assert!((agg.mean_batch_occupancy - 1.5).abs() < 1e-9, "{agg:?}");
        // request-path means weighted by responses: (30*2 + 90*1) / 3
        assert!((agg.mean_total_us - 50.0).abs() < 1e-6, "{agg:?}");
        assert!((agg.mean_iters - 4.0).abs() < 1e-9, "{agg:?}");
        assert!((agg.max_total_us - 90.0).abs() < 1e-6, "{agg:?}");
        // client rows survive the merge
        assert_eq!(agg.clients.len(), 2);
        assert!(agg.clients_conserved());
        assert_eq!(agg.client(a.id()).unwrap().requests, 2);
        assert_eq!(agg.client(b.id()).unwrap().shipped_f32, 16);
        // a part that does NOT conserve poisons shard_conserved even if
        // the summed counters happen to balance
        let unbalanced = {
            let m = Metrics::new();
            m.on_submit(None, 4, false); // in flight: requests=1, responses=0
            m.snapshot()
        };
        let agg2 = MetricsSnapshot::aggregate(&[shard1, unbalanced]);
        assert!(!agg2.shard_conserved);
    }

    #[test]
    fn fixcache_counters_accumulate_aggregate_and_stay_conserved() {
        let m = Metrics::new();
        // two requests: one served from the cache (hit = normal
        // response), one that missed, ran, and was admitted
        m.on_submit(None, 8, false);
        m.on_fixcache_hit();
        m.on_response(None, Duration::ZERO, Duration::from_micros(5), 3, false);
        m.on_submit(None, 8, false);
        m.on_fixcache_miss();
        m.on_batch(1, 1, Duration::from_micros(50));
        m.on_response(None, Duration::ZERO, Duration::from_micros(60), 3, false);
        m.on_fixcache_insert(256, true);
        let s = m.snapshot();
        assert_eq!(s.fixcache_hits, 1);
        assert_eq!(s.fixcache_misses, 1);
        assert_eq!(s.fixcache_evictions, 1);
        assert_eq!(s.fixcache_bytes, 256);
        assert_eq!(s.batches, 1, "the hit skipped its execution entirely");
        assert!(s.conserved(), "a cache hit is a normal response: {s:?}");
        assert!(s.summary().contains("fixcache_hits=1"));
        assert!(s.summary().contains("fixcache_misses=1"));
        assert!(s.summary().contains("fixcache_evictions=1"));
        assert!(s.summary().contains("fixcache_bytes=256"));
        // cache-off ledgers keep the historical summary shape
        assert!(
            !Metrics::new().snapshot().summary().contains("fixcache_"),
            "fixcache columns only print once the cache saw traffic"
        );
        // and the counters sum across shard ledgers
        let agg = MetricsSnapshot::aggregate(&[s.clone(), s]);
        assert_eq!(agg.fixcache_hits, 2);
        assert_eq!(agg.fixcache_misses, 2);
        assert_eq!(agg.fixcache_evictions, 2);
        assert_eq!(agg.fixcache_bytes, 512);
    }

    #[test]
    fn failed_batches_do_not_skew_success_stats() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.on_submit(None, 4, false);
        }
        // one successful batch of 2, one failed batch dropping 1 request
        m.on_batch(2, 4, Duration::from_micros(100));
        m.on_response(None, Duration::from_micros(10), Duration::from_micros(110), 3, false);
        m.on_response(None, Duration::from_micros(12), Duration::from_micros(112), 3, false);
        m.on_batch_failed(&[None]);
        let s = m.snapshot();
        assert_eq!(s.batches, 1, "failed executions must not count as batches");
        assert_eq!(s.failed_batches, 1);
        assert_eq!(s.dropped_requests, 1);
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!(s.conserved(), "requests == responses + dropped at quiescence");
        assert!(s.summary().contains("failed=1"));
        assert!(s.summary().contains("dropped=1"));
    }
}
