//! Coordinator metrics: request/batch counters, latency decomposition
//! (queue wait vs execution), batch-occupancy histogram, padding waste,
//! upload volume (f32 values shipped client→executor, the quantity the
//! delta-probe encoding shrinks), and failure accounting (failed fused
//! executions, dropped requests, stale delta probes).
//!
//! The session-level conservation invariant, checked by every
//! quiescent-state test: `requests == responses + dropped_requests`.
//! Every plane that reached the queue is either answered or explicitly
//! accounted as dropped — nothing vanishes.
//!
//! ```
//! use rtac::coordinator::Metrics;
//!
//! let m = Metrics::new();
//! m.on_submit(128);     // a full plane: 128 f32 values shipped
//! m.on_stale_delta();   // a rejected delta probe counts as dropped
//! let s = m.snapshot();
//! assert_eq!(s.shipped_f32, 128);
//! assert!(s.conserved(), "requests == responses + dropped");
//! ```

use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::Online;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    responses: u64,
    batches: u64,
    failed_batches: u64,
    dropped_requests: u64,
    stale_deltas: u64,
    shipped_f32: u64,
    base_uploads: u64,
    batch_occupancy_sum: u64,
    padded_slots: u64,
    wipeouts: u64,
    queue_us: Online,
    exec_us: Online,
    total_us: Online,
    iters: Online,
}

/// A snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    /// Successfully executed fused batches only — a failed XLA execution
    /// counts in `failed_batches`, not here, so occupancy and exec stats
    /// describe work that actually produced responses.
    pub batches: u64,
    /// Fused executions that returned an error from the runtime.
    pub failed_batches: u64,
    /// Requests whose responders were dropped without a response (their
    /// batch failed, the executor shut down with them in flight, or a
    /// delta probe referenced a stale base — see `stale_deltas`).
    pub dropped_requests: u64,
    /// Delta probes rejected because their base fingerprint missed the
    /// executor's cached base plane (counted in `dropped_requests` too,
    /// so conservation holds).
    pub stale_deltas: u64,
    /// Total f32 values shipped client→executor: full planes, delta
    /// rows, and base uploads.  The delta-vs-full bench cell compares
    /// this across submission modes.
    pub shipped_f32: u64,
    /// Delta base planes uploaded (each re-upload invalidates the
    /// previous cached base).
    pub base_uploads: u64,
    pub mean_batch_occupancy: f64,
    pub padded_slots: u64,
    pub wipeouts: u64,
    pub mean_queue_us: f64,
    pub mean_exec_us: f64,
    pub mean_total_us: f64,
    pub max_total_us: f64,
    pub mean_iters: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one request reaching the executor queue, shipping `f32s`
    /// values (a full plane's `vars_len`, or just the row length `d`
    /// for a delta probe).
    pub fn on_submit(&self, f32s: usize) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.shipped_f32 += f32s as u64;
    }

    /// Record one delta-base upload of `f32s` values.  Not a request —
    /// the base produces no response of its own; it only feeds later
    /// delta reconstructions.
    pub fn on_base_upload(&self, f32s: usize) {
        let mut m = self.inner.lock().unwrap();
        m.base_uploads += 1;
        m.shipped_f32 += f32s as u64;
    }

    /// Record one delta probe rejected for referencing a stale/unknown
    /// base plane: its responder is dropped, so it also counts as a
    /// dropped request (conservation).
    pub fn on_stale_delta(&self) {
        let mut m = self.inner.lock().unwrap();
        m.stale_deltas += 1;
        m.dropped_requests += 1;
    }

    /// Record one *successfully executed* batch: `real` occupied slots of
    /// `capacity`.  Must be called only after the runtime returned `Ok` —
    /// failed executions go through [`Metrics::on_batch_failed`] so they
    /// cannot skew occupancy or exec-latency stats.
    pub fn on_batch(&self, real: usize, capacity: usize, exec: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_occupancy_sum += real as u64;
        m.padded_slots += (capacity - real) as u64;
        m.exec_us.push(exec.as_secs_f64() * 1e6);
    }

    /// Record one failed fused execution: its `real` requests are dropped
    /// (their responders never fire).
    pub fn on_batch_failed(&self, real: usize) {
        let mut m = self.inner.lock().unwrap();
        m.failed_batches += 1;
        m.dropped_requests += real as u64;
    }

    /// Record one completed request.
    pub fn on_response(&self, queue: Duration, total: Duration, iters: i32, wiped: bool) {
        let mut m = self.inner.lock().unwrap();
        m.responses += 1;
        m.queue_us.push(queue.as_secs_f64() * 1e6);
        m.total_us.push(total.as_secs_f64() * 1e6);
        m.iters.push(iters as f64);
        if wiped {
            m.wipeouts += 1;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: m.requests,
            responses: m.responses,
            batches: m.batches,
            failed_batches: m.failed_batches,
            dropped_requests: m.dropped_requests,
            stale_deltas: m.stale_deltas,
            shipped_f32: m.shipped_f32,
            base_uploads: m.base_uploads,
            mean_batch_occupancy: if m.batches == 0 {
                0.0
            } else {
                m.batch_occupancy_sum as f64 / m.batches as f64
            },
            padded_slots: m.padded_slots,
            wipeouts: m.wipeouts,
            mean_queue_us: m.queue_us.mean(),
            mean_exec_us: m.exec_us.mean(),
            mean_total_us: m.total_us.mean(),
            max_total_us: m.total_us.max(),
            mean_iters: m.iters.mean(),
        }
    }
}

impl MetricsSnapshot {
    /// One-line human summary (served by `rtac serve` and the examples).
    pub fn summary(&self) -> String {
        format!(
            "req={} resp={} batches={} failed={} dropped={} stale_deltas={} \
             shipped={}f32 bases={} occ={:.2} padded={} \
             wipeouts={} queue={:.0}µs exec={:.0}µs total={:.0}µs iters={:.2}",
            self.requests,
            self.responses,
            self.batches,
            self.failed_batches,
            self.dropped_requests,
            self.stale_deltas,
            self.shipped_f32,
            self.base_uploads,
            self.mean_batch_occupancy,
            self.padded_slots,
            self.wipeouts,
            self.mean_queue_us,
            self.mean_exec_us,
            self.mean_total_us,
            self.mean_iters,
        )
    }

    /// Conservation invariant at quiescence: every request that reached
    /// the queue was either answered or explicitly dropped.  (Transiently
    /// false while requests are in flight.)
    pub fn conserved(&self) -> bool {
        self.requests == self.responses + self.dropped_requests
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit(16);
        m.on_submit(16);
        m.on_batch(2, 4, Duration::from_micros(100));
        m.on_response(Duration::from_micros(10), Duration::from_micros(110), 4, false);
        m.on_response(Duration::from_micros(20), Duration::from_micros(120), 5, true);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.failed_batches, 0);
        assert_eq!(s.dropped_requests, 0);
        assert_eq!(s.padded_slots, 2);
        assert_eq!(s.wipeouts, 1);
        assert_eq!(s.shipped_f32, 32);
        assert_eq!(s.base_uploads, 0);
        assert_eq!(s.stale_deltas, 0);
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!((s.mean_iters - 4.5).abs() < 1e-9);
        assert!(s.mean_total_us > s.mean_queue_us);
        assert!(s.conserved());
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn snapshot_of_empty_metrics() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_batch_occupancy, 0.0);
        assert!(s.conserved());
    }

    #[test]
    fn delta_accounting_preserves_conservation_and_tracks_volume() {
        let m = Metrics::new();
        // a delta round: one base upload + 3 delta rows (d = 8)
        m.on_base_upload(128);
        for _ in 0..3 {
            m.on_submit(8);
        }
        // two served, one stale-rejected
        m.on_batch(2, 4, Duration::from_micros(50));
        m.on_response(Duration::ZERO, Duration::from_micros(60), 2, false);
        m.on_response(Duration::ZERO, Duration::from_micros(60), 2, false);
        m.on_stale_delta();
        let s = m.snapshot();
        assert_eq!(s.requests, 3, "a base upload is not a request");
        assert_eq!(s.base_uploads, 1);
        assert_eq!(s.shipped_f32, 128 + 3 * 8);
        assert_eq!(s.stale_deltas, 1);
        assert_eq!(s.dropped_requests, 1);
        assert!(s.conserved(), "stale deltas must count as dropped: {s:?}");
        assert!(s.summary().contains("stale_deltas=1"));
        assert!(s.summary().contains("bases=1"));
    }

    #[test]
    fn failed_batches_do_not_skew_success_stats() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.on_submit(4);
        }
        // one successful batch of 2, one failed batch dropping 1 request
        m.on_batch(2, 4, Duration::from_micros(100));
        m.on_response(Duration::from_micros(10), Duration::from_micros(110), 3, false);
        m.on_response(Duration::from_micros(12), Duration::from_micros(112), 3, false);
        m.on_batch_failed(1);
        let s = m.snapshot();
        assert_eq!(s.batches, 1, "failed executions must not count as batches");
        assert_eq!(s.failed_batches, 1);
        assert_eq!(s.dropped_requests, 1);
        assert!((s.mean_batch_occupancy - 2.0).abs() < 1e-9);
        assert!(s.conserved(), "requests == responses + dropped at quiescence");
        assert!(s.summary().contains("failed=1"));
        assert!(s.summary().contains("dropped=1"));
    }
}
