//! `.csp` text format implementation (see module docs in `mod.rs`).

use std::io::{BufRead, Write};

use crate::core::{Problem, Relation};

/// Read a problem from `.csp` text.
pub fn read_csp(reader: impl std::io::Read) -> Result<Problem, String> {
    let buf = std::io::BufReader::new(reader);
    let mut name = String::from("unnamed");
    let mut n_vars: Option<usize> = None;
    let mut default_dom: Option<usize> = None;
    let mut dom_overrides: Vec<(usize, usize)> = Vec::new();
    // constraints parsed before we can build the Problem (domain sizes
    // must be known first), so buffer them.
    struct PendingCon {
        x: usize,
        y: usize,
        mode_allow: bool,
        pairs: Vec<(usize, usize)>,
        line: usize,
    }
    let mut pending: Vec<PendingCon> = Vec::new();
    let mut current: Option<PendingCon> = None;

    for (lineno, line) in buf.lines().enumerate() {
        let line = line.map_err(|e| format!("io error: {e}"))?;
        let line = line.split('#').next().unwrap_or("").trim().to_string();
        if line.is_empty() {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if let Some(con) = current.as_mut() {
            match toks[0] {
                "end" => pending.push(current.take().unwrap()),
                _ => {
                    if toks.len() != 2 {
                        return Err(format!("line {}: expected 'a b' pair or 'end'", lineno + 1));
                    }
                    let a = toks[0].parse().map_err(|_| format!("line {}: bad value", lineno + 1))?;
                    let b = toks[1].parse().map_err(|_| format!("line {}: bad value", lineno + 1))?;
                    con.pairs.push((a, b));
                }
            }
            continue;
        }
        match toks[0] {
            "csp" => name = toks.get(1).unwrap_or(&"unnamed").to_string(),
            "vars" => {
                n_vars = Some(
                    toks.get(1)
                        .and_then(|t| t.parse().ok())
                        .ok_or(format!("line {}: vars <n>", lineno + 1))?,
                )
            }
            "domsize" => {
                default_dom = Some(
                    toks.get(1)
                        .and_then(|t| t.parse().ok())
                        .ok_or(format!("line {}: domsize <d>", lineno + 1))?,
                )
            }
            "dom" => {
                let v = toks.get(1).and_then(|t| t.parse().ok());
                let d = toks.get(2).and_then(|t| t.parse().ok());
                match (v, d) {
                    (Some(v), Some(d)) => dom_overrides.push((v, d)),
                    _ => return Err(format!("line {}: dom <var> <size>", lineno + 1)),
                }
            }
            "con" => {
                let x = toks.get(1).and_then(|t| t.parse().ok());
                let y = toks.get(2).and_then(|t| t.parse().ok());
                let mode = toks.get(3).copied();
                match (x, y, mode) {
                    (Some(x), Some(y), Some("allow")) => {
                        current = Some(PendingCon { x, y, mode_allow: true, pairs: vec![], line: lineno + 1 })
                    }
                    (Some(x), Some(y), Some("forbid")) => {
                        current = Some(PendingCon { x, y, mode_allow: false, pairs: vec![], line: lineno + 1 })
                    }
                    _ => return Err(format!("line {}: con <x> <y> allow|forbid", lineno + 1)),
                }
            }
            other => return Err(format!("line {}: unknown directive {other:?}", lineno + 1)),
        }
    }
    if current.is_some() {
        return Err("unterminated 'con' block (missing 'end')".into());
    }
    let n = n_vars.ok_or("missing 'vars' directive")?;
    let dd = default_dom.ok_or("missing 'domsize' directive")?;
    let mut sizes = vec![dd; n];
    for (v, d) in dom_overrides {
        if v >= n {
            return Err(format!("dom override for out-of-range var {v}"));
        }
        sizes[v] = d;
    }
    let mut p = Problem::with_domains(&name, sizes);
    for con in pending {
        if con.x >= n || con.y >= n || con.x == con.y {
            return Err(format!("line {}: bad constraint endpoints", con.line));
        }
        let (dx, dy) = (p.dom_size(con.x), p.dom_size(con.y));
        let mut rel = if con.mode_allow {
            Relation::forbid_all(dx, dy)
        } else {
            Relation::allow_all(dx, dy)
        };
        for (a, b) in con.pairs {
            if a >= dx || b >= dy {
                return Err(format!("line {}: value pair ({a},{b}) out of range", con.line));
            }
            if con.mode_allow {
                rel.allow(a, b);
            } else {
                rel.forbid(a, b);
            }
        }
        p.add_constraint(con.x, con.y, rel);
    }
    p.validate()?;
    Ok(p)
}

/// Write a problem as `.csp` text (choosing allow/forbid per relation by
/// whichever list is shorter).
pub fn write_csp(p: &Problem, w: &mut impl Write) -> std::io::Result<()> {
    writeln!(w, "# generated by rtac")?;
    writeln!(w, "csp {}", p.name().split_whitespace().next().unwrap_or("unnamed"))?;
    writeln!(w, "vars {}", p.n_vars())?;
    let dmax = p.max_dom_size();
    writeln!(w, "domsize {dmax}")?;
    for v in 0..p.n_vars() {
        if p.dom_size(v) != dmax {
            writeln!(w, "dom {} {}", v, p.dom_size(v))?;
        }
    }
    for c in p.constraints() {
        let (dx, dy) = (c.rel.dx(), c.rel.dy());
        let allowed = c.rel.cardinality();
        let forbidden = dx * dy - allowed;
        if allowed <= forbidden {
            writeln!(w, "con {} {} allow", c.x, c.y)?;
            for a in 0..dx {
                for b in c.rel.row_fwd(a).iter_ones() {
                    writeln!(w, "{a} {b}")?;
                }
            }
        } else {
            writeln!(w, "con {} {} forbid", c.x, c.y)?;
            for a in 0..dx {
                for b in 0..dy {
                    if !c.rel.allows(a, b) {
                        writeln!(w, "{a} {b}")?;
                    }
                }
            }
        }
        writeln!(w, "end")?;
    }
    Ok(())
}

/// Round-trip helper: problem -> text -> string.
pub fn to_string(p: &Problem) -> String {
    let mut buf = Vec::new();
    write_csp(p, &mut buf).expect("write to Vec cannot fail");
    String::from_utf8(buf).expect("csp text is utf8")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{queens, random::{random_csp, RandomSpec}};

    #[test]
    fn parse_minimal() {
        let src = "\
# a triangle
csp tri
vars 3
domsize 2
con 0 1 forbid
0 0
1 1
end
con 1 2 allow
0 1
1 0
end
";
        let p = read_csp(src.as_bytes()).unwrap();
        assert_eq!(p.name(), "tri");
        assert_eq!(p.n_vars(), 3);
        assert_eq!(p.n_constraints(), 2);
        assert!(!p.constraint(0).rel.allows(0, 0));
        assert!(p.constraint(0).rel.allows(0, 1));
        assert!(p.constraint(1).rel.allows(0, 1));
        assert!(!p.constraint(1).rel.allows(0, 0));
    }

    #[test]
    fn dom_override() {
        let src = "csp t\nvars 2\ndomsize 3\ndom 1 5\ncon 0 1 allow\n0 4\nend\n";
        let p = read_csp(src.as_bytes()).unwrap();
        assert_eq!(p.dom_size(0), 3);
        assert_eq!(p.dom_size(1), 5);
        assert!(p.constraint(0).rel.allows(0, 4));
    }

    #[test]
    fn errors_reported_with_lines() {
        assert!(read_csp("vars 2".as_bytes()).is_err()); // no domsize
        assert!(read_csp("domsize 2".as_bytes()).is_err()); // no vars
        let unterminated = "csp t\nvars 2\ndomsize 2\ncon 0 1 allow\n0 0\n";
        assert!(read_csp(unterminated.as_bytes()).unwrap_err().contains("unterminated"));
        let oob = "csp t\nvars 2\ndomsize 2\ncon 0 1 allow\n0 5\nend\n";
        assert!(read_csp(oob.as_bytes()).unwrap_err().contains("out of range"));
        let badtok = "csp t\nvars 2\ndomsize 2\nwhat 1\n";
        assert!(read_csp(badtok.as_bytes()).unwrap_err().contains("unknown directive"));
    }

    #[test]
    fn roundtrip_queens() {
        let p = queens(5);
        let text = to_string(&p);
        let q = read_csp(text.as_bytes()).unwrap();
        assert_eq!(q.n_vars(), p.n_vars());
        assert_eq!(q.n_constraints(), p.n_constraints());
        for (a, b) in p.constraints().iter().zip(q.constraints()) {
            assert_eq!((a.x, a.y), (b.x, b.y));
            assert_eq!(a.rel, b.rel);
        }
    }

    #[test]
    fn roundtrip_random() {
        let p = random_csp(&RandomSpec::new(10, 6, 0.5, 0.35, 17));
        let q = read_csp(to_string(&p).as_bytes()).unwrap();
        assert_eq!(q.n_constraints(), p.n_constraints());
        for (a, b) in p.constraints().iter().zip(q.constraints()) {
            assert_eq!(a.rel, b.rel, "constraint ({},{})", a.x, a.y);
        }
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let src = "\n# hi\ncsp t # trailing\n\nvars 2\ndomsize 2\n";
        let p = read_csp(src.as_bytes()).unwrap();
        assert_eq!(p.n_vars(), 2);
    }
}
