//! Plain-text CSP interchange format (`.csp`) reader/writer.
//!
//! The format is line-oriented and diff-friendly:
//!
//! ```text
//! # comment
//! csp <name>
//! vars <n>
//! dom <var> <size>            # optional; default domain size via `domsize`
//! domsize <size>              # uniform domain size shortcut
//! con <x> <y> allow|forbid    # followed by pair lines "a b", ended by "end"
//! a b
//! end
//! ```
//!
//! `allow` lists the allowed pairs (everything else forbidden); `forbid`
//! lists the forbidden pairs (everything else allowed — the economical
//! form for loose relations like `!=`).

pub mod text;

pub use text::{read_csp, write_csp};
