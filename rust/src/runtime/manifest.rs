//! Artifact manifest: the machine-readable index written by
//! `python/compile/aot.py` (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// What an artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    /// One revise sweep: (cons, vars) -> (vars',)
    Step,
    /// Full fixpoint with wipeout abort: (cons, vars) -> (vars*, iters, status)
    Fixpoint,
    /// Joint fixpoint over a batch: (cons, vars[B]) -> (vars*[B], iters, status[B])
    FixpointBatched,
    /// Prop.-2 incremental ablation variant.
    FixpointIncremental,
}

impl Kind {
    fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "step" => Kind::Step,
            "fixpoint" => Kind::Fixpoint,
            "fixpoint_batched" => Kind::FixpointBatched,
            "fixpoint_incremental" => Kind::FixpointIncremental,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub path: PathBuf,
    pub kind: Kind,
    /// Shape bucket: number of variables.
    pub n: usize,
    /// Shape bucket: domain size.
    pub d: usize,
    /// Batch size (1 except FixpointBatched).
    pub batch: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub block_x: usize,
    pub entries: Vec<Entry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?} (run `make artifacts`)"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("parsing {man_path:?}: {e}"))?;
        let format = root.get("format").and_then(Json::as_usize).unwrap_or(0);
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let block_x = root
            .get("block_x")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing block_x"))?;
        let mut entries = Vec::new();
        for e in root
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing {k}"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> Result<usize> {
                e.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("entry missing {k}"))
            };
            let name = get_str("name")?;
            let file = get_str("file")?;
            let path = dir.join(&file);
            if !path.exists() {
                bail!("artifact file {path:?} listed in manifest but missing on disk");
            }
            entries.push(Entry {
                name,
                path,
                kind: Kind::parse(&get_str("kind")?)?,
                n: get_usize("n")?,
                d: get_usize("d")?,
                batch: get_usize("batch")?,
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest { block_x, entries, dir: dir.to_path_buf() })
    }

    /// Entry lookup by name.
    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Distinct (n, d) buckets available for a kind, ascending by volume.
    pub fn buckets(&self, kind: Kind) -> Vec<(usize, usize)> {
        let mut b: Vec<(usize, usize)> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| (e.n, e.d))
            .collect();
        b.sort_by_key(|&(n, d)| n * d);
        b.dedup();
        b
    }

    /// Smallest entry of `kind` (and batch, where relevant) that fits a
    /// request of `n` variables × `d` values.
    pub fn pick(&self, kind: Kind, n: usize, d: usize, batch: usize) -> Option<&Entry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.batch == batch && e.n >= n && e.d >= d)
            .min_by_key(|e| e.n * e.n * e.d * e.d)
    }

    /// Batch sizes available for FixpointBatched at any bucket.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == Kind::FixpointBatched)
            .map(|e| e.batch)
            .collect();
        b.sort();
        b.dedup();
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn kind_parse() {
        assert_eq!(Kind::parse("step").unwrap(), Kind::Step);
        assert_eq!(Kind::parse("fixpoint_batched").unwrap(), Kind::FixpointBatched);
        assert!(Kind::parse("nope").is_err());
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        assert!(m.block_x >= 1);
        assert!(!m.entries.is_empty());
        assert!(!m.buckets(Kind::Fixpoint).is_empty());
        assert_eq!(m.batch_sizes(), vec![4, 8]);
    }

    #[test]
    fn pick_prefers_smallest_fitting_bucket() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let e = m.pick(Kind::Fixpoint, 10, 5, 1).expect("bucket for 10x5");
        assert_eq!((e.n, e.d), (16, 8));
        let tiny = m.pick(Kind::Fixpoint, 3, 3, 1).unwrap();
        assert_eq!((tiny.n, tiny.d), (8, 4));
        assert!(m.pick(Kind::Fixpoint, 1000, 4, 1).is_none());
    }

    #[test]
    fn missing_dir_is_error_with_hint() {
        let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
