//! PJRT execution of the AOT artifacts.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.  HLO *text*
//! is the interchange format (see `python/compile/aot.py`).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so a `Runtime` must stay on
//! the thread that created it; the coordinator owns one on a dedicated
//! executor thread and feeds it through channels.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{Entry, Kind, Manifest};

/// Output of a fixpoint artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct FixpointOut {
    /// The enforced plane(s): `batch * n * d` f32 values.
    pub vars: Vec<f32>,
    /// Sweeps executed (== native `#Recurrence` for batch == 1).
    pub iters: i32,
    /// Per-batch-element status: 0 consistent, 1 wipeout.
    pub status: Vec<i32>,
}

/// Status code produced by the L2 model.
pub const STATUS_CONSISTENT: i32 = 0;
/// Status code produced by the L2 model.
pub const STATUS_WIPEOUT: i32 = 1;

struct Loaded {
    entry: Entry,
    exe: xla::PjRtLoadedExecutable,
}

/// A device-resident tensor (see [`Runtime::upload`]).  Not `Send` —
/// lives and dies on the runtime's thread like everything PJRT.
pub struct DeviceTensor {
    buf: xla::PjRtBuffer,
}

/// A PJRT CPU client plus the compiled artifacts.
pub struct Runtime {
    manifest: Manifest,
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
}

impl Runtime {
    /// Load the manifest and compile every artifact eagerly.
    pub fn load(artifact_dir: &Path) -> Result<Runtime> {
        Self::load_filtered(artifact_dir, |_| true)
    }

    /// Load one `(n, d)` bucket's fixpoint artifacts — the unbatched
    /// `fix*` plus every compiled `fixb*` batch size — and nothing
    /// else.  This is the coordinator session's init (and *re-init*:
    /// the supervised executor rebuilds its whole PJRT state through
    /// this exact call when it restarts after a crash, so recovery is
    /// deterministic by construction — same artifacts, same compile).
    pub fn load_fixpoint_bucket(artifact_dir: &Path, n: usize, d: usize) -> Result<Runtime> {
        Self::load_filtered(artifact_dir, |e| {
            e.n == n && e.d == d && matches!(e.kind, Kind::Fixpoint | Kind::FixpointBatched)
        })
        .with_context(|| format!("loading the fixpoint artifacts of bucket {n}x{d}"))
    }

    /// Load the manifest and compile the entries `keep` accepts
    /// (compilation is the expensive part; benches load only what they
    /// exercise).
    pub fn load_filtered(artifact_dir: &Path, keep: impl Fn(&Entry) -> bool) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let mut loaded = HashMap::new();
        for entry in manifest.entries.iter().filter(|e| keep(e)) {
            let exe = compile_entry(&client, entry)
                .with_context(|| format!("compiling artifact {}", entry.name))?;
            loaded.insert(entry.name.clone(), Loaded { entry: entry.clone(), exe });
        }
        if loaded.is_empty() {
            bail!("no artifacts loaded from {artifact_dir:?}");
        }
        Ok(Runtime { manifest, client, loaded })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn loaded_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.loaded.keys().cloned().collect();
        names.sort();
        names
    }

    fn get(&self, name: &str) -> Result<&Loaded> {
        self.loaded
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not loaded (have {:?})", self.loaded_names()))
    }

    /// Execute a `step` artifact: one revise sweep.
    pub fn run_step(&self, name: &str, cons: &[f32], vars: &[f32]) -> Result<Vec<f32>> {
        let l = self.get(name)?;
        if l.entry.kind != Kind::Step {
            bail!("{name} is not a step artifact");
        }
        let (n, d) = (l.entry.n as i64, l.entry.d as i64);
        check_len(cons, (n * n * d * d) as usize, "cons")?;
        check_len(vars, (n * d) as usize, "vars")?;
        let cons_l = lit(cons, &[n, n, d, d])?;
        let vars_l = lit(vars, &[n, d])?;
        let out = execute(&l.exe, &[cons_l, vars_l])?;
        let out = out.to_tuple1().map_err(wrap)?;
        out.to_vec::<f32>().map_err(wrap)
    }

    /// Execute a fixpoint-family artifact.
    pub fn run_fixpoint(&self, name: &str, cons: &[f32], vars: &[f32]) -> Result<FixpointOut> {
        let l = self.get(name)?;
        let (n, d, b) = (l.entry.n as i64, l.entry.d as i64, l.entry.batch as i64);
        check_len(cons, (n * n * d * d) as usize, "cons")?;
        let cons_l = lit(cons, &[n, n, d, d])?;
        let vars_l = match l.entry.kind {
            Kind::Fixpoint | Kind::FixpointIncremental => {
                check_len(vars, (n * d) as usize, "vars")?;
                lit(vars, &[n, d])?
            }
            Kind::FixpointBatched => {
                check_len(vars, (b * n * d) as usize, "vars")?;
                lit(vars, &[b, n, d])?
            }
            Kind::Step => bail!("{name} is a step artifact; use run_step"),
        };
        let out = execute(&l.exe, &[cons_l, vars_l])?;
        let (vars_out, iters_out, status_out) = out.to_tuple3().map_err(wrap)?;
        let vars = vars_out.to_vec::<f32>().map_err(wrap)?;
        let iters = iters_out.to_vec::<i32>().map_err(wrap)?[0];
        let status = if l.entry.kind == Kind::FixpointBatched {
            status_out.to_vec::<i32>().map_err(wrap)?
        } else {
            vec![status_out.to_vec::<i32>().map_err(wrap)?[0]]
        };
        Ok(FixpointOut { vars, iters, status })
    }

    /// The entry metadata for a loaded artifact.
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        Ok(&self.get(name)?.entry)
    }

    /// Upload a tensor to the device once; reuse across executions.
    ///
    /// §Perf L3: the constraint tensor is by far the largest input
    /// (16.8 MB at the 64×16 bucket) and is immutable per session —
    /// re-uploading it per request dominated execution time (3.8 ms of
    /// the 6.3 ms fixpoint; EXPERIMENTS.md §Perf).  The coordinator
    /// uploads it once per session and passes the resident buffer.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<DeviceTensor> {
        let buf = self
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(wrap)?;
        Ok(DeviceTensor { buf })
    }

    /// `run_fixpoint` with a device-resident constraint tensor.
    pub fn run_fixpoint_dev(
        &self,
        name: &str,
        cons: &DeviceTensor,
        vars: &[f32],
    ) -> Result<FixpointOut> {
        let l = self.get(name)?;
        let (n, d, b) = (l.entry.n, l.entry.d, l.entry.batch);
        let vars_buf = match l.entry.kind {
            Kind::Fixpoint | Kind::FixpointIncremental => {
                check_len(vars, n * d, "vars")?;
                self.client.buffer_from_host_buffer(vars, &[n, d], None).map_err(wrap)?
            }
            Kind::FixpointBatched => {
                check_len(vars, b * n * d, "vars")?;
                self.client.buffer_from_host_buffer(vars, &[b, n, d], None).map_err(wrap)?
            }
            Kind::Step => bail!("{name} is a step artifact; use run_step"),
        };
        let bufs = l.exe.execute_b(&[&cons.buf, &vars_buf]).map_err(wrap)?;
        let out = bufs[0][0].to_literal_sync().map_err(wrap)?;
        let (vars_out, iters_out, status_out) = out.to_tuple3().map_err(wrap)?;
        let vars = vars_out.to_vec::<f32>().map_err(wrap)?;
        let iters = iters_out.to_vec::<i32>().map_err(wrap)?[0];
        let status = if l.entry.kind == Kind::FixpointBatched {
            status_out.to_vec::<i32>().map_err(wrap)?
        } else {
            vec![status_out.to_vec::<i32>().map_err(wrap)?[0]]
        };
        Ok(FixpointOut { vars, iters, status })
    }

    /// Host-driven fixpoint over the *step* artifact: Rust owns the
    /// recurrence loop, paying one host↔device round-trip per sweep.
    ///
    /// Semantically identical to the fused `fixpoint` artifact (asserted
    /// in tests); exists to *measure* what fusing the while_loop into one
    /// executable buys (EXPERIMENTS.md §Perf: round-trip ablation) and as
    /// the hook where an L3 scheduler could interleave work between
    /// sweeps.
    pub fn run_fixpoint_stepwise(
        &self,
        step_name: &str,
        cons: &[f32],
        vars: &[f32],
    ) -> Result<FixpointOut> {
        let entry = self.entry(step_name)?.clone();
        if entry.kind != Kind::Step {
            bail!("{step_name} is not a step artifact");
        }
        let (n, d) = (entry.n, entry.d);
        let mut cur = vars.to_vec();
        let mut iters = 0i32;
        loop {
            let next = self.run_step(step_name, cons, &cur)?;
            iters += 1;
            let wiped = (0..n).any(|x| next[x * d..(x + 1) * d].iter().all(|&v| v == 0.0));
            if wiped {
                return Ok(FixpointOut { vars: next, iters, status: vec![STATUS_WIPEOUT] });
            }
            if next == cur {
                return Ok(FixpointOut { vars: next, iters, status: vec![STATUS_CONSISTENT] });
            }
            cur = next;
        }
    }
}

fn compile_entry(client: &xla::PjRtClient, entry: &Entry) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = entry
        .path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", entry.path))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .map_err(|e| anyhow!("parsing HLO text {path_str}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("XLA compile failed: {e:?}"))
}

fn lit(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data).reshape(dims).map_err(wrap)
}

fn execute(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let bufs = exe.execute::<xla::Literal>(args).map_err(wrap)?;
    bufs[0][0].to_literal_sync().map_err(wrap)
}

fn check_len(xs: &[f32], want: usize, what: &str) -> Result<()> {
    if xs.len() != want {
        bail!("{what} has {} elements, artifact expects {want}", xs.len());
    }
    Ok(())
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}
