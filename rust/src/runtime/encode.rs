//! Tensor encoding/decoding: `Problem` + `State` ⇄ the f32 planes the
//! AOT artifacts consume (DESIGN.md §Hardware-Adaptation).
//!
//! Layout contract (mirrors `python/compile/kernels/ref.py`):
//! * `vars[x, a] = 1.0` iff value `a` is live in `dom(x)`; row-major
//!   `[n, d]`.
//! * `cons[x, y, a, b] = 1.0` iff the pair is allowed; row-major
//!   `[n, n, d, d]`; unconstrained pairs (and the diagonal) hold the
//!   universal relation.
//!
//! Padding up to a shape bucket `(N, D)` must be **AC-neutral**:
//! * padded *variables* (`x >= n`) get all-ones rows and universal
//!   relations — they support everything and are never pruned (unless a
//!   real domain wipes, which ends the run anyway);
//! * padded *values* (`a >= dom_size(x)` of a real variable) are 0 in
//!   `vars` and 0 in every real constraint slab, so they neither receive
//!   nor provide support.
//!
//! Neutrality is proven by `python/tests/test_model.py
//! TestPaddingNeutrality` and re-checked here against the native engine.

use anyhow::{bail, Result};

use crate::core::{DomainPlane, Problem, State, VarId};

/// A (n_vars, dom) shape bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub n: usize,
    pub d: usize,
}

impl Bucket {
    pub fn fits(&self, problem: &Problem) -> bool {
        problem.n_vars() <= self.n && problem.max_dom_size() <= self.d
    }

    pub fn cons_len(&self) -> usize {
        self.n * self.n * self.d * self.d
    }

    pub fn vars_len(&self) -> usize {
        self.n * self.d
    }
}

/// Encode the constraint tensor of `problem`, padded to `bucket`.
///
/// O(N²D²) — do this once per (problem, bucket) and cache (the
/// coordinator does); only the vars plane changes across requests.
pub fn encode_cons(problem: &Problem, bucket: Bucket) -> Result<Vec<f32>> {
    if !bucket.fits(problem) {
        bail!(
            "problem ({} vars, dom {}) exceeds bucket ({}, {})",
            problem.n_vars(),
            problem.max_dom_size(),
            bucket.n,
            bucket.d
        );
    }
    let (nn, dd) = (bucket.n, bucket.d);
    // universal by default (covers padded vars, diagonal, non-edges)
    let mut cons = vec![1.0f32; nn * nn * dd * dd];
    let idx = |x: usize, y: usize, a: usize, b: usize| ((x * nn + y) * dd + a) * dd + b;
    for c in problem.constraints() {
        let (dx, dy) = (c.rel.dx(), c.rel.dy());
        for a in 0..dd {
            for b in 0..dd {
                let allowed = a < dx && b < dy && c.rel.allows(a, b);
                let v = if allowed { 1.0 } else { 0.0 };
                // real pair: padded (a, b) region must provide no fake
                // support, so everything outside the real rectangle is 0.
                cons[idx(c.x, c.y, a, b)] = v;
                cons[idx(c.y, c.x, b, a)] = v;
            }
        }
    }
    Ok(cons)
}

/// Encode the current domains of `state`, padded to `bucket`.
pub fn encode_vars(problem: &Problem, state: &State, bucket: Bucket) -> Result<Vec<f32>> {
    if !bucket.fits(problem) {
        bail!("problem exceeds bucket");
    }
    let mut vars = Vec::new();
    encode_vars_into(state.plane(), bucket, &mut vars)?;
    Ok(vars)
}

/// Encode a domain plane — the flat arena — into the padded f32 tensor
/// layout, reusing `out` as the staging buffer (cleared and refilled; no
/// allocation once it has reached bucket size).
///
/// This is the arena follow-on recorded in ROADMAP.md: the arena rows
/// already mirror the tensor's `[n, d]` layout, so staging a plane for
/// upload is one pass over the word rows instead of a per-variable
/// re-gather through `Problem` + `State`.  The coordinator-routed SAC
/// backend stages the launch domains ONCE per probe round and derives
/// each probe's plane from the staging buffer with a single-row edit.
pub fn encode_vars_into(plane: &DomainPlane, bucket: Bucket, out: &mut Vec<f32>) -> Result<()> {
    let n = plane.n_vars();
    if n > bucket.n || plane.max_width() > bucket.d {
        bail!(
            "plane ({} vars, dom {}) exceeds bucket ({}, {})",
            n,
            plane.max_width(),
            bucket.n,
            bucket.d
        );
    }
    let dd = bucket.d;
    out.clear();
    out.resize(bucket.vars_len(), 0.0);
    for x in 0..n {
        let row = &mut out[x * dd..(x + 1) * dd];
        for a in plane.bits(x).iter_ones() {
            row[a] = 1.0;
        }
    }
    // padded variables: full dummy domains (all ones) — AC-neutral, see
    // the module docs.
    out[n * dd..].fill(1.0);
    Ok(())
}

/// Apply an output plane back onto `state`: every live value that the
/// plane zeroed is removed (through the trail, so search can undo it).
/// Returns the list of changed variables.
pub fn decode_vars(
    problem: &Problem,
    state: &mut State,
    plane: &[f32],
    bucket: Bucket,
) -> Result<Vec<VarId>> {
    if plane.len() != bucket.vars_len() {
        bail!("plane length {} != bucket {}", plane.len(), bucket.vars_len());
    }
    let dd = bucket.d;
    let mut changed = Vec::new();
    for x in 0..problem.n_vars() {
        let mut x_changed = false;
        for a in 0..problem.dom_size(x) {
            let live = state.contains(x, a);
            let keep = plane[x * dd + a] != 0.0;
            if live && !keep {
                state.remove(x, a);
                x_changed = true;
            } else if !live && keep {
                // the artifact can only remove values (monotone sweep);
                // seeing a resurrection means caller mixed up planes.
                bail!("plane resurrects removed value ({x}, {a})");
            }
        }
        if x_changed {
            changed.push(x);
        }
    }
    Ok(changed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::{rtac::RtacNative, Counters, Propagator};
    use crate::gen::random::{random_csp, RandomSpec};

    fn bucket() -> Bucket {
        Bucket { n: 16, d: 8 }
    }

    #[test]
    fn cons_universal_for_nonedges_zero_padded_for_edges() {
        let p = random_csp(&RandomSpec::new(5, 4, 0.99, 0.4, 3));
        let b = bucket();
        let cons = encode_cons(&p, b).unwrap();
        let idx = |x: usize, y: usize, a: usize, bb: usize| ((x * b.n + y) * b.d + a) * b.d + bb;
        // diagonal universal
        assert_eq!(cons[idx(0, 0, 7, 7)], 1.0);
        // padded var rows universal
        assert_eq!(cons[idx(10, 2, 3, 3)], 1.0);
        // a real edge: padded region is zero
        let c = &p.constraints()[0];
        assert_eq!(cons[idx(c.x, c.y, 0, 7)], 0.0); // b >= dy
        assert_eq!(cons[idx(c.x, c.y, 7, 0)], 0.0); // a >= dx
        // symmetry
        for a in 0..4 {
            for bb in 0..4 {
                assert_eq!(cons[idx(c.x, c.y, a, bb)], cons[idx(c.y, c.x, bb, a)]);
            }
        }
    }

    #[test]
    fn vars_padding_layout() {
        let p = random_csp(&RandomSpec::new(5, 4, 0.5, 0.3, 9));
        let mut s = State::new(&p);
        s.remove(2, 1);
        let b = bucket();
        let vars = encode_vars(&p, &s, b).unwrap();
        assert_eq!(vars.len(), 16 * 8);
        assert_eq!(vars[2 * 8 + 1], 0.0); // removed value
        assert_eq!(vars[2 * 8 + 0], 1.0);
        assert_eq!(vars[0 * 8 + 5], 0.0); // padded value of real var
        assert_eq!(vars[10 * 8 + 7], 1.0); // padded var fully live
    }

    #[test]
    fn encode_vars_into_matches_encode_vars_and_reuses_the_buffer() {
        let p = random_csp(&RandomSpec::new(6, 5, 0.7, 0.4, 21));
        let mut s = State::new(&p);
        s.remove(1, 2);
        s.remove(4, 0);
        s.assign(3, 1);
        let b = bucket();
        let reference = encode_vars(&p, &s, b).unwrap();
        let mut staged = vec![9.0f32; 3]; // stale content must be cleared
        encode_vars_into(s.plane(), b, &mut staged).unwrap();
        assert_eq!(staged, reference);
        // staging a second state into the same buffer must not leak the
        // first encoding
        let s2 = State::new(&p);
        encode_vars_into(s2.plane(), b, &mut staged).unwrap();
        assert_eq!(staged, encode_vars(&p, &s2, b).unwrap());
    }

    #[test]
    fn encode_vars_into_singleton_row_edit_matches_assigned_state() {
        // the XLA probe backend derives each probe plane from the staged
        // base by one row edit: that must equal encoding the assigned
        // state from scratch.
        let p = random_csp(&RandomSpec::new(5, 4, 0.6, 0.3, 8));
        let s = State::new(&p);
        let b = bucket();
        let mut base = Vec::new();
        encode_vars_into(s.plane(), b, &mut base).unwrap();
        for (x, a) in [(0usize, 2usize), (4, 0)] {
            let mut probe = base.clone();
            let row = &mut probe[x * b.d..(x + 1) * b.d];
            row.fill(0.0);
            row[a] = 1.0;
            let mut s_assigned = s.clone();
            s_assigned.assign(x, a);
            assert_eq!(probe, encode_vars(&p, &s_assigned, b).unwrap(), "probe ({x}, {a})");
        }
    }

    #[test]
    fn encode_vars_into_rejects_oversized_plane() {
        let p = random_csp(&RandomSpec::new(20, 4, 0.1, 0.1, 1));
        let s = State::new(&p);
        let mut out = Vec::new();
        assert!(encode_vars_into(s.plane(), bucket(), &mut out).is_err());
    }

    #[test]
    fn decode_applies_removals_and_reports_changes() {
        let p = random_csp(&RandomSpec::new(4, 4, 0.0, 0.0, 1));
        let mut s = State::new(&p);
        let b = bucket();
        let mut plane = encode_vars(&p, &s, b).unwrap();
        plane[0 * 8 + 2] = 0.0;
        plane[3 * 8 + 0] = 0.0;
        let changed = decode_vars(&p, &mut s, &plane, b).unwrap();
        assert_eq!(changed, vec![0, 3]);
        assert!(!s.contains(0, 2));
        assert!(!s.contains(3, 0));
        assert_eq!(s.dom_size(1), 4);
    }

    #[test]
    fn decode_rejects_resurrection() {
        let p = random_csp(&RandomSpec::new(3, 3, 0.0, 0.0, 1));
        let mut s = State::new(&p);
        s.remove(1, 1);
        let b = Bucket { n: 8, d: 4 };
        let mut plane = encode_vars(&p, &s, b).unwrap();
        plane[1 * 4 + 1] = 1.0;
        assert!(decode_vars(&p, &mut s, &plane, b).is_err());
    }

    #[test]
    fn bucket_too_small_is_error() {
        let p = random_csp(&RandomSpec::new(20, 4, 0.1, 0.1, 1));
        assert!(encode_cons(&p, bucket()).is_err());
        let s = State::new(&p);
        assert!(encode_vars(&p, &s, bucket()).is_err());
    }

    /// CPU reference of one dense revise sweep over the padded planes —
    /// mirrors ref.py, used to cross-check the encoding against the
    /// native engine (no XLA needed in unit tests).
    fn sweep_plane(cons: &[f32], vars: &[f32], b: Bucket) -> Vec<f32> {
        let (nn, dd) = (b.n, b.d);
        let mut out = vars.to_vec();
        for x in 0..nn {
            for a in 0..dd {
                if vars[x * dd + a] == 0.0 {
                    continue;
                }
                for y in 0..nn {
                    let mut supp = 0.0f32;
                    for bb in 0..dd {
                        supp += cons[((x * nn + y) * dd + a) * dd + bb] * vars[y * dd + bb];
                    }
                    if supp == 0.0 {
                        out[x * dd + a] = 0.0;
                        break;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn padded_sweep_fixpoint_matches_native_rtac() {
        for seed in [1u64, 7, 42] {
            let p = random_csp(&RandomSpec::new(6, 4, 0.8, 0.5, seed));
            let b = bucket();
            let cons = encode_cons(&p, b).unwrap();

            // native closure
            let mut s_native = State::new(&p);
            let mut c = Counters::default();
            let native_out = RtacNative::dense().enforce(&p, &mut s_native, &[], &mut c);

            // plane fixpoint
            let s0 = State::new(&p);
            let mut plane = encode_vars(&p, &s0, b).unwrap();
            let mut sweeps = 0;
            loop {
                let next = sweep_plane(&cons, &plane, b);
                sweeps += 1;
                let wiped = (0..p.n_vars())
                    .any(|x| (0..b.d).all(|a| next[x * b.d + a] == 0.0));
                if wiped || next == plane {
                    plane = next;
                    break;
                }
                plane = next;
            }
            assert_eq!(sweeps as u64, c.recurrences, "seed {seed}: sweep count");

            if native_out.is_consistent() {
                let mut s_decode = State::new(&p);
                decode_vars(&p, &mut s_decode, &plane, b).unwrap();
                assert_eq!(s_decode.snapshot(), s_native.snapshot(), "seed {seed}");
            }
        }
    }
}
