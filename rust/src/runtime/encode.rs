//! Tensor encoding/decoding: `Problem` + `State` ⇄ the f32 planes the
//! AOT artifacts consume (DESIGN.md §Hardware-Adaptation).
//!
//! Layout contract (mirrors `python/compile/kernels/ref.py`):
//! * `vars[x, a] = 1.0` iff value `a` is live in `dom(x)`; row-major
//!   `[n, d]`.
//! * `cons[x, y, a, b] = 1.0` iff the pair is allowed; row-major
//!   `[n, n, d, d]`; unconstrained pairs (and the diagonal) hold the
//!   universal relation.
//!
//! Padding up to a shape bucket `(N, D)` must be **AC-neutral**:
//! * padded *variables* (`x >= n`) get all-ones rows and universal
//!   relations — they support everything and are never pruned (unless a
//!   real domain wipes, which ends the run anyway);
//! * padded *values* (`a >= dom_size(x)` of a real variable) are 0 in
//!   `vars` and 0 in every real constraint slab, so they neither receive
//!   nor provide support.
//!
//! Neutrality is proven by `python/tests/test_model.py
//! TestPaddingNeutrality` and re-checked here against the native engine.
//!
//! # Delta plane encoding
//!
//! Two serving workloads re-ship planes that differ from a plane the
//! executor has already seen in only a few rows:
//!
//! * a batched-SAC probe round submits K planes that are all the *same*
//!   launch plane with one variable row replaced by a singleton;
//! * consecutive MAC search nodes submit planes that differ from the
//!   previous node's plane in the handful of rows the last assignment,
//!   backtrack, and propagation touched.
//!
//! Shipping full planes re-sends the unchanged rows every time; a
//! [`PlaneDelta`] instead names the base plane by content fingerprint
//! ([`plane_fingerprint`]) and carries only the replaced rows.  A probe
//! is the 1-row case ([`PlaneDelta::singleton`]); a search step is the
//! general case ([`PlaneDelta::diff`] between the consecutive planes).
//! The consumer (the coordinator executor) caches one base per
//! *client*, keyed by that fingerprint, and reconstructs full planes
//! with [`PlaneDelta::apply`]; a re-upload replaces (invalidates) that
//! client's base, and a delta whose fingerprint misses the cache is
//! rejected rather than silently applied to the wrong base.
//!
//! ```
//! use rtac::runtime::{plane_fingerprint, Bucket, PlaneDelta};
//!
//! let bucket = Bucket { n: 2, d: 2 };
//! let base = vec![1.0, 1.0, 1.0, 1.0]; // both vars fully live
//! let fp = plane_fingerprint(&base);
//! // probe "x0 := 1": same plane, row 0 reduced to the singleton {1}
//! let probe = PlaneDelta::singleton(fp, 0, 1, bucket);
//! assert_eq!(probe.apply(&base, bucket).unwrap(), vec![0.0, 1.0, 1.0, 1.0]);
//! // a delta against a different base is refused, not misapplied
//! let other = vec![1.0, 0.0, 1.0, 1.0];
//! assert!(probe.apply(&other, bucket).is_err());
//! // the search-step case: diff two consecutive planes row-wise
//! let next = vec![1.0, 1.0, 0.0, 1.0]; // only row 1 changed
//! let step = PlaneDelta::diff(&base, &next, bucket).unwrap();
//! assert_eq!(step.n_rows(), 1);
//! assert_eq!(step.shipped_f32(), bucket.d);
//! assert_eq!(step.apply(&base, bucket).unwrap(), next);
//! ```

use anyhow::{bail, Result};

use crate::core::{DomainPlane, Problem, State, Val, VarId};

/// A (n_vars, dom) shape bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub n: usize,
    pub d: usize,
}

impl Bucket {
    pub fn fits(&self, problem: &Problem) -> bool {
        problem.n_vars() <= self.n && problem.max_dom_size() <= self.d
    }

    pub fn cons_len(&self) -> usize {
        self.n * self.n * self.d * self.d
    }

    pub fn vars_len(&self) -> usize {
        self.n * self.d
    }
}

/// Encode the constraint tensor of `problem`, padded to `bucket`.
///
/// O(N²D²) — do this once per (problem, bucket) and cache (the
/// coordinator does); only the vars plane changes across requests.
pub fn encode_cons(problem: &Problem, bucket: Bucket) -> Result<Vec<f32>> {
    if !bucket.fits(problem) {
        bail!(
            "problem ({} vars, dom {}) exceeds bucket ({}, {})",
            problem.n_vars(),
            problem.max_dom_size(),
            bucket.n,
            bucket.d
        );
    }
    let (nn, dd) = (bucket.n, bucket.d);
    // universal by default (covers padded vars, diagonal, non-edges)
    let mut cons = vec![1.0f32; nn * nn * dd * dd];
    let idx = |x: usize, y: usize, a: usize, b: usize| ((x * nn + y) * dd + a) * dd + b;
    for c in problem.constraints() {
        let (dx, dy) = (c.rel.dx(), c.rel.dy());
        for a in 0..dd {
            for b in 0..dd {
                let allowed = a < dx && b < dy && c.rel.allows(a, b);
                let v = if allowed { 1.0 } else { 0.0 };
                // real pair: padded (a, b) region must provide no fake
                // support, so everything outside the real rectangle is 0.
                cons[idx(c.x, c.y, a, b)] = v;
                cons[idx(c.y, c.x, b, a)] = v;
            }
        }
    }
    Ok(cons)
}

/// Encode the current domains of `state`, padded to `bucket`.
pub fn encode_vars(problem: &Problem, state: &State, bucket: Bucket) -> Result<Vec<f32>> {
    if !bucket.fits(problem) {
        bail!("problem exceeds bucket");
    }
    let mut vars = Vec::new();
    encode_vars_into(state.plane(), bucket, &mut vars)?;
    Ok(vars)
}

/// Encode a domain plane — the flat arena — into the padded f32 tensor
/// layout, reusing `out` as the staging buffer (cleared and refilled; no
/// allocation once it has reached bucket size).
///
/// This is the arena follow-on recorded in ROADMAP.md: the arena rows
/// already mirror the tensor's `[n, d]` layout, so staging a plane for
/// upload is one pass over the word rows instead of a per-variable
/// re-gather through `Problem` + `State`.  The coordinator-routed SAC
/// backend stages the launch domains ONCE per probe round and derives
/// each probe's plane from the staging buffer with a single-row edit.
pub fn encode_vars_into(plane: &DomainPlane, bucket: Bucket, out: &mut Vec<f32>) -> Result<()> {
    let n = plane.n_vars();
    if n > bucket.n || plane.max_width() > bucket.d {
        bail!(
            "plane ({} vars, dom {}) exceeds bucket ({}, {})",
            n,
            plane.max_width(),
            bucket.n,
            bucket.d
        );
    }
    let dd = bucket.d;
    out.clear();
    out.resize(bucket.vars_len(), 0.0);
    for x in 0..n {
        let row = &mut out[x * dd..(x + 1) * dd];
        for a in plane.bits(x).iter_ones() {
            row[a] = 1.0;
        }
    }
    // padded variables: full dummy domains (all ones) — AC-neutral, see
    // the module docs.
    out[n * dd..].fill(1.0);
    Ok(())
}

/// Content fingerprint of an encoded f32 plane (FNV-1a over the raw bit
/// patterns) — the cache key of the delta-probe protocol (see the
/// module docs).  Two planes share a fingerprint iff they are
/// bit-identical (modulo the astronomically unlikely 64-bit collision),
/// so `-0.0` vs `0.0` differ — irrelevant here because every encoder in
/// this module writes literal `0.0`/`1.0`.
pub fn plane_fingerprint(plane: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in plane {
        h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A plane in delta form: the identity of a base plane plus the
/// variable rows that differ.  Two producers ship these instead of full
/// `[N, D]` planes (see the module docs for the protocol,
/// [`crate::coordinator::Handle::submit_batch_delta`] and
/// [`crate::coordinator::Handle::submit_delta`] for the client-side
/// entry points):
///
/// * a batched-SAC probe round — one base upload + K single-row
///   ([`PlaneDelta::singleton`]) deltas per round;
/// * a MAC search worker — one base upload per session (or per
///   invalidation), then a [`PlaneDelta::diff`] of changed rows per
///   search node.
#[derive(Clone, Debug, PartialEq)]
pub struct PlaneDelta {
    /// [`plane_fingerprint`] of the base plane this delta edits.
    pub base_fp: u64,
    /// The replaced rows: `(row index, replacement row)` pairs in
    /// strictly ascending row order, each row exactly `bucket.d`
    /// values.  Empty is legal — the plane *is* the base (how a client
    /// re-synchronizes right after uploading a fresh base).
    pub rows: Vec<(VarId, Vec<f32>)>,
}

impl PlaneDelta {
    /// The delta of a singleton probe `var := val`: a one-hot row.  The
    /// SAC probe shape — reducing one variable to `{val}` and leaving
    /// every other row of the base untouched.
    pub fn singleton(base_fp: u64, var: VarId, val: Val, bucket: Bucket) -> PlaneDelta {
        debug_assert!(var < bucket.n && val < bucket.d);
        let mut row = vec![0.0; bucket.d];
        row[val] = 1.0;
        PlaneDelta { base_fp, rows: vec![(var, row)] }
    }

    /// The empty delta: reconstructs to the base itself.  What a client
    /// submits right after [`PlaneDelta::diff`] found nothing to ship,
    /// or right after uploading a fresh base (the request still needs
    /// an enforcement response; it just carries no rows).
    pub fn empty(base_fp: u64) -> PlaneDelta {
        PlaneDelta { base_fp, rows: Vec::new() }
    }

    /// The row-wise difference between two consecutive planes of the
    /// same bucket: every `[N, D]` row where `next` differs from
    /// `base`, keyed by `base`'s fingerprint.  Applying the result to
    /// `base` reconstructs `next` bit-exactly — the search-plane delta
    /// the MAC workers ship per node.
    pub fn diff(base: &[f32], next: &[f32], bucket: Bucket) -> Result<PlaneDelta> {
        if base.len() != bucket.vars_len() || next.len() != bucket.vars_len() {
            bail!(
                "diff planes have {} / {} values, bucket wants {}",
                base.len(),
                next.len(),
                bucket.vars_len()
            );
        }
        let d = bucket.d;
        let rows = (0..bucket.n)
            .filter(|&x| base[x * d..(x + 1) * d] != next[x * d..(x + 1) * d])
            .map(|x| (x, next[x * d..(x + 1) * d].to_vec()))
            .collect();
        Ok(PlaneDelta { base_fp: plane_fingerprint(base), rows })
    }

    /// Number of replaced rows.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// f32 values this delta ships client→executor (its rows; the base
    /// fingerprint and row indices are metadata) — the quantity
    /// [`crate::coordinator::Metrics`] accounts under `shipped_f32`.
    pub fn shipped_f32(&self) -> usize {
        self.rows.iter().map(|(_, row)| row.len()).sum()
    }

    /// Shape-check this delta against `bucket` without a base plane —
    /// what [`crate::coordinator::Handle::submit_batch_delta`] runs
    /// before enqueuing anything.  Rows must be strictly ascending (so
    /// no row is replaced twice) and exactly `bucket.d` wide.
    pub fn validate(&self, bucket: Bucket) -> Result<()> {
        let mut prev: Option<VarId> = None;
        for (var, row) in &self.rows {
            let var = *var;
            if var >= bucket.n {
                bail!("delta edits var {var} but the bucket has {} rows", bucket.n);
            }
            if row.len() != bucket.d {
                bail!(
                    "delta row for var {var} has {} values, bucket rows hold {}",
                    row.len(),
                    bucket.d
                );
            }
            if prev.is_some_and(|p| p >= var) {
                bail!("delta rows must be strictly ascending by var (saw {var} after {prev:?})");
            }
            prev = Some(var);
        }
        Ok(())
    }

    /// Reconstruct the full plane into `out` (cleared and refilled):
    /// the base with every delta row replaced.  Refuses a base whose
    /// shape or fingerprint does not match — a delta must never be
    /// applied to a plane other than the one it was derived from.
    pub fn apply_into(&self, base: &[f32], bucket: Bucket, out: &mut Vec<f32>) -> Result<()> {
        self.validate(bucket)?;
        if base.len() != bucket.vars_len() {
            bail!("base plane has {} values, bucket wants {}", base.len(), bucket.vars_len());
        }
        let fp = plane_fingerprint(base);
        if fp != self.base_fp {
            bail!(
                "delta was derived from base {:016x} but got base {fp:016x} \
                 (stale or unknown base plane)",
                self.base_fp
            );
        }
        out.clear();
        out.extend_from_slice(base);
        for (var, row) in &self.rows {
            let start = var * bucket.d;
            out[start..start + bucket.d].copy_from_slice(row);
        }
        Ok(())
    }

    /// [`PlaneDelta::apply_into`] into a fresh buffer.
    pub fn apply(&self, base: &[f32], bucket: Bucket) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.apply_into(base, bucket, &mut out)?;
        Ok(out)
    }
}

/// Apply an output plane back onto `state`: every live value that the
/// plane zeroed is removed (through the trail, so search can undo it).
/// Returns the list of changed variables.
pub fn decode_vars(
    problem: &Problem,
    state: &mut State,
    plane: &[f32],
    bucket: Bucket,
) -> Result<Vec<VarId>> {
    if plane.len() != bucket.vars_len() {
        bail!("plane length {} != bucket {}", plane.len(), bucket.vars_len());
    }
    let dd = bucket.d;
    let mut changed = Vec::new();
    for x in 0..problem.n_vars() {
        let mut x_changed = false;
        for a in 0..problem.dom_size(x) {
            let live = state.contains(x, a);
            let keep = plane[x * dd + a] != 0.0;
            if live && !keep {
                state.remove(x, a);
                x_changed = true;
            } else if !live && keep {
                // the artifact can only remove values (monotone sweep);
                // seeing a resurrection means caller mixed up planes.
                bail!("plane resurrects removed value ({x}, {a})");
            }
        }
        if x_changed {
            changed.push(x);
        }
    }
    Ok(changed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::{rtac::RtacNative, Counters, Propagator};
    use crate::gen::random::{random_csp, RandomSpec};

    fn bucket() -> Bucket {
        Bucket { n: 16, d: 8 }
    }

    #[test]
    fn cons_universal_for_nonedges_zero_padded_for_edges() {
        let p = random_csp(&RandomSpec::new(5, 4, 0.99, 0.4, 3));
        let b = bucket();
        let cons = encode_cons(&p, b).unwrap();
        let idx = |x: usize, y: usize, a: usize, bb: usize| ((x * b.n + y) * b.d + a) * b.d + bb;
        // diagonal universal
        assert_eq!(cons[idx(0, 0, 7, 7)], 1.0);
        // padded var rows universal
        assert_eq!(cons[idx(10, 2, 3, 3)], 1.0);
        // a real edge: padded region is zero
        let c = &p.constraints()[0];
        assert_eq!(cons[idx(c.x, c.y, 0, 7)], 0.0); // b >= dy
        assert_eq!(cons[idx(c.x, c.y, 7, 0)], 0.0); // a >= dx
        // symmetry
        for a in 0..4 {
            for bb in 0..4 {
                assert_eq!(cons[idx(c.x, c.y, a, bb)], cons[idx(c.y, c.x, bb, a)]);
            }
        }
    }

    #[test]
    fn vars_padding_layout() {
        let p = random_csp(&RandomSpec::new(5, 4, 0.5, 0.3, 9));
        let mut s = State::new(&p);
        s.remove(2, 1);
        let b = bucket();
        let vars = encode_vars(&p, &s, b).unwrap();
        assert_eq!(vars.len(), 16 * 8);
        assert_eq!(vars[2 * 8 + 1], 0.0); // removed value
        assert_eq!(vars[2 * 8 + 0], 1.0);
        assert_eq!(vars[0 * 8 + 5], 0.0); // padded value of real var
        assert_eq!(vars[10 * 8 + 7], 1.0); // padded var fully live
    }

    #[test]
    fn encode_vars_into_matches_encode_vars_and_reuses_the_buffer() {
        let p = random_csp(&RandomSpec::new(6, 5, 0.7, 0.4, 21));
        let mut s = State::new(&p);
        s.remove(1, 2);
        s.remove(4, 0);
        s.assign(3, 1);
        let b = bucket();
        let reference = encode_vars(&p, &s, b).unwrap();
        let mut staged = vec![9.0f32; 3]; // stale content must be cleared
        encode_vars_into(s.plane(), b, &mut staged).unwrap();
        assert_eq!(staged, reference);
        // staging a second state into the same buffer must not leak the
        // first encoding
        let s2 = State::new(&p);
        encode_vars_into(s2.plane(), b, &mut staged).unwrap();
        assert_eq!(staged, encode_vars(&p, &s2, b).unwrap());
    }

    #[test]
    fn encode_vars_into_singleton_row_edit_matches_assigned_state() {
        // the XLA probe backend derives each probe plane from the staged
        // base by one row edit: that must equal encoding the assigned
        // state from scratch.
        let p = random_csp(&RandomSpec::new(5, 4, 0.6, 0.3, 8));
        let s = State::new(&p);
        let b = bucket();
        let mut base = Vec::new();
        encode_vars_into(s.plane(), b, &mut base).unwrap();
        for (x, a) in [(0usize, 2usize), (4, 0)] {
            let mut probe = base.clone();
            let row = &mut probe[x * b.d..(x + 1) * b.d];
            row.fill(0.0);
            row[a] = 1.0;
            let mut s_assigned = s.clone();
            s_assigned.assign(x, a);
            assert_eq!(probe, encode_vars(&p, &s_assigned, b).unwrap(), "probe ({x}, {a})");
        }
    }

    #[test]
    fn delta_reconstruction_equals_full_plane_encoding_for_random_edits() {
        // the satellite contract: for random instances and random
        // singleton edits, base + PlaneDelta must be bit-identical to
        // encoding the edited state from scratch.
        let b = bucket();
        for seed in [3u64, 19, 77] {
            let p = random_csp(&RandomSpec::new(6, 5, 0.7, 0.4, seed));
            let mut s = State::new(&p);
            // a non-trivial base: knock out a few values first
            s.remove(0, 1);
            s.remove(3, 2);
            let base = encode_vars(&p, &s, b).unwrap();
            let fp = plane_fingerprint(&base);
            let mut rng = crate::util::rng::Rng::new(seed);
            for _ in 0..8 {
                let x = rng.gen_range(p.n_vars());
                let a = rng.gen_range(p.dom_size(x));
                if !s.contains(x, a) {
                    continue;
                }
                let delta = PlaneDelta::singleton(fp, x, a, b);
                let mut s_assigned = s.clone();
                s_assigned.assign(x, a);
                let reference = encode_vars(&p, &s_assigned, b).unwrap();
                assert_eq!(delta.apply(&base, b).unwrap(), reference, "probe ({x}, {a})");
            }
        }
    }

    #[test]
    fn diff_reconstructs_consecutive_search_planes_exactly() {
        // the search-plane contract: for consecutive states along a MAC
        // path (assign, propagate-ish removals, backtrack), diff(prev,
        // next) applied to prev is bit-identical to next, and ships
        // only the changed rows.
        let b = bucket();
        for seed in [4u64, 23, 61] {
            let p = random_csp(&RandomSpec::new(7, 5, 0.6, 0.35, seed));
            let mut s = State::new(&p);
            let mut prev = encode_vars(&p, &s, b).unwrap();
            let mut rng = crate::util::rng::Rng::new(seed);
            for step in 0..6 {
                // mutate a couple of rows, like one search node does
                let x = rng.gen_range(p.n_vars());
                if s.dom_size(x) > 1 {
                    let a = s.dom(x).iter_ones().next().unwrap();
                    s.remove(x, a);
                }
                let y = rng.gen_range(p.n_vars());
                if s.dom_size(y) > 1 {
                    let a = s.dom(y).iter_ones().next().unwrap();
                    s.assign(y, a);
                }
                let next = encode_vars(&p, &s, b).unwrap();
                let delta = PlaneDelta::diff(&prev, &next, b).unwrap();
                assert!(delta.n_rows() <= 2, "seed {seed} step {step}: at most 2 rows changed");
                assert_eq!(delta.shipped_f32(), delta.n_rows() * b.d);
                assert_eq!(delta.apply(&prev, b).unwrap(), next, "seed {seed} step {step}");
                prev = next;
            }
            // identical planes diff to the empty delta
            let noop = PlaneDelta::diff(&prev, &prev, b).unwrap();
            assert_eq!(noop.n_rows(), 0);
            assert_eq!(noop, PlaneDelta::empty(plane_fingerprint(&prev)));
            assert_eq!(noop.apply(&prev, b).unwrap(), prev);
        }
    }

    #[test]
    fn multi_row_delta_applies_all_rows() {
        let b = Bucket { n: 4, d: 3 };
        let base = vec![1.0; b.vars_len()];
        let fp = plane_fingerprint(&base);
        let delta = PlaneDelta {
            base_fp: fp,
            rows: vec![(0, vec![0.0, 1.0, 0.0]), (2, vec![1.0, 0.0, 0.0])],
        };
        assert_eq!(delta.n_rows(), 2);
        assert_eq!(delta.shipped_f32(), 6);
        let got = delta.apply(&base, b).unwrap();
        assert_eq!(got[0..3], [0.0, 1.0, 0.0]);
        assert_eq!(got[3..6], [1.0; 3]);
        assert_eq!(got[6..9], [1.0, 0.0, 0.0]);
        assert_eq!(got[9..12], [1.0; 3]);
    }

    #[test]
    fn delta_rejects_unordered_or_duplicate_rows() {
        let b = Bucket { n: 4, d: 3 };
        let row = vec![1.0, 0.0, 0.0];
        let unordered =
            PlaneDelta { base_fp: 1, rows: vec![(2, row.clone()), (0, row.clone())] };
        assert!(unordered.validate(b).is_err());
        let duplicate = PlaneDelta { base_fp: 1, rows: vec![(2, row.clone()), (2, row)] };
        assert!(duplicate.validate(b).is_err());
    }

    #[test]
    fn delta_apply_reuses_the_buffer() {
        let b = bucket();
        let base = vec![1.0; b.vars_len()];
        let fp = plane_fingerprint(&base);
        let mut out = vec![9.0f32; 3]; // stale content must be cleared
        PlaneDelta::singleton(fp, 2, 1, b).apply_into(&base, b, &mut out).unwrap();
        assert_eq!(out.len(), b.vars_len());
        assert_eq!(out[2 * b.d + 1], 1.0);
        assert_eq!(out[2 * b.d], 0.0);
        // second apply into the same buffer must not leak the first
        PlaneDelta::singleton(fp, 0, 0, b).apply_into(&base, b, &mut out).unwrap();
        assert_eq!(out[2 * b.d], 1.0, "row 2 must be back to the base");
    }

    #[test]
    fn delta_rejects_stale_base_and_bad_shapes() {
        let b = bucket();
        let base = vec![1.0; b.vars_len()];
        let fp = plane_fingerprint(&base);
        // stale base: same shape, different content
        let mut other = base.clone();
        other[5] = 0.0;
        let err = PlaneDelta::singleton(fp, 0, 0, b).apply(&other, b).unwrap_err();
        assert!(format!("{err:#}").contains("stale"), "{err:#}");
        // row length mismatch
        let bad_row = PlaneDelta { base_fp: fp, rows: vec![(0, vec![1.0; b.d + 1])] };
        assert!(bad_row.validate(b).is_err());
        assert!(bad_row.apply(&base, b).is_err());
        // var out of the bucket
        let bad_var = PlaneDelta { base_fp: fp, rows: vec![(b.n, vec![1.0; b.d])] };
        assert!(bad_var.validate(b).is_err());
        // base of the wrong length
        assert!(PlaneDelta::singleton(fp, 0, 0, b).apply(&base[1..], b).is_err());
    }

    #[test]
    fn plane_fingerprint_is_content_keyed() {
        let a = vec![1.0, 0.0, 1.0];
        let b = vec![1.0, 0.0, 1.0];
        let c = vec![0.0, 1.0, 1.0]; // same multiset, different positions
        assert_eq!(plane_fingerprint(&a), plane_fingerprint(&b));
        assert_ne!(plane_fingerprint(&a), plane_fingerprint(&c));
        assert_ne!(plane_fingerprint(&a), plane_fingerprint(&a[..2]));
    }

    #[test]
    fn encode_vars_into_rejects_oversized_plane() {
        let p = random_csp(&RandomSpec::new(20, 4, 0.1, 0.1, 1));
        let s = State::new(&p);
        let mut out = Vec::new();
        assert!(encode_vars_into(s.plane(), bucket(), &mut out).is_err());
    }

    #[test]
    fn decode_applies_removals_and_reports_changes() {
        let p = random_csp(&RandomSpec::new(4, 4, 0.0, 0.0, 1));
        let mut s = State::new(&p);
        let b = bucket();
        let mut plane = encode_vars(&p, &s, b).unwrap();
        plane[0 * 8 + 2] = 0.0;
        plane[3 * 8 + 0] = 0.0;
        let changed = decode_vars(&p, &mut s, &plane, b).unwrap();
        assert_eq!(changed, vec![0, 3]);
        assert!(!s.contains(0, 2));
        assert!(!s.contains(3, 0));
        assert_eq!(s.dom_size(1), 4);
    }

    #[test]
    fn decode_rejects_resurrection() {
        let p = random_csp(&RandomSpec::new(3, 3, 0.0, 0.0, 1));
        let mut s = State::new(&p);
        s.remove(1, 1);
        let b = Bucket { n: 8, d: 4 };
        let mut plane = encode_vars(&p, &s, b).unwrap();
        plane[1 * 4 + 1] = 1.0;
        assert!(decode_vars(&p, &mut s, &plane, b).is_err());
    }

    #[test]
    fn bucket_too_small_is_error() {
        let p = random_csp(&RandomSpec::new(20, 4, 0.1, 0.1, 1));
        assert!(encode_cons(&p, bucket()).is_err());
        let s = State::new(&p);
        assert!(encode_vars(&p, &s, bucket()).is_err());
    }

    /// CPU reference of one dense revise sweep over the padded planes —
    /// mirrors ref.py, used to cross-check the encoding against the
    /// native engine (no XLA needed in unit tests).
    fn sweep_plane(cons: &[f32], vars: &[f32], b: Bucket) -> Vec<f32> {
        let (nn, dd) = (b.n, b.d);
        let mut out = vars.to_vec();
        for x in 0..nn {
            for a in 0..dd {
                if vars[x * dd + a] == 0.0 {
                    continue;
                }
                for y in 0..nn {
                    let mut supp = 0.0f32;
                    for bb in 0..dd {
                        supp += cons[((x * nn + y) * dd + a) * dd + bb] * vars[y * dd + bb];
                    }
                    if supp == 0.0 {
                        out[x * dd + a] = 0.0;
                        break;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn padded_sweep_fixpoint_matches_native_rtac() {
        for seed in [1u64, 7, 42] {
            let p = random_csp(&RandomSpec::new(6, 4, 0.8, 0.5, seed));
            let b = bucket();
            let cons = encode_cons(&p, b).unwrap();

            // native closure
            let mut s_native = State::new(&p);
            let mut c = Counters::default();
            let native_out = RtacNative::dense().enforce(&p, &mut s_native, &[], &mut c);

            // plane fixpoint
            let s0 = State::new(&p);
            let mut plane = encode_vars(&p, &s0, b).unwrap();
            let mut sweeps = 0;
            loop {
                let next = sweep_plane(&cons, &plane, b);
                sweeps += 1;
                let wiped = (0..p.n_vars())
                    .any(|x| (0..b.d).all(|a| next[x * b.d + a] == 0.0));
                if wiped || next == plane {
                    plane = next;
                    break;
                }
                plane = next;
            }
            assert_eq!(sweeps as u64, c.recurrences, "seed {seed}: sweep count");

            if native_out.is_consistent() {
                let mut s_decode = State::new(&p);
                decode_vars(&p, &mut s_decode, &plane, b).unwrap();
                assert_eq!(s_decode.snapshot(), s_native.snapshot(), "seed {seed}");
            }
        }
    }
}
