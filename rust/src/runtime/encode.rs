//! Tensor encoding/decoding: `Problem` + `State` ⇄ the f32 planes the
//! AOT artifacts consume (DESIGN.md §Hardware-Adaptation).
//!
//! Layout contract (mirrors `python/compile/kernels/ref.py`):
//! * `vars[x, a] = 1.0` iff value `a` is live in `dom(x)`; row-major
//!   `[n, d]`.
//! * `cons[x, y, a, b] = 1.0` iff the pair is allowed; row-major
//!   `[n, n, d, d]`; unconstrained pairs (and the diagonal) hold the
//!   universal relation.
//!
//! Padding up to a shape bucket `(N, D)` must be **AC-neutral**:
//! * padded *variables* (`x >= n`) get all-ones rows and universal
//!   relations — they support everything and are never pruned (unless a
//!   real domain wipes, which ends the run anyway);
//! * padded *values* (`a >= dom_size(x)` of a real variable) are 0 in
//!   `vars` and 0 in every real constraint slab, so they neither receive
//!   nor provide support.
//!
//! Neutrality is proven by `python/tests/test_model.py
//! TestPaddingNeutrality` and re-checked here against the native engine.

use anyhow::{bail, Result};

use crate::core::{Problem, State, VarId};

/// A (n_vars, dom) shape bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub n: usize,
    pub d: usize,
}

impl Bucket {
    pub fn fits(&self, problem: &Problem) -> bool {
        problem.n_vars() <= self.n && problem.max_dom_size() <= self.d
    }

    pub fn cons_len(&self) -> usize {
        self.n * self.n * self.d * self.d
    }

    pub fn vars_len(&self) -> usize {
        self.n * self.d
    }
}

/// Encode the constraint tensor of `problem`, padded to `bucket`.
///
/// O(N²D²) — do this once per (problem, bucket) and cache (the
/// coordinator does); only the vars plane changes across requests.
pub fn encode_cons(problem: &Problem, bucket: Bucket) -> Result<Vec<f32>> {
    if !bucket.fits(problem) {
        bail!(
            "problem ({} vars, dom {}) exceeds bucket ({}, {})",
            problem.n_vars(),
            problem.max_dom_size(),
            bucket.n,
            bucket.d
        );
    }
    let (nn, dd) = (bucket.n, bucket.d);
    // universal by default (covers padded vars, diagonal, non-edges)
    let mut cons = vec![1.0f32; nn * nn * dd * dd];
    let idx = |x: usize, y: usize, a: usize, b: usize| ((x * nn + y) * dd + a) * dd + b;
    for c in problem.constraints() {
        let (dx, dy) = (c.rel.dx(), c.rel.dy());
        for a in 0..dd {
            for b in 0..dd {
                let allowed = a < dx && b < dy && c.rel.allows(a, b);
                let v = if allowed { 1.0 } else { 0.0 };
                // real pair: padded (a, b) region must provide no fake
                // support, so everything outside the real rectangle is 0.
                cons[idx(c.x, c.y, a, b)] = v;
                cons[idx(c.y, c.x, b, a)] = v;
            }
        }
    }
    Ok(cons)
}

/// Encode the current domains of `state`, padded to `bucket`.
pub fn encode_vars(problem: &Problem, state: &State, bucket: Bucket) -> Result<Vec<f32>> {
    if !bucket.fits(problem) {
        bail!("problem exceeds bucket");
    }
    let (nn, dd) = (bucket.n, bucket.d);
    let mut vars = vec![0.0f32; nn * dd];
    for x in 0..problem.n_vars() {
        for a in state.dom(x).iter_ones() {
            vars[x * dd + a] = 1.0;
        }
    }
    // padded variables: full dummy domains (all ones)
    for x in problem.n_vars()..nn {
        for a in 0..dd {
            vars[x * dd + a] = 1.0;
        }
    }
    Ok(vars)
}

/// Apply an output plane back onto `state`: every live value that the
/// plane zeroed is removed (through the trail, so search can undo it).
/// Returns the list of changed variables.
pub fn decode_vars(
    problem: &Problem,
    state: &mut State,
    plane: &[f32],
    bucket: Bucket,
) -> Result<Vec<VarId>> {
    if plane.len() != bucket.vars_len() {
        bail!("plane length {} != bucket {}", plane.len(), bucket.vars_len());
    }
    let dd = bucket.d;
    let mut changed = Vec::new();
    for x in 0..problem.n_vars() {
        let mut x_changed = false;
        for a in 0..problem.dom_size(x) {
            let live = state.contains(x, a);
            let keep = plane[x * dd + a] != 0.0;
            if live && !keep {
                state.remove(x, a);
                x_changed = true;
            } else if !live && keep {
                // the artifact can only remove values (monotone sweep);
                // seeing a resurrection means caller mixed up planes.
                bail!("plane resurrects removed value ({x}, {a})");
            }
        }
        if x_changed {
            changed.push(x);
        }
    }
    Ok(changed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ac::{rtac::RtacNative, Counters, Propagator};
    use crate::gen::random::{random_csp, RandomSpec};

    fn bucket() -> Bucket {
        Bucket { n: 16, d: 8 }
    }

    #[test]
    fn cons_universal_for_nonedges_zero_padded_for_edges() {
        let p = random_csp(&RandomSpec::new(5, 4, 0.99, 0.4, 3));
        let b = bucket();
        let cons = encode_cons(&p, b).unwrap();
        let idx = |x: usize, y: usize, a: usize, bb: usize| ((x * b.n + y) * b.d + a) * b.d + bb;
        // diagonal universal
        assert_eq!(cons[idx(0, 0, 7, 7)], 1.0);
        // padded var rows universal
        assert_eq!(cons[idx(10, 2, 3, 3)], 1.0);
        // a real edge: padded region is zero
        let c = &p.constraints()[0];
        assert_eq!(cons[idx(c.x, c.y, 0, 7)], 0.0); // b >= dy
        assert_eq!(cons[idx(c.x, c.y, 7, 0)], 0.0); // a >= dx
        // symmetry
        for a in 0..4 {
            for bb in 0..4 {
                assert_eq!(cons[idx(c.x, c.y, a, bb)], cons[idx(c.y, c.x, bb, a)]);
            }
        }
    }

    #[test]
    fn vars_padding_layout() {
        let p = random_csp(&RandomSpec::new(5, 4, 0.5, 0.3, 9));
        let mut s = State::new(&p);
        s.remove(2, 1);
        let b = bucket();
        let vars = encode_vars(&p, &s, b).unwrap();
        assert_eq!(vars.len(), 16 * 8);
        assert_eq!(vars[2 * 8 + 1], 0.0); // removed value
        assert_eq!(vars[2 * 8 + 0], 1.0);
        assert_eq!(vars[0 * 8 + 5], 0.0); // padded value of real var
        assert_eq!(vars[10 * 8 + 7], 1.0); // padded var fully live
    }

    #[test]
    fn decode_applies_removals_and_reports_changes() {
        let p = random_csp(&RandomSpec::new(4, 4, 0.0, 0.0, 1));
        let mut s = State::new(&p);
        let b = bucket();
        let mut plane = encode_vars(&p, &s, b).unwrap();
        plane[0 * 8 + 2] = 0.0;
        plane[3 * 8 + 0] = 0.0;
        let changed = decode_vars(&p, &mut s, &plane, b).unwrap();
        assert_eq!(changed, vec![0, 3]);
        assert!(!s.contains(0, 2));
        assert!(!s.contains(3, 0));
        assert_eq!(s.dom_size(1), 4);
    }

    #[test]
    fn decode_rejects_resurrection() {
        let p = random_csp(&RandomSpec::new(3, 3, 0.0, 0.0, 1));
        let mut s = State::new(&p);
        s.remove(1, 1);
        let b = Bucket { n: 8, d: 4 };
        let mut plane = encode_vars(&p, &s, b).unwrap();
        plane[1 * 4 + 1] = 1.0;
        assert!(decode_vars(&p, &mut s, &plane, b).is_err());
    }

    #[test]
    fn bucket_too_small_is_error() {
        let p = random_csp(&RandomSpec::new(20, 4, 0.1, 0.1, 1));
        assert!(encode_cons(&p, bucket()).is_err());
        let s = State::new(&p);
        assert!(encode_vars(&p, &s, bucket()).is_err());
    }

    /// CPU reference of one dense revise sweep over the padded planes —
    /// mirrors ref.py, used to cross-check the encoding against the
    /// native engine (no XLA needed in unit tests).
    fn sweep_plane(cons: &[f32], vars: &[f32], b: Bucket) -> Vec<f32> {
        let (nn, dd) = (b.n, b.d);
        let mut out = vars.to_vec();
        for x in 0..nn {
            for a in 0..dd {
                if vars[x * dd + a] == 0.0 {
                    continue;
                }
                for y in 0..nn {
                    let mut supp = 0.0f32;
                    for bb in 0..dd {
                        supp += cons[((x * nn + y) * dd + a) * dd + bb] * vars[y * dd + bb];
                    }
                    if supp == 0.0 {
                        out[x * dd + a] = 0.0;
                        break;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn padded_sweep_fixpoint_matches_native_rtac() {
        for seed in [1u64, 7, 42] {
            let p = random_csp(&RandomSpec::new(6, 4, 0.8, 0.5, seed));
            let b = bucket();
            let cons = encode_cons(&p, b).unwrap();

            // native closure
            let mut s_native = State::new(&p);
            let mut c = Counters::default();
            let native_out = RtacNative::dense().enforce(&p, &mut s_native, &[], &mut c);

            // plane fixpoint
            let s0 = State::new(&p);
            let mut plane = encode_vars(&p, &s0, b).unwrap();
            let mut sweeps = 0;
            loop {
                let next = sweep_plane(&cons, &plane, b);
                sweeps += 1;
                let wiped = (0..p.n_vars())
                    .any(|x| (0..b.d).all(|a| next[x * b.d + a] == 0.0));
                if wiped || next == plane {
                    plane = next;
                    break;
                }
                plane = next;
            }
            assert_eq!(sweeps as u64, c.recurrences, "seed {seed}: sweep count");

            if native_out.is_consistent() {
                let mut s_decode = State::new(&p);
                decode_vars(&p, &mut s_decode, &plane, b).unwrap();
                assert_eq!(s_decode.snapshot(), s_native.snapshot(), "seed {seed}");
            }
        }
    }
}
