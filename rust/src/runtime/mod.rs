//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the PJRT CPU client, and
//! execute them from the L3 hot path.  Also owns the tensor
//! encode/decode contract between `Problem`/`State` and the artifact
//! planes.

pub mod encode;
pub mod executor;
pub mod manifest;

pub use encode::{
    decode_vars, encode_cons, encode_vars, encode_vars_into, plane_fingerprint, Bucket, PlaneDelta,
};
pub use executor::{DeviceTensor, FixpointOut, Runtime, STATUS_CONSISTENT, STATUS_WIPEOUT};
pub use manifest::{Entry, Kind, Manifest};

/// Default artifact directory: `$RTAC_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("RTAC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
